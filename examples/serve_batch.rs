//! Serving example: start the batching TCP server over a SALR-deployed
//! model (bitmap pipeline backend), fire concurrent client requests, and
//! report latency/throughput — the paper's deployment story end to end.
//!
//! Run: `cargo run --release --example serve_batch` (after `make artifacts`)

use anyhow::Result;
use salr::eval::{deploy_engine, ExpContext, RunKey, Task};
use salr::server::{serve, BatchPolicy, Client};
use salr::util::json::Json;
use std::time::Duration;

fn main() -> Result<()> {
    salr::util::logger::init();
    // Keep the demo snappy: a lightly-trained model is fine for serving.
    if std::env::var("SALR_STEPS").is_err() {
        std::env::set_var("SALR_STEPS", "40");
    }
    if std::env::var("SALR_PRETRAIN_STEPS").is_err() {
        std::env::set_var("SALR_PRETRAIN_STEPS", "60");
    }
    let ctx = ExpContext::new("artifacts", "tiny", "results")?;
    let key = RunKey {
        baseline: salr::salr::Baseline::Salr,
        task: Task::Math,
        sparsity: 0.5,
    };
    let (spec, adapters, _) = ctx.run(&key)?;
    let engine = deploy_engine(&ctx.cfg, &spec, &adapters, None)?;

    // Start the server on an ephemeral port.
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve(
            engine,
            "127.0.0.1:0",
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                ..Default::default()
            },
            Some(tx),
        )
    });
    let addr = rx.recv()?;
    println!("server up on {addr}");

    // Fire 24 concurrent requests from 8 client threads.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..8 {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<Vec<Json>> {
            let mut client = Client::connect(&addr)?;
            let mut replies = Vec::new();
            for i in 0..3 {
                let a = 10 + c * 7 + i;
                let b = 20 + i * 3;
                let reply = client.generate(&format!("Q: {a}+{b}=? A: "), 5)?;
                replies.push(reply);
            }
            Ok(replies)
        }));
    }
    let mut total_tokens = 0usize;
    let mut n = 0usize;
    for h in handles {
        for reply in h.join().unwrap()? {
            n += 1;
            total_tokens += reply.get("tokens").and_then(Json::as_usize).unwrap_or(0);
            if n <= 4 {
                println!(
                    "  sample reply: text={:?} queue={:.1}ms compute={:.1}ms",
                    reply.get("text").and_then(Json::as_str).unwrap_or(""),
                    reply.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
                    reply.get("compute_ms").and_then(Json::as_f64).unwrap_or(0.0),
                );
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // Pull server-side metrics, then shut down.
    let mut client = Client::connect(&addr.to_string())?;
    let metrics = client.metrics()?;
    println!("\n== serving metrics ==");
    println!(
        "  requests: {}  mean batch: {:.2}",
        metrics.get("requests").and_then(Json::as_usize).unwrap_or(0),
        metrics
            .get("mean_batch_size")
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
    );
    println!(
        "  latency p50/p90/p99: {:.1} / {:.1} / {:.1} ms",
        metrics.get("latency_p50_ms").and_then(Json::as_f64).unwrap_or(0.0),
        metrics.get("latency_p90_ms").and_then(Json::as_f64).unwrap_or(0.0),
        metrics.get("latency_p99_ms").and_then(Json::as_f64).unwrap_or(0.0),
    );
    println!(
        "  client-side: {n} requests, {total_tokens} tokens in {wall:.2}s → {:.1} tokens/s",
        total_tokens as f64 / wall
    );
    client.shutdown()?;
    server.join().unwrap()?;
    println!("serve_batch OK");
    Ok(())
}
