//! The paper's byte-mask lookup table:
//! `LUT: {0..255} → {-1,0,…,7}⁸` where, for a byte mask `m`, `LUT(m)[t]` is
//! the index of bit `t` within the compacted nonzero segment of that byte
//! (i.e. `popcount(m & ((1<<t)-1))`) if bit `t` is set, and `-1` otherwise.

/// Precomputed decode LUT, 256 masks × 8 lane indices.
pub static DECODE_LUT: once_cell::sync::Lazy<[[i8; 8]; 256]> =
    once_cell::sync::Lazy::new(build_lut);

fn build_lut() -> [[i8; 8]; 256] {
    let mut lut = [[-1i8; 8]; 256];
    for mask in 0..256usize {
        let mut idx = 0i8;
        for t in 0..8 {
            if (mask >> t) & 1 == 1 {
                lut[mask][t] = idx;
                idx += 1;
            }
        }
    }
    lut
}

/// Decode one byte-block: scatter up to 8 packed values into `out[0..8]`
/// according to `mask`; returns the number of values consumed
/// (= popcount(mask)). `out` lanes with a 0 bit are set to 0.0.
#[inline]
pub fn decode_byte(mask: u8, values: &[f32], out: &mut [f32]) -> usize {
    let lanes = &DECODE_LUT[mask as usize];
    for t in 0..8 {
        let l = lanes[t];
        out[t] = if l >= 0 { values[l as usize] } else { 0.0 };
    }
    mask.count_ones() as usize
}

/// Branchless variant used on the hot path: iterates set bits only.
#[inline]
pub fn decode_byte_bits(mask: u8, values: &[f32], out: &mut [f32]) -> usize {
    out[..8].fill(0.0);
    let mut m = mask;
    let mut i = 0usize;
    while m != 0 {
        let t = m.trailing_zeros() as usize;
        out[t] = values[i];
        i += 1;
        m &= m - 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_popcount_prefix() {
        for mask in 0..256usize {
            let lanes = &DECODE_LUT[mask];
            for t in 0..8 {
                if (mask >> t) & 1 == 1 {
                    let want = (mask & ((1 << t) - 1)).count_ones() as i8;
                    assert_eq!(lanes[t], want, "mask={mask:08b} t={t}");
                } else {
                    assert_eq!(lanes[t], -1);
                }
            }
        }
    }

    #[test]
    fn decode_byte_scatters() {
        let vals = [1.0, 2.0, 3.0];
        let mut out = [9.0f32; 8];
        let consumed = decode_byte(0b1010_0010, &vals, &mut out);
        assert_eq!(consumed, 3);
        assert_eq!(out, [0.0, 1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 3.0]);
    }

    #[test]
    fn decode_variants_agree() {
        let vals = [5.0, -1.5, 2.25, 7.0, 0.5, 3.0, -2.0, 8.0];
        for mask in 0..256usize {
            let mut a = [0.0f32; 8];
            let mut b = [0.0f32; 8];
            let ca = decode_byte(mask as u8, &vals, &mut a);
            let cb = decode_byte_bits(mask as u8, &vals, &mut b);
            assert_eq!(ca, cb);
            assert_eq!(a, b, "mask={mask:08b}");
        }
    }
}
