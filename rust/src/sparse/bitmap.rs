//! The paper's bitmap sparse matrix: a `{0,1}^{d_in × d_out}` bitmap packed
//! into bytes (8 columns per byte block, row-major) plus a compact value
//! array `v ∈ R^{nnz}` in row-major order. True compression: at 50%
//! sparsity the format stores 1 bit + 0.5·32 bits per entry ≈ 0.53× the
//! dense f32 size; the paper's "2× model compression".

use crate::sparse::lut::decode_byte;
use crate::tensor::Tensor;

/// Bitmap-encoded sparse matrix (row-major, byte-blocked columns).
#[derive(Clone, Debug, PartialEq)]
pub struct BitmapMatrix {
    rows: usize,
    cols: usize,
    /// `bytes_per_row = ceil(cols / 8)` masks per row.
    masks: Vec<u8>,
    /// Nonzero values, row-major.
    values: Vec<f32>,
    /// Per-row offsets into `values` (len = rows + 1) for O(1) row access.
    row_offsets: Vec<u32>,
}

impl BitmapMatrix {
    /// Encode a dense matrix (exact zeros are pruned positions).
    pub fn encode(t: &Tensor) -> BitmapMatrix {
        let (rows, cols) = (t.rows(), t.cols());
        let bpr = cols.div_ceil(8);
        let mut masks = vec![0u8; rows * bpr];
        let mut values = Vec::with_capacity(t.nnz());
        let mut row_offsets = Vec::with_capacity(rows + 1);
        row_offsets.push(0u32);
        for i in 0..rows {
            let row = t.row(i);
            for (b, chunk) in row.chunks(8).enumerate() {
                let mut mask = 0u8;
                for (tbit, &v) in chunk.iter().enumerate() {
                    if v != 0.0 {
                        mask |= 1 << tbit;
                        values.push(v);
                    }
                }
                masks[i * bpr + b] = mask;
            }
            row_offsets.push(values.len() as u32);
        }
        BitmapMatrix {
            rows,
            cols,
            masks,
            values,
            row_offsets,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Bytes per row of bitmap.
    pub fn bytes_per_row(&self) -> usize {
        self.cols.div_ceil(8)
    }

    pub fn masks(&self) -> &[u8] {
        &self.masks
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    pub fn row_offsets(&self) -> &[u32] {
        &self.row_offsets
    }

    /// Serialized size in bytes: bitmap + values + offsets (+16B header).
    pub fn storage_bytes(&self) -> usize {
        16 + self.masks.len() + self.values.len() * 4 + self.row_offsets.len() * 4
    }

    /// Size of the equivalent dense f32 matrix.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Compression ratio dense/bitmap.
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }

    /// Decode the full matrix to dense.
    pub fn decode(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let cols = self.cols;
        for i in 0..self.rows {
            self.decode_row_into(i, &mut out.data_mut()[i * cols..(i + 1) * cols]);
        }
        out
    }

    /// Decode one row into a caller-provided buffer of length `cols`.
    ///
    /// Fast path: the mask is consumed **64 bits at a time** — one
    /// `u64` load per 8 byte-blocks, a vectorizable 64-lane zero fill,
    /// then a popcount-driven scatter that touches only the set bits
    /// (`trailing_zeros` + clear-lowest per nonzero, no per-lane branch).
    /// This is stage 1 of the paper's two-stage pipeline, so at high
    /// sparsity the scatter does `(1−p)·64` stores per word instead of
    /// 64 LUT writes. The ragged tail (< 64 columns) falls back to the
    /// byte-LUT decode — the paper's reconstruction rule, kept as the
    /// oracle the word path is tested against.
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(out.len() >= self.cols);
        let bpr = self.bytes_per_row();
        let mut voff = self.row_offsets[i] as usize;
        let row_masks = &self.masks[i * bpr..(i + 1) * bpr];
        // Word-at-a-time over every full 64-column block.
        let words = self.cols / 64;
        for wi in 0..words {
            let mbytes: [u8; 8] = row_masks[wi * 8..wi * 8 + 8].try_into().unwrap();
            // Little-endian: byte b of the word covers columns
            // [base + 8b, base + 8b + 8), bit t within it column base+8b+t
            // — so ascending bit index is ascending column index and the
            // packed values are consumed in their row-major order.
            let mut m = u64::from_le_bytes(mbytes);
            let base = wi * 64;
            let seg = &mut out[base..base + 64];
            seg.fill(0.0);
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                seg[t] = self.values[voff];
                voff += 1;
                m &= m - 1;
            }
        }
        // Byte-LUT tail for the remaining < 64 columns.
        let mut scratch = [0.0f32; 8];
        for b in words * 8..bpr {
            let mask = row_masks[b];
            let base = b * 8;
            let lanes = (self.cols - base).min(8);
            if lanes == 8 {
                voff += decode_byte(mask, &self.values[voff..], &mut out[base..base + 8]);
            } else {
                // Ragged tail block.
                let n = decode_byte(mask, &self.values[voff..], &mut scratch);
                out[base..base + lanes].copy_from_slice(&scratch[..lanes]);
                voff += n;
            }
        }
    }

    /// Decode a contiguous block of rows `[r0, r1)` into `out`
    /// (row-major, `(r1-r0) × cols`). This is the unit of work handed to the
    /// two-stage pipeline's decode stage.
    pub fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        let cols = self.cols;
        for (k, i) in (r0..r1).enumerate() {
            self.decode_row_into(i, &mut out[k * cols..(k + 1) * cols]);
        }
    }

    /// Random access to a single element (tests / debugging; O(1) via
    /// popcount of the mask prefix).
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let bpr = self.bytes_per_row();
        let b = j / 8;
        let t = j % 8;
        let mask = self.masks[i * bpr + b];
        if (mask >> t) & 1 == 0 {
            return 0.0;
        }
        // Count nonzeros in the row before this byte block.
        let mut off = self.row_offsets[i] as usize;
        for bb in 0..b {
            off += self.masks[i * bpr + bb].count_ones() as usize;
        }
        off += (mask & ((1u16 << t) as u8).wrapping_sub(1)).count_ones() as usize;
        self.values[off]
    }

    /// Overwrite the nonzero values from a dense tensor with the *same*
    /// sparsity pattern (used when the trained residual is folded back).
    pub fn refill_values(&mut self, t: &Tensor) {
        assert_eq!(t.rows(), self.rows);
        assert_eq!(t.cols(), self.cols);
        let mut k = 0usize;
        let bpr = self.bytes_per_row();
        for i in 0..self.rows {
            let row = t.row(i);
            for b in 0..bpr {
                let mask = self.masks[i * bpr + b];
                let mut m = mask;
                while m != 0 {
                    let tbit = m.trailing_zeros() as usize;
                    self.values[k] = row[b * 8 + tbit];
                    k += 1;
                    m &= m - 1;
                }
            }
        }
        debug_assert_eq!(k, self.values.len());
    }

    /// Serialize only the sparsity *pattern* (header + masks; offsets are
    /// recomputed on load). Pair with an external value codec (e.g. NF4
    /// for QSALR) via [`BitmapMatrix::from_pattern_and_values`].
    pub fn pattern_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.masks.len());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        out.extend_from_slice(&(self.values.len() as u32).to_le_bytes());
        out.extend_from_slice(&0xB17Bu32.to_le_bytes()); // pattern magic
        out.extend_from_slice(&self.masks);
        out
    }

    /// Rebuild from a pattern (see [`BitmapMatrix::pattern_bytes`]) plus a
    /// row-major value array of length nnz.
    pub fn from_pattern_and_values(bytes: &[u8], values: Vec<f32>) -> anyhow::Result<BitmapMatrix> {
        use anyhow::{bail, ensure};
        ensure!(bytes.len() >= 16, "bitmap pattern: truncated header");
        let rows = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let cols = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let nnz = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
        let magic = u32::from_le_bytes(bytes[12..16].try_into()?);
        if magic != 0xB17B {
            bail!("bitmap pattern: bad magic {magic:#x}");
        }
        let bpr = cols.div_ceil(8);
        ensure!(bytes.len() == 16 + rows * bpr, "bitmap pattern: bad size");
        ensure!(values.len() == nnz, "bitmap pattern: value count mismatch");
        let masks = bytes[16..].to_vec();
        let mut row_offsets = Vec::with_capacity(rows + 1);
        row_offsets.push(0u32);
        let mut acc = 0u32;
        for i in 0..rows {
            for b in 0..bpr {
                acc += masks[i * bpr + b].count_ones();
            }
            row_offsets.push(acc);
        }
        ensure!(acc as usize == nnz, "bitmap pattern: popcount != nnz");
        Ok(BitmapMatrix {
            rows,
            cols,
            masks,
            values,
            row_offsets,
        })
    }

    /// Serialize to bytes (header, masks, offsets, values — little endian).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.storage_bytes());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        out.extend_from_slice(&(self.values.len() as u32).to_le_bytes());
        out.extend_from_slice(&0xB17Au32.to_le_bytes()); // magic
        out.extend_from_slice(&self.masks);
        for &o in &self.row_offsets {
            out.extend_from_slice(&o.to_le_bytes());
        }
        for &v in &self.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Deserialize from `to_bytes` output.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<BitmapMatrix> {
        use anyhow::{bail, ensure};
        ensure!(bytes.len() >= 16, "bitmap: truncated header");
        let rows = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let cols = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let nnz = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
        let magic = u32::from_le_bytes(bytes[12..16].try_into()?);
        if magic != 0xB17A {
            bail!("bitmap: bad magic {magic:#x}");
        }
        let bpr = cols.div_ceil(8);
        let masks_len = rows * bpr;
        let offsets_len = (rows + 1) * 4;
        let need = 16 + masks_len + offsets_len + nnz * 4;
        ensure!(bytes.len() == need, "bitmap: size {} != {need}", bytes.len());
        let masks = bytes[16..16 + masks_len].to_vec();
        let mut row_offsets = Vec::with_capacity(rows + 1);
        let mut p = 16 + masks_len;
        for _ in 0..=rows {
            row_offsets.push(u32::from_le_bytes(bytes[p..p + 4].try_into()?));
            p += 4;
        }
        let mut values = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            values.push(f32::from_le_bytes(bytes[p..p + 4].try_into()?));
            p += 4;
        }
        Ok(BitmapMatrix {
            rows,
            cols,
            masks,
            values,
            row_offsets,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, r: usize, c: usize, p: f64) -> Tensor {
        let mut t = Tensor::randn(&[r, c], 1.0, rng);
        prune_global(&mut [&mut t], p);
        t
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(80);
        for &(r, c, p) in &[(8, 8, 0.5), (16, 100, 0.5), (7, 13, 0.3), (1, 1, 0.0), (5, 9, 0.9)] {
            let t = random_sparse(&mut rng, r, c, p);
            let bm = BitmapMatrix::encode(&t);
            assert_eq!(bm.decode(), t, "({r},{c},{p})");
            assert_eq!(bm.nnz(), t.nnz());
        }
    }

    #[test]
    fn random_access_matches_dense() {
        let mut rng = Rng::new(81);
        let t = random_sparse(&mut rng, 20, 37, 0.6);
        let bm = BitmapMatrix::encode(&t);
        for i in 0..20 {
            for j in 0..37 {
                assert_eq!(bm.get(i, j), t.at(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn compression_near_two_x_at_half_sparsity() {
        let mut rng = Rng::new(82);
        let t = random_sparse(&mut rng, 512, 512, 0.5);
        let bm = BitmapMatrix::encode(&t);
        let ratio = bm.compression_ratio();
        // dense = 32 bits/entry; bitmap = 1 + 0.5*32 ≈ 17 bits → ratio ≈ 1.88
        assert!(ratio > 1.8 && ratio < 2.0, "ratio={ratio}");
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Rng::new(83);
        let t = random_sparse(&mut rng, 33, 65, 0.5);
        let bm = BitmapMatrix::encode(&t);
        let bytes = bm.to_bytes();
        assert_eq!(bytes.len(), bm.storage_bytes());
        let back = BitmapMatrix::from_bytes(&bytes).unwrap();
        assert_eq!(back, bm);
        assert!(BitmapMatrix::from_bytes(&bytes[..10]).is_err());
        let mut corrupt = bytes.clone();
        corrupt[12] = 0xFF;
        assert!(BitmapMatrix::from_bytes(&corrupt).is_err());
    }

    #[test]
    fn refill_preserves_pattern() {
        let mut rng = Rng::new(84);
        let t = random_sparse(&mut rng, 12, 24, 0.5);
        let mut bm = BitmapMatrix::encode(&t);
        let t2 = t.map(|x| x * 3.0);
        bm.refill_values(&t2);
        assert_eq!(bm.decode(), t2);
    }

    #[test]
    fn decode_rows_block() {
        let mut rng = Rng::new(85);
        let t = random_sparse(&mut rng, 16, 40, 0.5);
        let bm = BitmapMatrix::encode(&t);
        let mut buf = vec![0.0f32; 4 * 40];
        bm.decode_rows_into(4, 8, &mut buf);
        for k in 0..4 {
            assert_eq!(&buf[k * 40..(k + 1) * 40], t.row(4 + k));
        }
    }

    #[test]
    fn word_fast_path_matches_lut_decode() {
        // Shapes chosen to exercise the 64-bit word path: exactly one
        // word, multiple words, words + byte tail, words + ragged bit
        // tail — across sparsities including fully dense and fully empty.
        let mut rng = Rng::new(86);
        for &(r, c) in &[(4usize, 64usize), (3, 128), (2, 130), (5, 197), (1, 64 + 7)] {
            for &p in &[0.0f64, 0.5, 0.95, 1.0] {
                let t = random_sparse(&mut rng, r, c, p);
                let bm = BitmapMatrix::encode(&t);
                // decode() goes through decode_row_into (the word path).
                assert_eq!(bm.decode(), t, "({r},{c},{p})");
                // Per-element oracle: the popcount-prefix random access.
                let mut row = vec![f32::NAN; c];
                for i in 0..r {
                    bm.decode_row_into(i, &mut row);
                    for j in 0..c {
                        assert_eq!(row[j], bm.get(i, j), "({r},{c},{p}) at ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn word_path_handles_extreme_masks() {
        // All-ones and all-zeros words, plus a single bit at each word
        // boundary position.
        let mut t = Tensor::zeros(&[3, 128]);
        for j in 0..128 {
            t.set(0, j, (j + 1) as f32); // row 0: fully dense
        }
        t.set(2, 0, 1.0);
        t.set(2, 63, 2.0);
        t.set(2, 64, 3.0);
        t.set(2, 127, 4.0);
        let bm = BitmapMatrix::encode(&t);
        assert_eq!(bm.decode(), t);
    }

    #[test]
    fn prop_roundtrip_any_shape_and_sparsity() {
        Prop::new(32).check(
            "bitmap roundtrip",
            |rng| {
                let r = 1 + rng.below(30);
                let c = 1 + rng.below(70);
                let p = rng.uniform() * 0.95;
                let mut t = Tensor::randn(&[r, c], 1.0, rng);
                prune_global(&mut [&mut t], p);
                t
            },
            |t| {
                let bm = BitmapMatrix::encode(t);
                if bm.decode() == *t && BitmapMatrix::from_bytes(&bm.to_bytes()).unwrap() == bm {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
