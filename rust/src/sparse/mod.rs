//! Sparse weight representations.
//!
//! The paper's deployment format is a **bitmap encoding**: one bit per
//! element plus a compact row-major array of the nonzero values. Decoding
//! is byte-block-wise with a precomputed 256-entry lookup table
//! (paper, "Mapping Sparse Weights"). A CSR implementation is included as
//! the baseline the paper argues against (indexing overhead), and a
//! block decoder feeds the two-stage pipeline in [`crate::gemm::pipeline`].

pub mod bitmap;
pub mod csr;
pub mod lut;

pub use bitmap::BitmapMatrix;
pub use csr::CsrMatrix;
pub use lut::{decode_byte, DECODE_LUT};
