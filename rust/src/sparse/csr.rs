//! CSR (compressed sparse row) matrix — the baseline format the paper
//! argues incurs "significant indexing overhead" relative to the bitmap.
//! Included for the format-comparison microbenchmarks and to validate that
//! claim on this testbed.

use crate::tensor::Tensor;

/// Classic CSR: row pointers, column indices, values.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    pub fn encode(t: &Tensor) -> CsrMatrix {
        let (rows, cols) = (t.rows(), t.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..rows {
            for (j, &v) in t.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        CsrMatrix {
            rows,
            cols,
            row_ptr,
            col_idx,
            values,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Serialized size: ptrs + 32-bit indices + values (+16B header).
    pub fn storage_bytes(&self) -> usize {
        16 + self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }

    pub fn decode(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let orow = out.row_mut(i);
            for k in s..e {
                orow[self.col_idx[k] as usize] = self.values[k];
            }
        }
        out
    }

    /// Decode one row into a zeroed buffer.
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        out[..self.cols].fill(0.0);
        let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        for k in s..e {
            out[self.col_idx[k] as usize] = self.values[k];
        }
    }

    /// Sparse matrix–vector product `y = Aᵀ·x`-style row gather:
    /// `y[j] += Σ_i x[i]·A[i,j]` done row-wise (`x` has `rows` entries).
    pub fn spmv_t(&self, x: &[f32], y: &mut [f32]) {
        assert!(x.len() >= self.rows && y.len() >= self.cols);
        y[..self.cols].fill(0.0);
        for i in 0..self.rows {
            let xv = x[i];
            if xv == 0.0 {
                continue;
            }
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for k in s..e {
                y[self.col_idx[k] as usize] += xv * self.values[k];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::sparse::BitmapMatrix;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, r: usize, c: usize, p: f64) -> Tensor {
        let mut t = Tensor::randn(&[r, c], 1.0, rng);
        prune_global(&mut [&mut t], p);
        t
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(90);
        let t = random_sparse(&mut rng, 23, 41, 0.5);
        let csr = CsrMatrix::encode(&t);
        assert_eq!(csr.decode(), t);
        assert_eq!(csr.nnz(), t.nnz());
    }

    #[test]
    fn bitmap_beats_csr_storage_at_moderate_sparsity() {
        // At 50% sparsity CSR pays 32 index bits/nnz = 16 bits/entry vs the
        // bitmap's 1 bit/entry — the paper's core storage argument.
        let mut rng = Rng::new(91);
        let t = random_sparse(&mut rng, 256, 256, 0.5);
        let csr = CsrMatrix::encode(&t);
        let bm = BitmapMatrix::encode(&t);
        assert!(
            bm.storage_bytes() < csr.storage_bytes(),
            "bitmap {} vs csr {}",
            bm.storage_bytes(),
            csr.storage_bytes()
        );
    }

    #[test]
    fn csr_wins_at_extreme_sparsity() {
        // At 99% sparsity the bitmap still pays 1 bit/entry; CSR's nnz-
        // proportional cost wins — the formats cross over as expected.
        let mut rng = Rng::new(92);
        let t = random_sparse(&mut rng, 256, 256, 0.99);
        let csr = CsrMatrix::encode(&t);
        let bm = BitmapMatrix::encode(&t);
        assert!(csr.storage_bytes() < bm.storage_bytes() + 256 * 256 / 8);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(93);
        let t = random_sparse(&mut rng, 30, 50, 0.6);
        let csr = CsrMatrix::encode(&t);
        let x: Vec<f32> = (0..30).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; 50];
        csr.spmv_t(&x, &mut y);
        // dense reference
        let mut want = vec![0.0f32; 50];
        for i in 0..30 {
            for j in 0..50 {
                want[j] += x[i] * t.at(i, j);
            }
        }
        for j in 0..50 {
            assert!((y[j] - want[j]).abs() < 1e-4);
        }
    }
}
