//! Persistent worker pool for the multi-core GEMM and pipeline stages.
//!
//! Threads are created **once** (per pool size) and reused for every
//! parallel region; submitting work never spawns a thread. A parallel
//! region is a *scoped parallel-for*: [`WorkerPool::run`] hands indices
//! `0..n` to the pool workers **and the calling thread**, and does not
//! return until every index has finished executing — which is what makes
//! it sound to pass a closure borrowing stack data.
//!
//! Design notes:
//!
//! * Jobs go through a FIFO queue. Workers drain the front job
//!   cooperatively (claiming indices from an atomic counter), pop it once
//!   all indices are claimed, and move on. The caller always participates
//!   in its own job, so a parallel-for completes even if every worker is
//!   busy elsewhere — workers are an acceleration, never a requirement.
//! * Jobs whose tasks *coordinate* with each other (the pipeline's decode
//!   and consume roles) rely on the queue being FIFO plus the invariant
//!   that a job's role count never exceeds `threads()`: the front job
//!   eventually receives every worker, so all roles get running.
//! * Task panics are caught at the task boundary, recorded, and re-raised
//!   on the submitting thread after the region completes.

use crossbeam_utils::CachePadded;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Raw `*mut f32` wrapper so pool tasks can write disjoint regions of a
/// shared output buffer. The caller is responsible for disjointness.
#[derive(Clone, Copy)]
pub struct SendPtr(
    /// Base pointer of the shared output buffer.
    pub *mut f32,
);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// One parallel-for region: workers claim indices `0..n` from `next`;
/// `done` counts finished index executions.
struct Job {
    /// Type-erased borrowed closure. Only dereferenced for successfully
    /// claimed indices, and the submitting thread blocks in `run` until
    /// `done == n`, which keeps the referent alive for every dereference.
    task: *const (dyn Fn(usize) + Sync),
    n: usize,
    next: CachePadded<AtomicUsize>,
    done: CachePadded<AtomicUsize>,
    panicked: AtomicBool,
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Inbox {
    queue: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    inbox: Mutex<Inbox>,
    cv: Condvar,
}

fn run_job(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        // SAFETY: `i < n` means the submitting thread is still blocked in
        // `run`, so the closure behind `task` is alive.
        let task = unsafe { &*job.task };
        if catch_unwind(AssertUnwindSafe(|| task(i))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        job.done.fetch_add(1, Ordering::Release);
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut inbox = shared.inbox.lock().unwrap();
            loop {
                if inbox.shutdown {
                    return;
                }
                // Retire fully-claimed jobs from the front.
                loop {
                    let exhausted = match inbox.queue.front() {
                        Some(front) => front.next.load(Ordering::Relaxed) >= front.n,
                        None => break,
                    };
                    if exhausted {
                        inbox.queue.pop_front();
                    } else {
                        break;
                    }
                }
                if let Some(front) = inbox.queue.front() {
                    break front.clone();
                }
                inbox = shared.cv.wait(inbox).unwrap();
            }
        };
        run_job(&job);
    }
}

/// A fixed-size pool of persistent worker threads (plus the caller).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Pool with `threads` total execution contexts: `threads - 1` OS
    /// threads are spawned; the submitting thread is always the last one.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            inbox: Mutex::new(Inbox {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("salr-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            threads,
        }
    }

    /// Total execution contexts (spawned workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(n-1)` across the pool and the calling thread;
    /// returns once all have finished. Panics (on the calling thread) if
    /// any task panicked. Nested calls are allowed and cannot deadlock:
    /// the nested caller drains its own job.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.threads == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // Erase the borrow lifetime: sound because we do not return until
        // `done == n` and no index is dereferenced after all are claimed.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let job = Arc::new(Job {
            task: task as *const (dyn Fn(usize) + Sync),
            n,
            next: CachePadded::new(AtomicUsize::new(0)),
            done: CachePadded::new(AtomicUsize::new(0)),
            panicked: AtomicBool::new(false),
        });
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.queue.push_back(job.clone());
        }
        self.shared.cv.notify_all();
        run_job(&job);
        let mut waited = 0u32;
        while job.done.load(Ordering::Acquire) < n {
            waited += 1;
            if waited < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker pool task panicked");
        }
    }

    /// The process-global pool, sized to `available_threads()` unless
    /// [`WorkerPool::set_global_threads`] chose otherwise.
    pub fn global() -> Arc<WorkerPool> {
        let mut g = global_slot().lock().unwrap();
        g.get_or_insert_with(|| Arc::new(WorkerPool::new(available_threads())))
            .clone()
    }

    /// Resize the process-global pool (the CLI `--threads` knob).
    /// `0` restores the hardware default.
    pub fn set_global_threads(threads: usize) {
        let threads = if threads == 0 {
            available_threads()
        } else {
            threads
        };
        *global_slot().lock().unwrap() = Some(WorkerPool::sized(threads));
    }

    /// Resolve a thread-count knob to a persistent pool: `0` means the
    /// process-global pool, anything else a cached pool of that exact size.
    pub fn with_threads(threads: usize) -> Arc<WorkerPool> {
        if threads == 0 {
            WorkerPool::global()
        } else {
            WorkerPool::sized(threads)
        }
    }

    fn sized(threads: usize) -> Arc<WorkerPool> {
        static SIZED: OnceLock<Mutex<HashMap<usize, Arc<WorkerPool>>>> = OnceLock::new();
        let map = SIZED.get_or_init(|| Mutex::new(HashMap::new()));
        let mut m = map.lock().unwrap();
        m.entry(threads)
            .or_insert_with(|| Arc::new(WorkerPool::new(threads)))
            .clone()
    }
}

fn global_slot() -> &'static Mutex<Option<Arc<WorkerPool>>> {
    static GLOBAL: OnceLock<Mutex<Option<Arc<WorkerPool>>>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(None))
}

/// Hardware thread count (1 if it cannot be determined).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut inbox = self.shared.inbox.lock().unwrap();
            inbox.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn single_thread_pool_runs_on_caller() {
        let pool = WorkerPool::new(1);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn disjoint_writes_via_sendptr() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0.0f32; 64];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.run(64, &|i| {
            // SAFETY: each task writes only its own element.
            unsafe { *ptr.0.add(i) = i as f32 };
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the caller");
        // The pool keeps working after a task panic.
        let sum = AtomicUsize::new(0);
        pool.run(4, &|i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn concurrent_submissions_from_many_threads() {
        let pool = WorkerPool::sized(3);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = pool.clone();
                s.spawn(move || {
                    let sum = AtomicUsize::new(0);
                    p.run(32, &|i| {
                        sum.fetch_add(i, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 31 * 32 / 2);
                });
            }
        });
    }

    #[test]
    fn with_threads_zero_is_global() {
        let a = WorkerPool::with_threads(0);
        let b = WorkerPool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }
}
