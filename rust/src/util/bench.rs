//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean / median / p10 / p90 and median absolute deviation, and renders
//! aligned comparison tables. Used by every `rust/benches/*.rs` target
//! (`harness = false`) and by the table-reproduction drivers in `eval`.

use crate::util::json::Json;
use std::time::Instant;

/// Result statistics of one benchmark case (all times in seconds/iteration).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub mad: f64,
    /// Optional work units per iteration (e.g. FLOPs or bytes) for rates.
    pub work_per_iter: f64,
}

impl Stats {
    /// Work units per second (0 if `work_per_iter` unset).
    pub fn rate(&self) -> f64 {
        if self.work_per_iter > 0.0 {
            self.work_per_iter / self.median
        } else {
            0.0
        }
    }

    /// Machine-readable form (times in seconds, rate in work units/s).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_s", self.mean)
            .set("median_s", self.median)
            .set("p10_s", self.p10)
            .set("p90_s", self.p90)
            .set("mad_s", self.mad)
            .set("work_per_iter", self.work_per_iter)
            .set("rate_per_s", self.rate())
    }
}

/// Benchmark runner with warmup and sample-based statistics.
pub struct Bench {
    /// Target total measurement time per case (seconds).
    pub measure_secs: f64,
    /// Warmup time per case (seconds).
    pub warmup_secs: f64,
    /// Number of samples (batches of iterations) to collect.
    pub samples: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_secs: read_env_f64("SALR_BENCH_SECS", 1.0),
            warmup_secs: 0.3,
            samples: 20,
            results: Vec::new(),
        }
    }
}

fn read_env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI: ~10x shorter runs.
    pub fn quick() -> Self {
        Bench {
            measure_secs: 0.1,
            warmup_secs: 0.02,
            samples: 8,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE iteration of work per call.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> Stats {
        self.run_with_work(name, 0.0, &mut f)
    }

    /// Benchmark with a known amount of work per iteration (for rates).
    pub fn run_with_work(&mut self, name: &str, work_per_iter: f64, f: &mut dyn FnMut()) -> Stats {
        // Calibrate: how many iters fit in one sample slot?
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let sample_time = self.measure_secs / self.samples as f64;
        let iters_per_sample = ((sample_time / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile(&samples, 50.0);
        let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            name: name.to_string(),
            iters: iters_per_sample * self.samples as u64,
            mean: samples.iter().sum::<f64>() / samples.len() as f64,
            median,
            p10: percentile(&samples, 10.0),
            p90: percentile(&samples, 90.0),
            mad: percentile(&devs, 50.0),
            work_per_iter,
        };
        println!("{}", format_stat_line(&stats));
        self.results.push(stats.clone());
        stats
    }

    /// All results collected so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// All collected results as a JSON array.
    pub fn results_json(&self) -> Json {
        Json::Arr(self.results.iter().map(Stats::to_json).collect())
    }

    /// Write results plus caller metadata to `path` as pretty JSON — the
    /// machine-readable `BENCH_*.json` perf-trajectory files are built
    /// from this (e.g. `SALR_BENCH_JSON=BENCH_gemm.json cargo bench
    /// --bench bench_gemm`).
    pub fn write_json(&self, path: &std::path::Path, meta: Json) -> std::io::Result<()> {
        write_bench_doc(path, meta, self.results_json())
    }

    /// Render a comparison table with speedups relative to the first row.
    pub fn comparison_table(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {title} ==\n"));
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>9}\n",
            "case", "median", "p10", "p90", "speedup"
        ));
        let base = self.results.first().map(|s| s.median).unwrap_or(1.0);
        for s in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>8.2}x\n",
                s.name,
                crate::util::human_secs(s.median),
                crate::util::human_secs(s.p10),
                crate::util::human_secs(s.p90),
                base / s.median
            ));
        }
        out
    }
}

/// Write a `salr-bench-v1` document (`schema` + `meta` + `results`) to
/// `path` — the single place the perf-trajectory file format is
/// assembled, shared by [`Bench::write_json`] and benches that collect
/// results outside a [`Bench`] (e.g. `bench_serve`'s throughput rows).
pub fn write_bench_doc(
    path: impl AsRef<std::path::Path>,
    meta: Json,
    results: Json,
) -> std::io::Result<()> {
    let doc = Json::obj()
        .set("schema", "salr-bench-v1")
        .set("meta", meta)
        .set("results", results);
    std::fs::write(path, doc.to_string_pretty())
}

fn format_stat_line(s: &Stats) -> String {
    let rate = if s.work_per_iter > 0.0 {
        format!("  ({:.2} Gunits/s)", s.rate() / 1e9)
    } else {
        String::new()
    };
    format!(
        "bench {:<44} median {:>10}  p90 {:>10}  (n={}){}",
        s.name,
        crate::util::human_secs(s.median),
        crate::util::human_secs(s.p90),
        s.iters,
        rate
    )
}

/// Linear-interpolated percentile of a **sorted** slice.
pub fn percentile(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

/// Prevent the optimizer from eliding a benchmarked computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_orders() {
        let mut b = Bench {
            measure_secs: 0.02,
            warmup_secs: 0.002,
            samples: 4,
            results: Vec::new(),
        };
        let s_fast = b.run("fast", || {
            black_box(1 + 1);
        });
        let mut acc = 0u64;
        let s_slow = b.run("slow", || {
            for i in 0..3000 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s_slow.median > s_fast.median);
        assert_eq!(b.results().len(), 2);
        assert!(b.comparison_table("t").contains("fast"));
    }

    #[test]
    fn json_emission_has_rates() {
        let mut b = Bench {
            measure_secs: 0.01,
            warmup_secs: 0.002,
            samples: 2,
            results: Vec::new(),
        };
        b.run_with_work("case", 100.0, &mut || {
            black_box(1 + 1);
        });
        let j = b.results_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").and_then(Json::as_str), Some("case"));
        assert!(arr[0].get("rate_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
