//! Foundational utilities built from scratch (the offline vendor set has no
//! `rand`, `serde`, `criterion` or `proptest`): a PCG64 PRNG, a JSON codec,
//! a micro-benchmark harness, a property-test driver, a logger, process
//! memory accounting and a persistent worker pool.

// Part of the documented-API guarantee (see lib.rs): every public item
// in the arena carries rustdoc, enforced by CI's `cargo doc` step.
#[warn(missing_docs)]
pub mod arena;
pub mod bench;
// Same documented-API guarantee as `arena`.
#[warn(missing_docs)]
pub mod fault;
// Same documented-API guarantee as `arena`.
#[warn(missing_docs)]
pub mod hist;
pub mod json;
pub mod logger;
pub mod mem;
// Same documented-API guarantee as `arena`.
#[warn(missing_docs)]
pub mod pool;
pub mod prop;
pub mod rng;
// Same documented-API guarantee as `arena`.
#[warn(missing_docs)]
pub mod trace;

pub use bench::Bench;
pub use hist::Hist;
pub use json::Json;
pub use pool::WorkerPool;
pub use rng::Rng;

/// One truthy-token set for every boolean env var and CLI flag
/// (`--prefix-cache on` and `SALR_PREFIX_CACHE=on` must agree).
pub fn truthy(s: &str) -> bool {
    matches!(s, "1" | "true" | "yes" | "on")
}

/// Format a byte count as a human-readable string (`12.3 MiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds adaptively (`1.23 ms`, `4.5 s`).
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(0.5e-9 * 2.0), "1.0 ns");
        assert_eq!(human_secs(2.5e-3), "2.50 ms");
        assert_eq!(human_secs(3.0), "3.00 s");
    }
}
