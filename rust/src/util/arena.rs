//! Per-worker scratch arena: thread-local, grow-only, zero steady-state
//! allocation on the GEMM/decode hot path.
//!
//! Every slab lives in a **thread-local free list**. Pool worker threads
//! (`util/pool.rs`) are persistent — created once per pool size and reused
//! for every parallel region — so a slab checked out by a worker for one
//! GEMM band comes back to the *same* worker's arena and is reused by the
//! next band it executes. After a warmup pass through a given call path,
//! every checkout is a free-list pop and every release a push: no heap
//! traffic at all.
//!
//! Ownership protocol:
//!
//! ```text
//!   caller thread            worker thread W            worker thread W'
//!   ─────────────            ───────────────            ────────────────
//!   [free list]              [free list]                [free list]
//!        │ scratch_*()            │ scratch_*()              │
//!        ▼                        ▼                          ▼
//!     Scratch guard  ──borrow──▶ kernel / decode / pack  (no sharing:
//!        │                        │                       each thread
//!        ▼ Drop                   ▼ Drop                  owns its slabs)
//!   [free list]              [free list]                [free list]
//! ```
//!
//! A [`Scratch`] guard owns its slab exclusively for its lifetime and
//! returns it on `Drop` (best-fit, capacity-sorted; a checkout nothing
//! fits starts a new slab rather than growing an undersized one, so one
//! warmup pass leaves a slab per live size class). Total arena capacity
//! is monotone and observable through [`allocated_bytes`] /
//! [`thread_allocated_bytes`], which is what the zero-allocation
//! regression tests assert on: after one warmup decode step, repeated
//! `decode_step` calls must not move the counter.
//!
//! Checkout flavors differ only in what they promise about contents:
//!
//! * [`scratch_f32`] — length set, **fully zeroed** (for accumulators);
//! * [`scratch_undef`] — length set, contents unspecified (for buffers
//!   the callee fully overwrites before reading — decode targets,
//!   transposes, GEMM outputs that are `fill(0.0)`-ed internally);
//! * [`scratch_raw`] — length and contents untouched (for pack buffers
//!   that manage their own `len`-keyed geometry check);
//! * [`take_vec`]/[`give_vec`] — guard-free checkout for buffers whose
//!   ownership must move into another structure (the pipeline's ring
//!   slots), returned manually after the parallel region.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Total bytes of slab capacity ever allocated (or grown) across every
/// thread's arena, monotone. Stable counter ⇒ zero heap allocation.
static ALLOCATED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's free slabs, sorted ascending by capacity.
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    /// This thread's share of [`ALLOCATED`] (tests snapshot this one:
    /// unlike the global counter it cannot be moved by unrelated tests
    /// allocating on other threads).
    static THREAD_ALLOCATED: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Bytes of f32 slab capacity the arenas have allocated process-wide
/// (monotone; growth only).
pub fn allocated_bytes() -> usize {
    ALLOCATED.load(Ordering::Relaxed)
}

/// Bytes of slab capacity allocated (or grown) **by the calling thread's
/// arena** — the deterministic counter the zero-allocation regression
/// tests snapshot around steady-state decode loops.
pub fn thread_allocated_bytes() -> usize {
    THREAD_ALLOCATED.with(|c| c.get())
}

fn count_growth(bytes: usize) {
    if bytes > 0 {
        ALLOCATED.fetch_add(bytes, Ordering::Relaxed);
        let _ = THREAD_ALLOCATED.try_with(|c| c.set(c.get() + bytes));
    }
}

/// Best-fit checkout: the smallest free slab with `capacity >= hint`,
/// else a brand-new empty one. Deliberately **never grows an undersized
/// slab**: growing would remove a small slab from the pool and let the
/// same call sequence re-trigger growth on the next iteration — with
/// create-on-miss, one warmup pass leaves a slab per live size class and
/// the steady state is allocation-free.
fn checkout(hint: usize) -> (Vec<f32>, usize) {
    let buf = FREE
        .try_with(|f| {
            let mut free = f.borrow_mut();
            free.iter()
                .position(|b| b.capacity() >= hint)
                .map(|i| free.remove(i))
        })
        .ok()
        .flatten()
        .unwrap_or_default();
    let cap = buf.capacity();
    (buf, cap)
}

/// Return a slab, keeping the free list capacity-sorted and accounting
/// any growth that happened while it was checked out.
fn give_back(buf: Vec<f32>, cap_at_checkout: usize) {
    let grown = buf.capacity().saturating_sub(cap_at_checkout);
    count_growth(grown * std::mem::size_of::<f32>());
    // Ignore TLS teardown: losing a slab at thread exit is fine.
    let _ = FREE.try_with(|f| {
        let mut free = f.borrow_mut();
        let pos = free
            .iter()
            .position(|b| b.capacity() >= buf.capacity())
            .unwrap_or(free.len());
        free.insert(pos, buf);
    });
}

/// An exclusively-owned scratch slab; returns to this thread's arena on
/// drop. Derefs to `Vec<f32>` so existing `&mut Vec<f32>` plumbing (the
/// pack-buffer geometry checks) works unchanged.
pub struct Scratch {
    buf: Vec<f32>,
    cap_at_checkout: usize,
}

impl Deref for Scratch {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.buf
    }
}

impl DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        give_back(std::mem::take(&mut self.buf), self.cap_at_checkout);
    }
}

/// Checkout `len` f32s, **zero-filled** — drop-in for `vec![0.0; len]`.
pub fn scratch_f32(len: usize) -> Scratch {
    let (mut buf, cap) = checkout(len);
    buf.clear();
    buf.resize(len, 0.0);
    Scratch {
        buf,
        cap_at_checkout: cap,
    }
}

/// Checkout `len` f32s with **unspecified contents** (stale data from the
/// slab's previous user). Only for buffers the caller fully overwrites
/// before reading — skips the O(len) zeroing of [`scratch_f32`].
pub fn scratch_undef(len: usize) -> Scratch {
    let (mut buf, cap) = checkout(len);
    if buf.len() < len {
        buf.resize(len, 0.0);
    } else {
        buf.truncate(len);
    }
    Scratch {
        buf,
        cap_at_checkout: cap,
    }
}

/// Checkout a slab sized *near* `hint` with length and contents exactly as
/// its previous user left them — for pack buffers whose
/// `if buf.len() != needed` geometry check decides what to reinitialize.
pub fn scratch_raw(hint: usize) -> Scratch {
    let (buf, cap) = checkout(hint);
    Scratch {
        buf,
        cap_at_checkout: cap,
    }
}

/// Guard-free checkout of a `len`-long slab (contents unspecified): for
/// buffers whose ownership moves into another structure (pipeline ring
/// slots). Pair with [`give_vec`] after the region completes; on panic the
/// slab is simply freed (safe, just not reused).
pub fn take_vec(len: usize) -> Vec<f32> {
    let (mut buf, cap) = checkout(len);
    if buf.len() < len {
        buf.resize(len, 0.0);
    } else {
        buf.truncate(len);
    }
    let grown = buf.capacity().saturating_sub(cap);
    count_growth(grown * std::mem::size_of::<f32>());
    buf
}

/// Return a slab obtained from [`take_vec`] to this thread's arena. Any
/// thread may return it (slabs are not pinned); it joins the returning
/// thread's free list.
pub fn give_vec(buf: Vec<f32>) {
    let cap = buf.capacity();
    give_back(buf, cap);
}

#[cfg(test)]
mod tests {
    use super::*;

    // Arena tests share per-thread state with the rest of the suite, so
    // each runs on a dedicated thread for a deterministic free list.
    fn on_fresh_thread(f: impl FnOnce() + Send + 'static) {
        std::thread::spawn(f).join().unwrap();
    }

    #[test]
    fn checkout_is_zeroed_after_dirty_use() {
        on_fresh_thread(|| {
            {
                let mut s = scratch_f32(64);
                for v in s.iter_mut() {
                    *v = 7.0;
                }
            }
            let s = scratch_f32(64);
            assert!(s.iter().all(|&v| v == 0.0), "scratch_f32 must re-zero");
        });
    }

    #[test]
    fn reuse_does_not_grow() {
        on_fresh_thread(|| {
            {
                let _a = scratch_f32(1000);
                let _b = scratch_f32(10);
            }
            let before = thread_allocated_bytes();
            for _ in 0..50 {
                let _b = scratch_undef(10); // best-fit: the small slab
                let _a = scratch_f32(1000);
                let _r = scratch_raw(0);
            }
            assert_eq!(
                thread_allocated_bytes(),
                before,
                "steady-state checkouts must not allocate"
            );
        });
    }

    #[test]
    fn best_fit_prefers_smallest_adequate_slab() {
        on_fresh_thread(|| {
            {
                let _small = scratch_f32(16);
                let _big = scratch_f32(4096);
            }
            let before = thread_allocated_bytes();
            // Taking small then big in either order must reuse both slabs.
            {
                let _big = scratch_f32(4096);
                let _small = scratch_f32(16);
            }
            {
                let _small = scratch_f32(16);
                let _big = scratch_f32(4096);
            }
            assert_eq!(thread_allocated_bytes(), before);
        });
    }

    #[test]
    fn growth_is_counted_once() {
        on_fresh_thread(|| {
            let before = thread_allocated_bytes();
            drop(scratch_f32(100));
            let after_first = thread_allocated_bytes();
            assert!(after_first >= before + 400, "new slab must be counted");
            drop(scratch_f32(100));
            assert_eq!(thread_allocated_bytes(), after_first, "reuse must not count");
        });
    }

    #[test]
    fn take_give_roundtrip() {
        on_fresh_thread(|| {
            let v = take_vec(256);
            assert_eq!(v.len(), 256);
            give_vec(v);
            let before = thread_allocated_bytes();
            let v2 = take_vec(256);
            assert_eq!(thread_allocated_bytes(), before, "take_vec must reuse");
            give_vec(v2);
        });
    }

    #[test]
    fn undef_preserves_capacity_not_contents_contract() {
        on_fresh_thread(|| {
            {
                let mut s = scratch_undef(32);
                s.iter_mut().for_each(|v| *v = 3.0);
            }
            // Contents are unspecified — only the length is guaranteed.
            let s = scratch_undef(8);
            assert_eq!(s.len(), 8);
        });
    }
}
