//! Leveled logger wired into the `log` facade, with a swappable sink.
//!
//! `log::set_boxed_logger` can only ever succeed once per process, so the
//! installed logger delegates every record to a process-global *sink*
//! that can be swapped at runtime: stderr in normal operation (level
//! filtered by `SALR_LOG`), or an in-memory capture buffer so tests can
//! assert on emitted events — in particular the span-close debug lines
//! the trace layer emits under the `salr::trace` target.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

/// Active level as a u8 (Level::Error=1 .. Level::Trace=5), swappable
/// without a lock on the `enabled` fast path.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// A handle to captured log lines (each rendered as `LEVEL target message`).
#[derive(Clone, Default)]
pub struct Capture {
    lines: Arc<Mutex<Vec<String>>>,
}

impl Capture {
    /// Snapshot of everything captured so far.
    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().unwrap().clone()
    }

    /// True if any captured line contains `needle`.
    pub fn contains(&self, needle: &str) -> bool {
        self.lines.lock().unwrap().iter().any(|l| l.contains(needle))
    }
}

enum Sink {
    Stderr,
    Capture(Capture),
}

static SINK: once_cell::sync::Lazy<Mutex<Sink>> =
    once_cell::sync::Lazy::new(|| Mutex::new(Sink::Stderr));

struct SalrLogger;

impl log::Log for SalrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() as u8 <= LEVEL.load(Ordering::Relaxed)
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        match &*SINK.lock().unwrap() {
            Sink::Stderr => eprintln!(
                "[{:>9.3}s {:<5} {}] {}",
                START.elapsed().as_secs_f64(),
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            ),
            Sink::Capture(cap) => cap.lines.lock().unwrap().push(format!(
                "{} {} {}",
                record.level(),
                record.target(),
                record.args()
            )),
        }
    }

    fn flush(&self) {}
}

fn level_from_env() -> Level {
    match std::env::var("SALR_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    }
}

/// Install the logger. Level comes from `SALR_LOG` (error..trace),
/// default info. Idempotent: the boxed logger installs once, later calls
/// only refresh the level from the environment.
pub fn init() {
    LEVEL.store(level_from_env() as u8, Ordering::Relaxed);
    let _ = log::set_boxed_logger(Box::new(SalrLogger));
    log::set_max_level(LevelFilter::Trace);
    once_cell::sync::Lazy::force(&START);
}

/// Override the active level filter (tests; `SALR_LOG` sets it at init).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Route all log output into an in-memory buffer and return the handle.
/// Installs the logger if needed and raises the level to `Debug` so the
/// trace layer's span lines are observable. Tests serialize around this
/// (the sink is process-global); call [`uncapture`] when done.
pub fn capture() -> Capture {
    init();
    set_level(Level::Debug);
    let cap = Capture::default();
    *SINK.lock().unwrap() = Sink::Capture(cap.clone());
    cap
}

/// Restore the stderr sink and the `SALR_LOG` level after a [`capture`].
pub fn uncapture() {
    *SINK.lock().unwrap() = Sink::Stderr;
    LEVEL.store(level_from_env() as u8, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_sees_trace_span_lines() {
        let cap = capture();
        crate::util::trace::set_enabled(true);
        let t0 = crate::util::trace::now_us();
        crate::util::trace::record_span_at(
            crate::util::trace::TraceKind::Heartbeat,
            987_654_301,
            t0,
            t0 + 3,
            2,
        );
        assert!(
            cap.contains("span heartbeat trace=987654301"),
            "span debug line not captured: {:?}",
            cap.lines()
        );
        uncapture();
    }
}
