//! Simple leveled stderr logger wired into the `log` facade.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:>9.3}s {:<5} {}] {}",
                START.elapsed().as_secs_f64(),
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger. Level comes from `SALR_LOG` (error..trace), default info.
pub fn init() {
    let level = match std::env::var("SALR_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
    log::set_max_level(LevelFilter::Trace);
    once_cell::sync::Lazy::force(&START);
}
