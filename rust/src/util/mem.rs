//! Process memory accounting from `/proc` (Linux), used by the Table-3
//! fine-tuning-memory experiment.

/// Current resident set size in bytes, or 0 if unavailable.
pub fn rss_bytes() -> u64 {
    read_statm().map(|(_, rss_pages)| rss_pages * page_size()).unwrap_or(0)
}

/// Peak resident set size in bytes (VmHWM), or 0 if unavailable.
pub fn peak_rss_bytes() -> u64 {
    let status = match std::fs::read_to_string("/proc/self/status") {
        Ok(s) => s,
        Err(_) => return 0,
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn read_statm() -> Option<(u64, u64)> {
    let s = std::fs::read_to_string("/proc/self/statm").ok()?;
    let mut it = s.split_whitespace();
    let size: u64 = it.next()?.parse().ok()?;
    let rss: u64 = it.next()?.parse().ok()?;
    Some((size, rss))
}

fn page_size() -> u64 {
    4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        // We're always on linux in this environment.
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn rss_grows_with_allocation() {
        let before = rss_bytes();
        let v = vec![1u8; 64 << 20];
        // Touch pages so they are actually resident.
        let sum: u64 = v.iter().step_by(4096).map(|&b| b as u64).sum();
        assert_eq!(sum, (64 << 20) / 4096);
        let after = rss_bytes();
        assert!(after > before, "rss before={before} after={after}");
    }
}
