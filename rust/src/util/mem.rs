//! Process memory accounting: `/proc` RSS (Linux, the Table-3
//! fine-tuning-memory experiment) plus exact resident-weight-byte
//! counters maintained by [`crate::model::WeightStore`].
//!
//! The counters split resident base-weight bytes by representation —
//! dense f32 vs compressed (bitmap / bitmap+NF4) — so tests can assert
//! the tentpole invariant directly: constructing an engine in a
//! compressed weight format must not leave any persistent dense f32
//! copy of Ŵ behind (`dense_weight_bytes()` delta stays 0).

use std::cell::Cell;

thread_local! {
    static DENSE_WEIGHT_BYTES: Cell<i64> = const { Cell::new(0) };
    static COMPRESSED_WEIGHT_BYTES: Cell<i64> = const { Cell::new(0) };
}

/// Net bytes of *dense f32* base-weight stores constructed (minus
/// dropped) **on this thread**. Per-thread, like
/// [`crate::util::arena::thread_allocated_bytes`], so test assertions
/// stay exact under parallel test execution: an engine built on the
/// calling thread registers all of its stores here.
pub fn dense_weight_bytes() -> i64 {
    DENSE_WEIGHT_BYTES.with(|c| c.get())
}

/// Net bytes of *compressed* base-weight stores (bitmap masks + value
/// payloads, NF4 codes + scales) constructed on this thread.
pub fn compressed_weight_bytes() -> i64 {
    COMPRESSED_WEIGHT_BYTES.with(|c| c.get())
}

/// Account `delta` resident dense-weight bytes (negative on drop).
/// Called by `WeightStore` constructors/Drop — not for general use.
pub fn track_dense_weight_bytes(delta: i64) {
    DENSE_WEIGHT_BYTES.with(|c| c.set(c.get() + delta));
}

/// Account `delta` resident compressed-weight bytes (negative on drop).
pub fn track_compressed_weight_bytes(delta: i64) {
    COMPRESSED_WEIGHT_BYTES.with(|c| c.set(c.get() + delta));
}

/// Current resident set size in bytes, or 0 if unavailable.
pub fn rss_bytes() -> u64 {
    read_statm().map(|(_, rss_pages)| rss_pages * page_size()).unwrap_or(0)
}

/// Peak resident set size in bytes (VmHWM), or 0 if unavailable.
pub fn peak_rss_bytes() -> u64 {
    let status = match std::fs::read_to_string("/proc/self/status") {
        Ok(s) => s,
        Err(_) => return 0,
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

fn read_statm() -> Option<(u64, u64)> {
    let s = std::fs::read_to_string("/proc/self/statm").ok()?;
    let mut it = s.split_whitespace();
    let size: u64 = it.next()?.parse().ok()?;
    let rss: u64 = it.next()?.parse().ok()?;
    Some((size, rss))
}

fn page_size() -> u64 {
    4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        // We're always on linux in this environment.
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn weight_counters_are_exact_per_thread() {
        let d0 = dense_weight_bytes();
        let c0 = compressed_weight_bytes();
        track_dense_weight_bytes(1024);
        track_compressed_weight_bytes(512);
        assert_eq!(dense_weight_bytes() - d0, 1024);
        assert_eq!(compressed_weight_bytes() - c0, 512);
        track_dense_weight_bytes(-1024);
        track_compressed_weight_bytes(-512);
        assert_eq!(dense_weight_bytes(), d0);
        assert_eq!(compressed_weight_bytes(), c0);
        // And another thread's counter is independent of ours.
        std::thread::spawn(|| {
            track_dense_weight_bytes(1 << 30);
            assert_eq!(dense_weight_bytes(), 1 << 30);
        })
        .join()
        .unwrap();
        assert_eq!(dense_weight_bytes(), d0);
    }

    #[test]
    fn rss_grows_with_allocation() {
        let before = rss_bytes();
        let v = vec![1u8; 64 << 20];
        // Touch pages so they are actually resident.
        let sum: u64 = v.iter().step_by(4096).map(|&b| b as u64).sum();
        assert_eq!(sum, (64 << 20) / 4096);
        let after = rss_bytes();
        assert!(after > before, "rss before={before} after={after}");
    }
}
