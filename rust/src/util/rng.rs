//! PCG64 (XSL-RR 128/64) pseudo-random number generator.
//!
//! The vendored crate set has no `rand`, so the crate carries its own small,
//! fast, reproducible generator. All stochastic components (weight init,
//! synthetic data, Monte-Carlo checks, property tests) draw from this so
//! experiments are seed-reproducible end to end.

/// A PCG64 XSL-RR generator: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (the stream id is derived from the seed as well).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (((seed as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng
            .state
            .wrapping_add(0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f ^ seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-layer / per-shard use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (uses two uniforms, discards the pair).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal `f32`.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with `N(0, sigma^2)` samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed; O(k) avg).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(1000, 50);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 50);
        let idx2 = r.sample_indices(10, 10);
        assert_eq!(idx2.len(), 10);
    }
}
