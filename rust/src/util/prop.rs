//! Tiny property-testing driver (the offline vendor set has no `proptest`).
//!
//! `check` runs a property over `cases` randomly generated inputs and, on
//! failure, greedily shrinks the failing input via a user-supplied shrinker
//! before panicking with the minimal counterexample it found.

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop {
            cases: std::env::var("SALR_PROP_CASES")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(64),
            seed: 0xC0FFEE,
        }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop {
            cases,
            ..Default::default()
        }
    }

    /// Check `property(gen(rng))` for `self.cases` random inputs.
    /// `property` returns `Err(reason)` on failure.
    pub fn check<T: std::fmt::Debug>(
        &self,
        name: &str,
        mut gen: impl FnMut(&mut Rng) -> T,
        mut property: impl FnMut(&T) -> Result<(), String>,
    ) {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let input = gen(&mut rng);
            if let Err(reason) = property(&input) {
                panic!(
                    "property '{name}' failed at case {case}/{}:\n  reason: {reason}\n  input: {input:?}",
                    self.cases
                );
            }
        }
    }

    /// Like `check`, but with a shrinker that proposes smaller variants.
    pub fn check_shrink<T: std::fmt::Debug + Clone>(
        &self,
        name: &str,
        mut gen: impl FnMut(&mut Rng) -> T,
        shrink: impl Fn(&T) -> Vec<T>,
        mut property: impl FnMut(&T) -> Result<(), String>,
    ) {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let input = gen(&mut rng);
            if let Err(first_reason) = property(&input) {
                // Greedy shrink: repeatedly take the first failing candidate.
                let mut best = input.clone();
                let mut reason = first_reason;
                'outer: for _round in 0..64 {
                    for cand in shrink(&best) {
                        if let Err(r) = property(&cand) {
                            best = cand;
                            reason = r;
                            continue 'outer;
                        }
                    }
                    break;
                }
                panic!(
                    "property '{name}' failed at case {case}/{} (shrunk):\n  reason: {reason}\n  input: {best:?}",
                    self.cases
                );
            }
        }
    }
}

/// Generate a random shape `(rows, cols)` within bounds, biased small.
pub fn gen_shape(rng: &mut Rng, max_dim: usize) -> (usize, usize) {
    let r = 1 + rng.below(max_dim);
    let c = 1 + rng.below(max_dim);
    (r, c)
}

/// Generate a random f32 matrix as a flat vec.
pub fn gen_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.normal_f32() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new(32).check(
            "reverse-reverse",
            |rng| (0..rng.below(20)).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn failing_property_shrinks() {
        Prop::new(64).check_shrink(
            "always-small",
            |rng| rng.below(1000),
            |&n| if n > 0 { vec![n / 2, n - 1] } else { vec![] },
            |&n| {
                if n < 10 {
                    Ok(())
                } else {
                    Err(format!("{n} >= 10"))
                }
            },
        );
    }
}
