//! End-to-end request tracing: per-thread lock-free span rings, a
//! propagated trace id, and Chrome `trace_event` export.
//!
//! Every tier of the serving path records typed span events — the
//! batcher's `admit`/`prefill_chunk`/`decode_step`/`spec_draft`/
//! `spec_verify`/`retire`, the router's `failover`/`heartbeat`, and the
//! kernel tier's `pack_b`/`gemm_call` — into a ring buffer owned by the
//! recording thread. A single [`enabled`] load guards every site, so the
//! disabled cost is one relaxed atomic read and the *hot path never
//! changes shape*: tracing reads clocks and writes to preallocated rings,
//! it never takes a lock, allocates (after a ring's one-time lazy
//! registration), or reorders work, which is why it cannot perturb the
//! byte-identity determinism invariant.
//!
//! Events carry two stamps: a monotonic microsecond clock (`t_start_us`,
//! for timelines and histograms) and a deterministic per-thread op
//! counter (`op`, mirroring the `util::fault` idiom) so two traces of the
//! same workload are diffable even though wall-clock stamps differ.
//!
//! The trace id is minted at the first tier that sees the request (the
//! router, or `serve` for direct submissions), travels on the wire as a
//! `"trace"` field — surviving the router's request re-keying — and flows
//! to worker and pool threads through a thread-local context
//! ([`with_trace`]), which is how a `pack_b` span recorded on a GEMM pool
//! thread stitches to the request that triggered it. Batched decode steps
//! run under trace id 0 (a step belongs to every ready sequence); the
//! batcher records one `decode_step` span per ready sequence instead.
//!
//! Rings are fixed-capacity (`SALR_TRACE_RING`, default 4096 events) and
//! overwrite oldest-first; the number of overwritten events is reported
//! as `trace_dropped`. Readers use a seqlock per slot: a torn read (slot
//! mid-rewrite) is skipped, never blocked on.

use std::cell::{Cell, OnceCell, UnsafeCell};
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once};
use std::time::Instant;

use crate::util::json::Json;

/// The span taxonomy. One variant per traced operation; the numeric value
/// indexes the per-kind aggregate table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceKind {
    /// Request accepted into a worker's decode batch (serve) or routed to
    /// a backend (router). `arg` = prompt tokens (serve) / backend index
    /// (router).
    Admit = 0,
    /// One chunked-prefill slice of a prompt. `arg` = chunk tokens.
    PrefillChunk = 1,
    /// One decode iteration, recorded per ready sequence. `arg` = batch
    /// occupancy for that step.
    DecodeStep = 2,
    /// Draft-token proposal for one sequence. `arg` = drafted tokens.
    SpecDraft = 3,
    /// Batched verify forward for one sequence. `arg` = accepted tokens.
    SpecVerify = 4,
    /// Request retired (final reply fired). `arg` = generated tokens.
    Retire = 5,
    /// Router re-dispatched a request to a new backend before its first
    /// token. `arg` = the replacement backend index.
    Failover = 6,
    /// One router heartbeat probe round. `arg` = healthy backend count.
    Heartbeat = 7,
    /// One B-panel pack (dense copy or fused bitmap/NF4 decode) inside
    /// the blocked GEMM. `arg` = packed `kb * nb` element count.
    PackB = 8,
    /// One GEMM entry call. `arg` = `m * n * k` MAC count.
    GemmCall = 9,
}

/// Number of span kinds (size of the aggregate table).
pub const NKINDS: usize = 10;

impl TraceKind {
    /// Wire/JSON name of the kind.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceKind::Admit => "admit",
            TraceKind::PrefillChunk => "prefill_chunk",
            TraceKind::DecodeStep => "decode_step",
            TraceKind::SpecDraft => "spec_draft",
            TraceKind::SpecVerify => "spec_verify",
            TraceKind::Retire => "retire",
            TraceKind::Failover => "failover",
            TraceKind::Heartbeat => "heartbeat",
            TraceKind::PackB => "pack_b",
            TraceKind::GemmCall => "gemm_call",
        }
    }

    /// All kinds, in aggregate-table order.
    pub const ALL: [TraceKind; NKINDS] = [
        TraceKind::Admit,
        TraceKind::PrefillChunk,
        TraceKind::DecodeStep,
        TraceKind::SpecDraft,
        TraceKind::SpecVerify,
        TraceKind::Retire,
        TraceKind::Failover,
        TraceKind::Heartbeat,
        TraceKind::PackB,
        TraceKind::GemmCall,
    ];
}

/// One recorded span. Fixed-size and `Copy` so ring slots never allocate.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    /// The request's trace id (0 = process-level, not tied to a request).
    pub trace_id: u64,
    /// What happened.
    pub kind: TraceKind,
    /// Start stamp, microseconds on the process-monotonic trace clock.
    pub t_start_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
    /// Deterministic per-thread op counter at record time.
    pub op: u64,
    /// Kind-specific payload (see [`TraceKind`]).
    pub arg: u64,
}

const BLANK: SpanEvent = SpanEvent {
    trace_id: 0,
    kind: TraceKind::Admit,
    t_start_us: 0,
    dur_us: 0,
    op: 0,
    arg: 0,
};

/// One ring slot: a seqlock sequence word plus the event payload. The
/// sequence is odd while the owning thread rewrites the slot; readers
/// skip slots whose sequence is odd or changes across the read.
struct Slot {
    seq: AtomicU64,
    ev: UnsafeCell<SpanEvent>,
}

// SAFETY: `ev` is only written by the ring's owning thread under the
// odd/even seqlock protocol; concurrent readers detect torn reads via
// `seq` and discard them.
unsafe impl Sync for Slot {}

/// A single-producer span ring. The owning thread is the only writer
/// ([`Ring::push`]); any thread may snapshot it. Capacity is fixed at
/// construction — recording never allocates.
pub struct Ring {
    name: String,
    slots: Box<[Slot]>,
    /// Total events ever pushed (monotonic; `widx - capacity` of the
    /// oldest retained event's index once wrapped).
    widx: AtomicU64,
    /// Deterministic op counter for this thread's spans.
    ops: AtomicU64,
}

impl Ring {
    /// A ring with `capacity` preallocated slots, labelled `name` (the
    /// lane name in exported traces).
    pub fn new(name: &str, capacity: usize) -> Ring {
        let cap = capacity.max(2);
        Ring {
            name: name.to_string(),
            slots: (0..cap)
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    ev: UnsafeCell::new(BLANK),
                })
                .collect(),
            widx: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        }
    }

    /// Lane name (the owning thread's name at registration).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Events overwritten so far (oldest-first once the ring wraps).
    pub fn dropped(&self) -> u64 {
        self.widx
            .load(Ordering::Relaxed)
            .saturating_sub(self.slots.len() as u64)
    }

    /// Next deterministic op stamp. Only the owning thread calls this.
    pub fn next_op(&self) -> u64 {
        self.ops.fetch_add(1, Ordering::Relaxed)
    }

    /// Append an event, overwriting the oldest once full. MUST only be
    /// called by the ring's owning thread (single producer).
    pub fn push(&self, ev: SpanEvent) {
        let w = self.widx.load(Ordering::Relaxed);
        let slot = &self.slots[(w % self.slots.len() as u64) as usize];
        let s = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(s + 1, Ordering::Relaxed); // odd: mid-write
        fence(Ordering::Release);
        // SAFETY: single producer (owning thread); readers discard torn
        // reads via the seqlock.
        unsafe { *slot.ev.get() = ev };
        slot.seq.store(s + 2, Ordering::Release);
        self.widx.store(w + 1, Ordering::Release);
    }

    /// Snapshot the retained events, oldest first. Slots caught
    /// mid-rewrite are skipped (bounded staleness, never a block).
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let w = self.widx.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let lo = w.saturating_sub(cap);
        let mut out = Vec::with_capacity((w - lo) as usize);
        for i in lo..w {
            let slot = &self.slots[(i % cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                continue;
            }
            // SAFETY: a torn read is detected by the seq re-check below
            // and discarded; read_volatile keeps the compiler from
            // caching across the fence.
            let ev = unsafe { std::ptr::read_volatile(slot.ev.get()) };
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == s1 {
                out.push(ev);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Global state: enablement, clock, registry, per-kind aggregates.

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();
static EPOCH: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);
static REGISTRY: once_cell::sync::Lazy<Mutex<Vec<std::sync::Arc<Ring>>>> =
    once_cell::sync::Lazy::new(|| Mutex::new(Vec::new()));
static LANE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-kind running totals (count + total duration), powering the
/// per-stage section of the `{"cmd":"metrics"}` reply without a ring walk.
struct KindAgg {
    count: AtomicU64,
    total_us: AtomicU64,
}
const AGG_ZERO: KindAgg = KindAgg {
    count: AtomicU64::new(0),
    total_us: AtomicU64::new(0),
};
static AGG: [KindAgg; NKINDS] = [AGG_ZERO; NKINDS];

thread_local! {
    /// The request trace id active on this thread (0 = none).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
    /// This thread's span ring, registered on first record.
    static RING: OnceCell<std::sync::Arc<Ring>> = const { OnceCell::new() };
}

/// Is tracing on? One relaxed load — the whole cost of a disabled span
/// site. `#[inline]` so call sites reduce to a load + branch.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off programmatically (tests, `--trace-out`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Enable tracing if `SALR_TRACE` is truthy. Idempotent; never *disables*
/// (so a programmatic enable survives).
pub fn init_from_env() {
    ENV_INIT.call_once(|| {
        if std::env::var("SALR_TRACE").is_ok_and(|v| crate::util::truthy(&v)) {
            ENABLED.store(true, Ordering::Relaxed);
        }
    });
}

/// Microseconds on the process-monotonic trace clock.
#[inline]
pub fn now_us() -> u64 {
    EPOCH.elapsed().as_micros() as u64
}

/// The trace id active on this thread (0 = none).
#[inline]
pub fn current_trace() -> u64 {
    CURRENT.with(|c| c.get())
}

/// Run `f` with `id` as the thread's active trace id, restoring the
/// previous id after — the propagation hop that carries a request's id
/// into engine calls and GEMM pool closures.
pub fn with_trace<R>(id: u64, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT.with(|c| c.replace(id));
    let out = f();
    CURRENT.with(|c| c.set(prev));
    out
}

fn ring_capacity() -> usize {
    static CAP: once_cell::sync::Lazy<usize> = once_cell::sync::Lazy::new(|| {
        std::env::var("SALR_TRACE_RING")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(4096)
            .max(2)
    });
    *CAP
}

fn with_ring<R>(f: impl FnOnce(&Ring) -> R) -> R {
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let lane = LANE_SEQ.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("lane-{lane}"));
            let ring = std::sync::Arc::new(Ring::new(&name, ring_capacity()));
            REGISTRY.lock().unwrap().push(ring.clone());
            ring
        });
        f(ring)
    })
}

/// Record a span that started at `start_us` and ends now.
#[inline]
pub fn record_span(kind: TraceKind, trace_id: u64, start_us: u64, arg: u64) {
    record_span_at(kind, trace_id, start_us, now_us(), arg);
}

/// Record a span with an explicit end stamp (the batcher records one
/// `decode_step` span per ready sequence over the same measured interval).
/// No-op when tracing is disabled.
pub fn record_span_at(kind: TraceKind, trace_id: u64, start_us: u64, end_us: u64, arg: u64) {
    if !enabled() {
        return;
    }
    let dur_us = end_us.saturating_sub(start_us);
    with_ring(|ring| {
        let op = ring.next_op();
        ring.push(SpanEvent {
            trace_id,
            kind,
            t_start_us: start_us,
            dur_us,
            op,
            arg,
        });
        // Span-close debug line through the `log` facade, so tests (and
        // SALR_LOG=debug operators) can observe emitted events. Gated on
        // the level check: the formatting allocation only happens when a
        // debug sink is actually listening.
        if log::log_enabled!(target: "salr::trace", log::Level::Debug) {
            log::debug!(
                target: "salr::trace",
                "span {} trace={} op={} dur_us={} arg={}",
                kind.as_str(),
                trace_id,
                op,
                dur_us,
                arg
            );
        }
    });
    let agg = &AGG[kind as usize];
    agg.count.fetch_add(1, Ordering::Relaxed);
    agg.total_us.fetch_add(dur_us, Ordering::Relaxed);
}

/// Total spans overwritten (dropped oldest-first) across all rings.
pub fn dropped() -> u64 {
    REGISTRY.lock().unwrap().iter().map(|r| r.dropped()).sum()
}

/// Per-kind `{count, total_us}` aggregates as a JSON object — the
/// `"stages"` section of the extended metrics reply.
pub fn kind_totals_json() -> Json {
    let mut obj = Json::obj();
    for k in TraceKind::ALL {
        let agg = &AGG[k as usize];
        let count = agg.count.load(Ordering::Relaxed);
        if count > 0 {
            obj = obj.set(
                k.as_str(),
                Json::obj()
                    .set("count", count as f64)
                    .set("total_us", agg.total_us.load(Ordering::Relaxed) as f64),
            );
        }
    }
    obj
}

/// Snapshot every ring: `(lane_name, events_oldest_first)`.
pub fn snapshot_all() -> Vec<(String, Vec<SpanEvent>)> {
    let rings: Vec<std::sync::Arc<Ring>> = REGISTRY.lock().unwrap().clone();
    rings
        .iter()
        .map(|r| (r.name().to_string(), r.snapshot()))
        .collect()
}

/// All retained spans for one trace id, as `(lane, event)` sorted by
/// start stamp.
pub fn spans_for(trace_id: u64) -> Vec<(String, SpanEvent)> {
    let mut out: Vec<(String, SpanEvent)> = Vec::new();
    for (lane, evs) in snapshot_all() {
        for ev in evs {
            if ev.trace_id == trace_id {
                out.push((lane.clone(), ev));
            }
        }
    }
    out.sort_by_key(|(_, ev)| (ev.t_start_us, u64::MAX - ev.dur_us));
    out
}

fn span_json(lane: &str, proc_name: &str, ev: &SpanEvent, children: Vec<Json>) -> Json {
    Json::obj()
        .set("kind", ev.kind.as_str())
        .set("lane", lane)
        .set("proc", proc_name)
        .set("t_start_us", ev.t_start_us as f64)
        .set("dur_us", ev.dur_us as f64)
        .set("op", ev.op as f64)
        .set("arg", ev.arg as f64)
        .set("children", Json::Arr(children))
}

/// The span tree for one trace id: spans nested by interval containment
/// (a kernel `pack_b` span sits under the `prefill_chunk` that ran it),
/// roots in start order. `proc_name` tags every span with the process
/// tier that recorded it ("serve" / "router") so a router-merged tree
/// keeps its provenance.
pub fn span_tree_json(trace_id: u64, proc_name: &str) -> Json {
    let spans = spans_for(trace_id);
    // Nodes are built bottom-up with an interval-containment stack:
    // spans arrive sorted by (start asc, dur desc), so a span's parent
    // is the nearest stack entry whose interval still contains it.
    struct Node {
        lane: String,
        ev: SpanEvent,
        children: Vec<Node>,
    }
    fn to_json(n: &Node, proc_name: &str) -> Json {
        let kids = n.children.iter().map(|c| to_json(c, proc_name)).collect();
        span_json(&n.lane, proc_name, &n.ev, kids)
    }
    fn count_nodes(n: &Node) -> usize {
        1 + n.children.iter().map(count_nodes).sum::<usize>()
    }
    let mut roots: Vec<Node> = Vec::new();
    let mut stack: Vec<Node> = Vec::new();
    let end = |n: &Node| n.ev.t_start_us + n.ev.dur_us;
    for (lane, ev) in spans {
        let node = Node {
            lane,
            ev,
            children: Vec::new(),
        };
        while let Some(top) = stack.last() {
            let contains = top.ev.t_start_us <= node.ev.t_start_us && end(top) >= end(&node);
            if contains {
                break;
            }
            let done = stack.pop().unwrap();
            match stack.last_mut() {
                Some(parent) => parent.children.push(done),
                None => roots.push(done),
            }
        }
        stack.push(node);
    }
    while let Some(done) = stack.pop() {
        match stack.last_mut() {
            Some(parent) => parent.children.push(done),
            None => roots.push(done),
        }
    }
    Json::obj()
        .set("id", trace_id as f64)
        .set("count", roots.iter().map(count_nodes).sum::<usize>() as f64)
        .set(
            "tree",
            Json::Arr(roots.iter().map(|n| to_json(n, proc_name)).collect()),
        )
}

/// Chrome `trace_event` JSON for every retained span: one `ph:"X"`
/// complete event per span (ts/dur in microseconds, as the format wants)
/// plus `ph:"M"` thread-name metadata per lane, wrapped in the
/// `{"traceEvents":[...]}` object form `chrome://tracing` and Perfetto
/// accept.
pub fn chrome_trace_json(proc_name: &str) -> String {
    let mut events: Vec<Json> = Vec::new();
    for (tid, (lane, evs)) in snapshot_all().into_iter().enumerate() {
        events.push(
            Json::obj()
                .set("name", "thread_name")
                .set("ph", "M")
                .set("pid", 0.0)
                .set("tid", tid as f64)
                .set("args", Json::obj().set("name", lane.as_str())),
        );
        for ev in evs {
            events.push(
                Json::obj()
                    .set("name", ev.kind.as_str())
                    .set("cat", proc_name)
                    .set("ph", "X")
                    .set("ts", ev.t_start_us as f64)
                    .set("dur", ev.dur_us as f64)
                    .set("pid", 0.0)
                    .set("tid", tid as f64)
                    .set(
                        "args",
                        Json::obj()
                            .set("trace", ev.trace_id as f64)
                            .set("op", ev.op as f64)
                            .set("arg", ev.arg as f64),
                    ),
            );
        }
    }
    Json::obj()
        .set("traceEvents", Json::Arr(events))
        .to_string_compact()
}

/// Dump [`chrome_trace_json`] to `path` (the `--trace-out` sink, called
/// at drain/shutdown).
pub fn write_chrome_trace(path: &str, proc_name: &str) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(proc_name))
}

static TRACE_OUT: once_cell::sync::Lazy<Mutex<Option<String>>> =
    once_cell::sync::Lazy::new(|| Mutex::new(None));

/// Arm `--trace-out`: enables tracing and remembers `path` so the serving
/// tier can dump the Chrome trace at drain/shutdown ([`dump_trace_out`]).
/// Process-global because `BatchPolicy`/`RouterPolicy` are `Copy` structs
/// and cannot carry the path.
pub fn set_trace_out(path: &str) {
    set_enabled(true);
    *TRACE_OUT.lock().unwrap() = Some(path.to_string());
}

/// Write the Chrome trace to the armed `--trace-out` path, if any.
/// Idempotent-safe to call from every tier's shutdown tail: the dump
/// re-runs (later snapshots strictly extend earlier ones), a missing
/// path is a no-op, and an I/O failure is logged, never fatal.
pub fn dump_trace_out(proc_name: &str) {
    let path = TRACE_OUT.lock().unwrap().clone();
    if let Some(path) = path {
        match write_chrome_trace(&path, proc_name) {
            Ok(()) => log::info!("wrote chrome trace to {path}"),
            Err(e) => log::warn!("failed writing chrome trace to {path}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_dropping_oldest_and_counts_dropped() {
        let r = Ring::new("test", 4);
        for i in 0..7u64 {
            r.push(SpanEvent {
                trace_id: i,
                kind: TraceKind::DecodeStep,
                t_start_us: i,
                dur_us: 1,
                op: r.next_op(),
                arg: 0,
            });
        }
        assert_eq!(r.dropped(), 3);
        let snap = r.snapshot();
        // Oldest three (0,1,2) overwritten; 3..7 retained in order.
        assert_eq!(snap.len(), 4);
        let ids: Vec<u64> = snap.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![3, 4, 5, 6]);
        // Op stamps are the deterministic push order.
        let ops: Vec<u64> = snap.iter().map(|e| e.op).collect();
        assert_eq!(ops, vec![3, 4, 5, 6]);
    }

    #[test]
    fn ring_snapshot_below_capacity() {
        let r = Ring::new("test", 8);
        assert!(r.snapshot().is_empty());
        assert_eq!(r.dropped(), 0);
        r.push(SpanEvent {
            trace_id: 42,
            kind: TraceKind::Admit,
            t_start_us: 5,
            dur_us: 2,
            op: 0,
            arg: 9,
        });
        let snap = r.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].trace_id, 42);
        assert_eq!(snap[0].arg, 9);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn with_trace_scopes_and_restores() {
        assert_eq!(current_trace(), 0);
        let inner = with_trace(7, || {
            let mid = current_trace();
            let nested = with_trace(9, current_trace);
            (mid, nested, current_trace())
        });
        assert_eq!(inner, (7, 9, 7));
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn record_and_query_span_tree() {
        set_enabled(true);
        // Unique trace id so parallel tests in this binary can't collide.
        let tid = 0xA11CE_0001;
        let t0 = now_us();
        // Outer span [t0, t0+100], child [t0+10, t0+40], sibling after.
        record_span_at(TraceKind::PrefillChunk, tid, t0, t0 + 100, 3);
        record_span_at(TraceKind::PackB, tid, t0 + 10, t0 + 40, 64);
        record_span_at(TraceKind::Retire, tid, t0 + 200, t0 + 210, 1);
        let tree = span_tree_json(tid, "serve");
        assert_eq!(tree.get("count").unwrap().as_f64().unwrap(), 3.0);
        let roots = tree.get("tree").unwrap().as_arr().unwrap();
        assert_eq!(roots.len(), 2);
        assert_eq!(roots[0].get("kind").unwrap().as_str().unwrap(), "prefill_chunk");
        let kids = roots[0].get("children").unwrap().as_arr().unwrap();
        assert_eq!(kids.len(), 1);
        assert_eq!(kids[0].get("kind").unwrap().as_str().unwrap(), "pack_b");
        assert_eq!(roots[1].get("kind").unwrap().as_str().unwrap(), "retire");
        assert_eq!(roots[1].get("proc").unwrap().as_str().unwrap(), "serve");
    }

    #[test]
    fn chrome_export_is_valid_json_with_thread_metadata() {
        set_enabled(true);
        let tid = 0xA11CE_0002;
        record_span_at(TraceKind::GemmCall, tid, now_us(), now_us() + 5, 4096);
        let text = chrome_trace_json("serve");
        let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events
            .iter()
            .any(|e| e.get("ph").map(|p| p.as_str()) == Some(Some("M"))));
        let ours = events
            .iter()
            .find(|e| {
                e.at(&["args", "trace"]).and_then(Json::as_f64) == Some(tid as f64)
            })
            .expect("our span exported");
        assert_eq!(ours.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ours.get("name").unwrap().as_str().unwrap(), "gemm_call");
        assert!(ours.get("ts").is_some() && ours.get("dur").is_some());
    }

    #[test]
    fn disabled_record_is_a_noop() {
        // Never *disable* globally (parallel tests): use a raw ring-free
        // check instead — record under a unique id while toggling through
        // the public API would race other tests, so assert the guard
        // logic directly.
        let tid = 0xA11CE_0003;
        if !enabled() {
            record_span_at(TraceKind::Admit, tid, 0, 10, 0);
            assert!(spans_for(tid).is_empty());
        }
        set_enabled(true);
        record_span_at(TraceKind::Admit, tid, 0, 10, 0);
        assert_eq!(spans_for(tid).len(), 1);
    }
}
