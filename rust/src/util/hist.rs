//! Fixed-bucket log2 latency histograms: lock-free, allocation-free at
//! record time, mergeable across workers.
//!
//! `Hist` replaces the old `Mutex<Vec<u64>>` latency path in
//! `ServerMetrics`: the heartbeat thread probes `{"cmd":"metrics"}` every
//! `--heartbeat-ms`, and snapshotting a mutex-guarded growing vector on
//! that cadence both contends with the retire path and allocates per
//! probe. A histogram record is two relaxed `fetch_add`s on preallocated
//! atomics; a snapshot is 66 relaxed loads. The price is resolution:
//! values are bucketed by bit length (power-of-two boundaries), so a
//! reported percentile is the *upper bound* of the bucket the true
//! percentile falls in — at most 2x the true value, which is the right
//! trade for latency telemetry (we care about orders of magnitude and
//! tail shape, not microsecond exactness).
//!
//! Bucket `0` holds exactly the value `0`; bucket `i >= 1` holds values
//! `v` with `2^(i-1) <= v < 2^i` (i.e. bit length `i`), saturating at the
//! last bucket. With 64 buckets a `u64` of microseconds can never
//! overflow the range.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Number of buckets: one zero bucket + one per possible `u64` bit length.
pub const NBUCKETS: usize = 64;

/// A mergeable log2 histogram of `u64` samples (microseconds by
/// convention in the serving tier). All operations are lock-free; `record`
/// never allocates.
pub struct Hist {
    buckets: [AtomicU64; NBUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Hist {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index for a sample: 0 for 0, else the bit length of `v`
    /// capped to the last bucket.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(NBUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (the largest sample it holds).
    pub fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one sample. Lock-free, allocation-free, wait-free.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples (same unit as the samples).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean sample, or 0.0 when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Fold another histogram's counts into this one (both keep serving
    /// concurrent records; the merge is a relaxed read-add per bucket).
    pub fn merge_from(&self, other: &Hist) {
        for i in 0..NBUCKETS {
            let c = other.buckets[i].load(Ordering::Relaxed);
            if c > 0 {
                self.buckets[i].fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// The `p`-quantile (`0.0 < p <= 1.0`) as the upper bound of the
    /// bucket the quantile sample falls in. Empty histograms report 0.0.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        // Rank of the quantile sample, 1-based, clamped into [1, n] so
        // p=1.0 lands exactly on the max sample's bucket.
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for i in 0..NBUCKETS {
            seen += self.buckets[i].load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_upper(i) as f64;
            }
        }
        Self::bucket_upper(NBUCKETS - 1) as f64
    }

    /// Snapshot of the raw bucket counts (for tests and merges).
    pub fn snapshot(&self) -> [u64; NBUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Compact JSON for the metrics reply: count, sum, mean and the
    /// populated buckets as `[upper_bound, count]` pairs (empty buckets
    /// are elided so the reply stays small).
    pub fn to_json(&self) -> Json {
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                buckets.push(Json::Arr(vec![
                    Json::Num(Self::bucket_upper(i) as f64),
                    Json::Num(c as f64),
                ]));
            }
        }
        Json::obj()
            .set("count", self.count() as f64)
            .set("sum_us", self.sum() as f64)
            .set("mean_us", self.mean())
            .set("p50_us", self.percentile(0.50))
            .set("p90_us", self.percentile(0.90))
            .set("p99_us", self.percentile(0.99))
            .set("buckets", Json::Arr(buckets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        // 0 is its own bucket; powers of two open a new bucket.
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(2), 2);
        assert_eq!(Hist::bucket_of(3), 2);
        assert_eq!(Hist::bucket_of(4), 3);
        assert_eq!(Hist::bucket_of(7), 3);
        assert_eq!(Hist::bucket_of(8), 4);
        assert_eq!(Hist::bucket_of(u64::MAX), NBUCKETS - 1);
        // Upper bounds are inclusive maxima of their buckets.
        assert_eq!(Hist::bucket_upper(0), 0);
        assert_eq!(Hist::bucket_upper(1), 1);
        assert_eq!(Hist::bucket_upper(2), 3);
        assert_eq!(Hist::bucket_upper(3), 7);
        for v in [0u64, 1, 2, 3, 4, 5, 100, 1 << 20, u64::MAX - 1] {
            let b = Hist::bucket_of(v);
            assert!(v <= Hist::bucket_upper(b), "v={v} above its bucket cap");
            if b > 0 {
                assert!(v > Hist::bucket_upper(b - 1), "v={v} fits a lower bucket");
            }
        }
    }

    #[test]
    fn empty_percentile_is_zero() {
        let h = Hist::new();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn percentiles_hit_bucket_upper_bounds() {
        let h = Hist::new();
        // 90 samples of 10us (bucket 4, upper 15), 10 samples of 1000us
        // (bucket 10, upper 1023).
        for _ in 0..90 {
            h.record(10);
        }
        for _ in 0..10 {
            h.record(1000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.percentile(0.50), 15.0);
        assert_eq!(h.percentile(0.90), 15.0);
        assert_eq!(h.percentile(0.91), 1023.0);
        assert_eq!(h.percentile(0.99), 1023.0);
        assert_eq!(h.percentile(1.0), 1023.0);
        assert_eq!(h.sum(), 90 * 10 + 10 * 1000);
    }

    #[test]
    fn merge_adds_counts() {
        let a = Hist::new();
        let b = Hist::new();
        a.record(0);
        a.record(5);
        b.record(5);
        b.record(1 << 30);
        a.merge_from(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.sum(), 5 + 5 + (1 << 30));
        let snap = a.snapshot();
        assert_eq!(snap[0], 1); // the zero
        assert_eq!(snap[Hist::bucket_of(5)], 2);
        assert_eq!(snap[Hist::bucket_of(1 << 30)], 1);
        // Merging an empty histogram is a no-op.
        a.merge_from(&Hist::new());
        assert_eq!(a.count(), 4);
    }

    #[test]
    fn json_elides_empty_buckets() {
        let h = Hist::new();
        h.record(3);
        h.record(3);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_f64().unwrap(), 2.0);
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
        assert_eq!(buckets[0].as_arr().unwrap()[1].as_f64().unwrap(), 2.0);
    }
}
