//! Minimal JSON value type, parser and pretty-printer.
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, for
//! experiment result files under `results/`, and for the serving wire
//! protocol. The offline vendor set has no `serde`/`serde_json`, so this is
//! a from-scratch, spec-conformant-enough implementation (no surrogate-pair
//! edge cases beyond what the manifest needs; numbers are f64).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object). Builder-style.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["config", "d_model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    x.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj()
            .set("name", "salr")
            .set("rank", 64usize)
            .set("sparsity", 0.5)
            .set("ok", true)
            .set("arr", vec![1i64, 2, 3]);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": {"b": [1, 2.5, "x", null, false]}}"#).unwrap();
        assert_eq!(j.at(&["a", "b"]).unwrap().as_arr().unwrap().len(), 5);
        assert_eq!(
            j.at(&["a", "b"]).unwrap().as_arr().unwrap()[1].as_f64(),
            Some(2.5)
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\tπ".to_string());
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
        let parsed = Json::parse(r#""éA""#).unwrap();
        assert_eq!(parsed.as_str(), Some("éA"));
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = Json::parse("[-1.5e3, 0.25, -7]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[1].as_f64(), Some(0.25));
        assert_eq!(a[2].as_f64(), Some(-7.0));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}
