//! Deterministic fault injection for the serving tier.
//!
//! A fault plan arms **one** injected failure, described by a spec string
//! (the `SALR_FAULT` environment variable, or [`FaultPlan::parse`] in
//! tests):
//!
//! ```text
//! <kind>:<key>=<val>[,<key>=<val>...]
//! ```
//!
//! | clause          | meaning                                                    |
//! |-----------------|------------------------------------------------------------|
//! | `panic:`        | panic the worker thread when the trigger fires (exercises the supervisor) |
//! | `delay:`        | stall the worker thread when the trigger fires             |
//! | `conn_drop:`    | sever the router↔backend connection when the trigger fires (exercises reconnect + failover) |
//! | `reply_delay:`  | stall a backend reply frame in the router's pump thread    |
//! | `backend_down:` | take the backend down permanently — sever and stop all reconnects (exercises mid-stream loss + redistribution) |
//! | `decode_step=N` | trigger before a worker's `N`-th decode step (1-based)     |
//! | `prefill=N`     | trigger before a worker's `N`-th prefill chunk (1-based)   |
//! | `verify_step=N` | trigger before a worker's `N`-th speculative verify (1-based) |
//! | `fwd=N`         | trigger before the router's `N`-th request forward to a backend (1-based) |
//! | `reply=N`       | trigger before the router delivers a backend's `N`-th data frame (1-based) |
//! | `worker=N`      | only engine worker `N` may fire the fault (default: any)   |
//! | `backend=N`     | only backend `N` may fire the fault (router synonym for `worker=`) |
//! | `ms=N`          | stall duration for `delay`/`reply_delay` faults (default 25 ms) |
//!
//! Examples: `panic:worker=1,decode_step=37` panics engine worker 1
//! immediately before its 37th decode step; `delay:prefill=3` stalls
//! whichever worker first reaches its third prefill chunk;
//! `backend_down:backend=1,fwd=2` takes router backend 1 down permanently
//! just before the router forwards its 2nd request to it.
//!
//! Kinds and triggers come in two classes that must match: the **engine**
//! kinds (`panic`, `delay`) pair with the engine-worker triggers
//! (`decode_step`, `prefill`, `verify_step`), and the **network** kinds
//! (`conn_drop`, `reply_delay`, `backend_down`) pair with the router
//! triggers (`fwd`, `reply`). A cross-class spec is rejected at parse
//! time — a network fault keyed on an engine op would never fire and a
//! CI leg armed with it would silently test nothing.
//!
//! Triggers are keyed on **op counters** — each worker's count of decode
//! steps / prefill chunks, each backend's count of forwards / reply
//! frames — never on wall-clock time, so every injected failure lands at
//! the same scheduler boundary on every run: the same determinism
//! discipline the kernel and cache layers follow. A plan is
//! **one-shot**: it fires exactly once per process, then disarms, so a
//! worker respawned by the supervisor does not immediately re-fault.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The scheduler operation a fault trigger counts.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FaultOp {
    /// One `Engine::decode_step` call in an engine-worker loop.
    DecodeStep,
    /// One `Engine::prefill_chunk` call in an engine-worker loop.
    PrefillChunk,
    /// One speculative draft+verify for one sequence in an engine-worker
    /// loop. The trigger sits between the draft and the verify forward
    /// (`Engine::decode_verify`): the drafter has run — self-drafting
    /// has appended and rolled back its base-only KV rows — but nothing
    /// is verified yet, the worst spot for speculative KV accounting,
    /// which is exactly why it is a fault point.
    VerifyStep,
    /// One request forward from the router to a backend. The trigger
    /// sits after the routing decision (the counters are bumped, the
    /// request is in the router's inflight table) but before the line
    /// is written to the backend socket — the spot where a send-side
    /// connection loss must trip pre-first-token failover.
    RouterFwd,
    /// One data frame (stream delta or final reply) arriving from a
    /// backend, counted in the router's per-backend pump thread before
    /// the frame is delivered to the client. `conn_drop`/`backend_down`
    /// here model a backend dying *mid-stream*, after bytes have been
    /// promised to the client — the case that must surface
    /// `error: "backend lost"` rather than a silent retry.
    RouterReply,
}

impl FaultOp {
    fn name(self) -> &'static str {
        match self {
            FaultOp::DecodeStep => "decode_step",
            FaultOp::PrefillChunk => "prefill",
            FaultOp::VerifyStep => "verify_step",
            FaultOp::RouterFwd => "fwd",
            FaultOp::RouterReply => "reply",
        }
    }

    /// Network-class ops are counted by the router per backend; engine
    /// ops are counted by the batcher per worker.
    fn is_network(self) -> bool {
        matches!(self, FaultOp::RouterFwd | FaultOp::RouterReply)
    }
}

/// What an armed fault does when its trigger fires.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic the calling worker thread with this message.
    Panic(String),
    /// Stall the calling thread for this long (engine `delay` and
    /// router `reply_delay` faults both resolve to this action).
    Delay(Duration),
    /// Sever the router↔backend connection. The backend stays eligible
    /// for reconnection — this models a transient network cut.
    DropConn,
    /// Take the backend down permanently: sever the connection and mark
    /// the backend `Down` so the router never reconnects. This models a
    /// crashed or decommissioned engine process.
    BackendDown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FaultKind {
    Panic,
    Delay(Duration),
    ConnDrop,
    ReplyDelay(Duration),
    BackendDown,
}

impl FaultKind {
    fn is_network(self) -> bool {
        matches!(
            self,
            FaultKind::ConnDrop | FaultKind::ReplyDelay(_) | FaultKind::BackendDown
        )
    }
}

/// A parsed, armed fault-injection plan (see the module docs for the
/// spec grammar). Shared by every worker of one batcher; interior
/// mutability keeps [`FaultPlan::check`] callable from `&self`.
#[derive(Debug)]
pub struct FaultPlan {
    kind: FaultKind,
    op: FaultOp,
    /// 1-based trigger count: fire before the `at`-th matching op.
    at: u64,
    /// Restrict firing to this worker id — or backend index, for the
    /// network ops, which count per backend (`None` = any).
    worker: Option<usize>,
    fired: AtomicBool,
    /// Per-worker (or per-backend) counts of the plan's op.
    counters: Mutex<HashMap<usize, u64>>,
}

impl FaultPlan {
    /// Parse a fault spec (`panic:worker=1,decode_step=37`). Errors
    /// describe the offending clause.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let (kind_s, rest) = spec
            .split_once(':')
            .ok_or_else(|| "expected `<kind>:<key>=<val>,...`".to_string())?;
        let mut trigger: Option<(FaultOp, u64)> = None;
        let mut worker = None;
        let mut ms = None;
        for clause in rest.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (k, v) = clause
                .split_once('=')
                .ok_or_else(|| format!("bad clause {clause:?}: expected key=value"))?;
            let n: u64 = v
                .trim()
                .parse()
                .map_err(|_| format!("bad value in {clause:?}: expected an integer"))?;
            match k.trim() {
                // `backend=` is the network-spec synonym: the router
                // counts ops per backend index in the same slot the
                // batcher uses for worker ids.
                "worker" | "backend" => worker = Some(n as usize),
                "decode_step" | "prefill" | "verify_step" | "fwd" | "reply" => {
                    if trigger.is_some() {
                        return Err(
                            "exactly one trigger (decode_step=N, prefill=N, verify_step=N, \
                             fwd=N or reply=N)"
                                .into(),
                        );
                    }
                    let op = match k.trim() {
                        "prefill" => FaultOp::PrefillChunk,
                        "verify_step" => FaultOp::VerifyStep,
                        "fwd" => FaultOp::RouterFwd,
                        "reply" => FaultOp::RouterReply,
                        _ => FaultOp::DecodeStep,
                    };
                    trigger = Some((op, n));
                }
                "ms" => ms = Some(n),
                other => return Err(format!("unknown key {other:?}")),
            }
        }
        let (op, at) = trigger.ok_or_else(|| {
            "spec needs a trigger: decode_step=N, prefill=N, verify_step=N, fwd=N or reply=N"
                .to_string()
        })?;
        if at == 0 {
            return Err("trigger counts are 1-based: use decode_step=1 for the first step".into());
        }
        let kind = match kind_s.trim() {
            "panic" => {
                if ms.is_some() {
                    return Err("ms= only applies to delay/reply_delay faults".into());
                }
                FaultKind::Panic
            }
            "delay" => FaultKind::Delay(Duration::from_millis(ms.unwrap_or(25))),
            "conn_drop" => {
                if ms.is_some() {
                    return Err("ms= only applies to delay/reply_delay faults".into());
                }
                FaultKind::ConnDrop
            }
            "reply_delay" => FaultKind::ReplyDelay(Duration::from_millis(ms.unwrap_or(25))),
            "backend_down" => {
                if ms.is_some() {
                    return Err("ms= only applies to delay/reply_delay faults".into());
                }
                FaultKind::BackendDown
            }
            other => {
                return Err(format!(
                    "unknown fault kind {other:?} \
                     (expected panic|delay|conn_drop|reply_delay|backend_down)"
                ))
            }
        };
        if kind.is_network() != op.is_network() {
            return Err(format!(
                "kind {kind_s:?} pairs with {} triggers ({})",
                if kind.is_network() { "network" } else { "engine" },
                if kind.is_network() {
                    "fwd=N or reply=N"
                } else {
                    "decode_step=N, prefill=N or verify_step=N"
                }
            ));
        }
        Ok(FaultPlan {
            kind,
            op,
            at,
            worker,
            fired: AtomicBool::new(false),
            counters: Mutex::new(HashMap::new()),
        })
    }

    /// The plan armed by the `SALR_FAULT` environment variable, if set.
    /// A malformed spec panics at startup — a fault plan silently
    /// misparsed would make a CI fault leg silently test nothing.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("SALR_FAULT").ok()?;
        let spec = spec.trim();
        if spec.is_empty() {
            return None;
        }
        match FaultPlan::parse(spec) {
            Ok(plan) => {
                log::warn!("SALR_FAULT armed: {spec}");
                Some(plan)
            }
            Err(e) => panic!("invalid SALR_FAULT spec {spec:?}: {e}"),
        }
    }

    /// Count one occurrence of `op` on `worker` and return the action to
    /// take if this is the plan's trigger point. Workers call this at the
    /// op boundary; counting happens for every matching op so the
    /// trigger's position is independent of which worker fires first.
    pub fn check(&self, op: FaultOp, worker: usize) -> Option<FaultAction> {
        if op != self.op {
            return None;
        }
        let count = {
            let mut counters = self.counters.lock().unwrap();
            let c = counters.entry(worker).or_insert(0);
            *c += 1;
            *c
        };
        if let Some(w) = self.worker {
            if w != worker {
                return None;
            }
        }
        if count != self.at {
            return None;
        }
        if self.fired.swap(true, Ordering::SeqCst) {
            return None; // one-shot: already fired elsewhere
        }
        Some(match self.kind {
            FaultKind::Panic => FaultAction::Panic(format!(
                "injected fault: panic before {} #{} on worker {worker}",
                self.op.name(),
                self.at
            )),
            FaultKind::Delay(d) | FaultKind::ReplyDelay(d) => FaultAction::Delay(d),
            FaultKind::ConnDrop => FaultAction::DropConn,
            FaultKind::BackendDown => FaultAction::BackendDown,
        })
    }

    /// Has the plan's one shot been spent?
    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_examples() {
        let p = FaultPlan::parse("panic:worker=1,decode_step=37").unwrap();
        assert_eq!(p.op, FaultOp::DecodeStep);
        assert_eq!(p.at, 37);
        assert_eq!(p.worker, Some(1));
        assert_eq!(p.kind, FaultKind::Panic);
        let d = FaultPlan::parse("delay:prefill=3").unwrap();
        assert_eq!(d.op, FaultOp::PrefillChunk);
        assert_eq!(d.at, 3);
        assert_eq!(d.worker, None);
        assert_eq!(d.kind, FaultKind::Delay(Duration::from_millis(25)));
        let d = FaultPlan::parse("delay:decode_step=2,ms=400").unwrap();
        assert_eq!(d.kind, FaultKind::Delay(Duration::from_millis(400)));
        let v = FaultPlan::parse("panic:worker=0,verify_step=2").unwrap();
        assert_eq!(v.op, FaultOp::VerifyStep);
        assert_eq!(v.at, 2);
        assert_eq!(v.worker, Some(0));
    }

    #[test]
    fn parses_the_network_kinds() {
        let p = FaultPlan::parse("backend_down:backend=1,fwd=2").unwrap();
        assert_eq!(p.op, FaultOp::RouterFwd);
        assert_eq!(p.at, 2);
        assert_eq!(p.worker, Some(1));
        assert_eq!(p.kind, FaultKind::BackendDown);
        let p = FaultPlan::parse("conn_drop:reply=3").unwrap();
        assert_eq!(p.op, FaultOp::RouterReply);
        assert_eq!(p.at, 3);
        assert_eq!(p.worker, None);
        assert_eq!(p.kind, FaultKind::ConnDrop);
        let p = FaultPlan::parse("reply_delay:reply=1,ms=40,backend=0").unwrap();
        assert_eq!(p.kind, FaultKind::ReplyDelay(Duration::from_millis(40)));
        assert_eq!(p.worker, Some(0));
        // `worker=` parses as a synonym on network specs too.
        let p = FaultPlan::parse("conn_drop:worker=1,fwd=1").unwrap();
        assert_eq!(p.worker, Some(1));
    }

    #[test]
    fn rejects_cross_class_kind_trigger_pairs() {
        for bad in [
            // Engine kinds never key on network triggers...
            "panic:fwd=1",
            "delay:reply=2",
            // ...and network kinds never key on engine ops.
            "conn_drop:decode_step=1",
            "backend_down:prefill=2",
            "reply_delay:verify_step=1",
            // Only the delaying kinds take a duration.
            "conn_drop:fwd=1,ms=5",
            "backend_down:fwd=1,ms=5",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must not parse");
        }
    }

    #[test]
    fn network_ops_count_per_backend_and_fire_once() {
        let p = FaultPlan::parse("backend_down:backend=1,fwd=2").unwrap();
        // Forwards to backend 0 never advance backend 1's count, and
        // reply frames never advance the fwd count.
        for _ in 0..4 {
            assert_eq!(p.check(FaultOp::RouterFwd, 0), None);
        }
        assert_eq!(p.check(FaultOp::RouterReply, 1), None);
        assert_eq!(p.check(FaultOp::RouterFwd, 1), None); // fwd 1
        assert_eq!(
            p.check(FaultOp::RouterFwd, 1), // fwd 2: fire
            Some(FaultAction::BackendDown)
        );
        assert!(p.fired());
        assert_eq!(p.check(FaultOp::RouterFwd, 1), None, "one-shot");
        let d = FaultPlan::parse("reply_delay:reply=1,ms=7").unwrap();
        assert_eq!(
            d.check(FaultOp::RouterReply, 0),
            Some(FaultAction::Delay(Duration::from_millis(7)))
        );
        let c = FaultPlan::parse("conn_drop:reply=1").unwrap();
        assert_eq!(c.check(FaultOp::RouterReply, 2), Some(FaultAction::DropConn));
    }

    #[test]
    fn verify_counter_is_independent_of_the_others() {
        let p = FaultPlan::parse("panic:verify_step=2").unwrap();
        // Decode steps and prefill chunks never advance the verify count.
        assert_eq!(p.check(FaultOp::DecodeStep, 0), None);
        assert_eq!(p.check(FaultOp::PrefillChunk, 0), None);
        assert_eq!(p.check(FaultOp::VerifyStep, 0), None); // verify 1
        let action = p.check(FaultOp::VerifyStep, 0); // verify 2: fire
        match action {
            Some(FaultAction::Panic(msg)) => assert!(msg.contains("verify_step #2")),
            other => panic!("expected a panic action, got {other:?}"),
        }
        assert_eq!(p.check(FaultOp::VerifyStep, 0), None, "one-shot");
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic:",
            "boom:decode_step=1",
            "panic:decode_step=0",
            "panic:decode_step=1,prefill=2",
            "panic:worker=1",
            "panic:decode_step=x",
            "panic:decode_step=1,ms=5",
            "panic:decode_step=1,frobnicate=2",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must not parse");
        }
    }

    #[test]
    fn fires_once_at_the_counted_op_on_the_matching_worker() {
        let p = FaultPlan::parse("panic:worker=1,decode_step=3").unwrap();
        // Worker 0 sails past its own third step: wrong worker.
        for _ in 0..5 {
            assert_eq!(p.check(FaultOp::DecodeStep, 0), None);
        }
        // Prefill chunks on worker 1 do not advance the decode counter.
        assert_eq!(p.check(FaultOp::PrefillChunk, 1), None);
        assert_eq!(p.check(FaultOp::DecodeStep, 1), None); // step 1
        assert_eq!(p.check(FaultOp::DecodeStep, 1), None); // step 2
        assert!(!p.fired());
        let action = p.check(FaultOp::DecodeStep, 1); // step 3: fire
        assert!(matches!(action, Some(FaultAction::Panic(_))));
        assert!(p.fired());
        // One-shot: the respawned worker's steps never re-fire.
        for _ in 0..5 {
            assert_eq!(p.check(FaultOp::DecodeStep, 1), None);
        }
    }

    #[test]
    fn unfiltered_plan_fires_on_whichever_worker_counts_there_first() {
        let p = FaultPlan::parse("delay:decode_step=2,ms=7").unwrap();
        assert_eq!(p.check(FaultOp::DecodeStep, 3), None); // worker 3, step 1
        assert_eq!(p.check(FaultOp::DecodeStep, 0), None); // worker 0, step 1
        assert_eq!(
            p.check(FaultOp::DecodeStep, 0), // worker 0 reaches step 2 first
            Some(FaultAction::Delay(Duration::from_millis(7)))
        );
        // Worker 3's own second step arrives after the shot is spent.
        assert_eq!(p.check(FaultOp::DecodeStep, 3), None);
    }
}
