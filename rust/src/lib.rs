//! # SALR — Sparsity-Aware Low-Rank Representation
//!
//! Reproduction of *"SALR: Sparsity-Aware Low-Rank Representation for
//! Efficient Fine-Tuning of Large Language Models"* (Zhang et al., 2026) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: pruning, bitmap sparse
//!   codec, truncated-SVD residual adapters, adapter concatenation, the
//!   two-stage decode+GEMM pipeline, a fine-tuning driver, a native
//!   inference engine, and a continuous-batching server with multiple
//!   engine workers. Python never runs on the request path.
//! * **Layer 2** — a JAX transformer (`python/compile/model.py`) whose
//!   train / eval / generate steps are AOT-lowered to HLO text and executed
//!   through the PJRT CPU client (`runtime`).
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   bitmap-decode matmul, the fused concatenated-adapter GEMM and NF4
//!   dequantization, validated against pure-jnp oracles.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a driver in [`eval`].

pub mod cli;
pub mod data;
pub mod eval;
// The serving-path modules hold the crate's load-bearing public API, so
// they carry a documentation guarantee: every public item is documented
// (`missing_docs` is scoped here and `cargo doc` runs with
// `RUSTDOCFLAGS="-D warnings"` in CI; `util::pool` opts in from
// `util/mod.rs`).
#[warn(missing_docs)]
pub mod gemm;
#[warn(missing_docs)]
pub mod infer;
pub mod linalg;
pub mod model;
pub mod prune;
pub mod quant;
pub mod runtime;
pub mod salr;
#[warn(missing_docs)]
pub mod server;
pub mod sparse;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
