//! `salr` — the coordinator binary: experiments, training, compression and
//! serving, all over the AOT artifacts + native engine (no python on any
//! code path here).

use anyhow::{bail, Result};
use salr::cli::{parse_baseline, Args, USAGE};
use salr::eval::{deploy_engine, ExpContext, RunKey, Task};
use salr::gemm::pipeline::PipelineConfig;
use salr::infer::Backend;
use salr::model::{save_model, Encoding};
use salr::salr::BaselineSpec;
use salr::server::{serve, serve_router, BatchPolicy, RouterPolicy};
use salr::train::TrainConfig;
use salr::util::pool::WorkerPool;

fn main() {
    salr::util::logger::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e:#}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Result<ExpContext> {
    if let Some(steps) = args.flag("steps") {
        std::env::set_var("SALR_STEPS", steps);
    }
    ExpContext::new(
        &args.str_or("artifacts", "artifacts"),
        &args.str_or("config", "tiny"),
        &args.str_or("results", "results"),
    )
}

fn parse_task(s: &str) -> Result<Task> {
    match s {
        "math" => Ok(Task::Math),
        "mcq" => Ok(Task::Mcq),
        other => bail!("unknown task {other} (math|mcq)"),
    }
}

fn run(args: &Args) -> Result<()> {
    // Size the process-global worker pool before any GEMM runs; every
    // command (experiments, training, serving) inherits it.
    let threads = args.usize_or("threads", 0)?;
    if threads > 0 {
        WorkerPool::set_global_threads(threads);
    }
    // Tracing: SALR_TRACE=1 enables recording; --trace-out FILE enables
    // it *and* dumps a Chrome trace_event JSON at drain/shutdown.
    salr::util::trace::init_from_env();
    if let Some(path) = args.flag("trace-out") {
        salr::util::trace::set_trace_out(path);
    }
    match args.command.as_str() {
        "exp" => {
            let ctx = ctx_from(args)?;
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            salr::eval::run_experiment(&ctx, id)
        }
        "pretrain" => {
            let ctx = ctx_from(args)?;
            let params = ctx.base_model()?;
            println!(
                "base model ready: {} tensors, {} params",
                params.len(),
                params.param_count()
            );
            Ok(())
        }
        "finetune" => {
            let ctx = ctx_from(args)?;
            let key = RunKey {
                baseline: parse_baseline(&args.str_or("baseline", "salr"))?,
                task: parse_task(&args.str_or("task", "math"))?,
                sparsity: args.f64_or("sparsity", 0.5)?,
            };
            let (spec, adapters, losses) = ctx.run(&key)?;
            let acc = ctx.accuracy(&spec, &adapters, key.task)?;
            println!(
                "{} on {} @p={}: accuracy {:.1}% ({} loss points)",
                key.baseline.name(),
                key.task.name(),
                key.sparsity,
                acc * 100.0,
                losses.len()
            );
            Ok(())
        }
        "serve" => {
            let ctx = ctx_from(args)?;
            let key = RunKey {
                baseline: parse_baseline(&args.str_or("baseline", "salr"))?,
                task: parse_task(&args.str_or("task", "math"))?,
                sparsity: args.f64_or("sparsity", 0.5)?,
            };
            let (spec, adapters, _) = ctx.run(&key)?;
            // Resident weight format defaults from SALR_WEIGHT_FORMAT
            // (bitmap when unset); an explicit flag overrides the env.
            let wfmt = match args.flag("weight-format") {
                Some(s) => salr::model::WeightFormat::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("--weight-format must be f32|bitmap|nf4"))?,
                None => salr::model::WeightFormat::env_default(),
            };
            let mut engine =
                salr::eval::deploy_engine_with_format(&ctx.cfg, &spec, &adapters, None, wfmt)?;
            engine.backend = match args.str_or("backend", "pipeline").as_str() {
                "dense" => Backend::Dense,
                "bitmap" => Backend::BitmapSequential,
                "pipeline" => Backend::BitmapPipelined(PipelineConfig::with_threads(threads)),
                other => bail!("unknown backend {other}"),
            };
            // Cache knobs default from the environment (SALR_PREFIX_CACHE
            // / SALR_KV_BLOCK); explicit flags override. `--prefix-cache
            // false` turns the cache off even when the env forces it on.
            let defaults = BatchPolicy::default();
            let policy = BatchPolicy {
                max_batch: args.usize_or("max-batch", 8)?,
                max_wait: std::time::Duration::from_millis(
                    args.usize_or("max-wait-ms", 5)? as u64,
                ),
                num_threads: threads,
                engine_workers: args.usize_or("engine-workers", 1)?.max(1),
                prefill_chunk: args.usize_or("prefill-chunk", 64)?,
                kv_block_size: args.usize_or("kv-block-size", defaults.kv_block_size)?.max(1),
                prefix_cache: if args.flag("prefix-cache").is_some() {
                    args.bool("prefix-cache")
                } else {
                    defaults.prefix_cache
                },
                stream_frame_cap: args
                    .usize_or("stream-frame-cap", defaults.stream_frame_cap)?
                    .max(1),
                default_deadline_ms: args.usize_or("default-deadline-ms", 0)? as u64,
                max_queue_depth: args.usize_or("max-queue-depth", 0)?,
                idle_timeout_ms: args.usize_or("idle-timeout-ms", 0)? as u64,
                // Speculation defaults from SALR_SPEC; an explicit flag
                // overrides, including `--spec-decode off` against the env.
                spec_decode: match args.flag("spec-decode") {
                    Some(s) => salr::infer::SpecMode::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("--spec-decode must be off|radix|self"))?,
                    None => defaults.spec_decode,
                },
                spec_k: args.usize_or("spec-k", defaults.spec_k)?.max(1),
            };
            serve(engine, &args.str_or("addr", "127.0.0.1:7433"), policy, None)
        }
        "router" => {
            // The router tier needs no model artifacts: it fronts
            // engine processes started separately with `salr serve`.
            let spec = args
                .flag("backends")
                .or_else(|| args.flag("backend"))
                .map(str::to_string)
                .unwrap_or_default();
            let backends: Vec<String> = spec
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if backends.is_empty() {
                bail!("router needs --backends host:port[,host:port,...]");
            }
            let d = RouterPolicy::default();
            let policy = RouterPolicy {
                heartbeat_ms: args.usize_or("heartbeat-ms", d.heartbeat_ms as usize)? as u64,
                miss_threshold: args
                    .usize_or("miss-threshold", d.miss_threshold as usize)?
                    .max(1) as u64,
                spill_depth: args.usize_or("spill-depth", d.spill_depth as usize)? as u64,
                hash_blocks: args.usize_or("hash-blocks", d.hash_blocks)?.max(1),
                kv_block_size: args.usize_or("kv-block-size", d.kv_block_size)?.max(1),
                vnodes: args.usize_or("vnodes", d.vnodes)?.max(1),
                backoff_base_ms: args
                    .usize_or("backoff-base-ms", d.backoff_base_ms as usize)?
                    .max(1) as u64,
                backoff_max_ms: args
                    .usize_or("backoff-max-ms", d.backoff_max_ms as usize)?
                    .max(1) as u64,
                stream_frame_cap: args
                    .usize_or("stream-frame-cap", d.stream_frame_cap)?
                    .max(1),
                connect_timeout_ms: args
                    .usize_or("connect-timeout-ms", d.connect_timeout_ms as usize)?
                    .max(1) as u64,
            };
            serve_router(&backends, &args.str_or("addr", "127.0.0.1:7400"), policy, None)
        }
        "compress" => {
            let ctx = ctx_from(args)?;
            let sparsity = args.f64_or("sparsity", 0.5)?;
            let base = ctx.base_model()?;
            let spec = BaselineSpec::build(
                &ctx.cfg,
                &base,
                salr::salr::Baseline::Salr,
                sparsity,
                13,
            );
            let adapted: std::collections::HashSet<String> =
                ctx.cfg.adapted_layers().into_iter().collect();
            let out = ctx.results_dir.join("compressed_model.salr");
            let dense = base.dense_bytes() as u64;
            let bytes = save_model(&out, &spec.params, |name, t| {
                if adapted.contains(name) && t.ndim() == 2 {
                    if args.bool("nf4") {
                        Encoding::SparseNf4
                    } else {
                        Encoding::Bitmap
                    }
                } else {
                    Encoding::Dense
                }
            })?;
            println!(
                "compressed @p={sparsity}: {} -> {} ({:.2}x) at {:?}",
                salr::util::human_bytes(dense),
                salr::util::human_bytes(bytes),
                dense as f64 / bytes as f64,
                out
            );
            Ok(())
        }
        "info" => {
            let ctx = ctx_from(args)?;
            let man = ctx.runtime.manifest();
            println!("configs:");
            for c in &man.configs {
                println!(
                    "  {}: d_model={} layers={} heads={} d_ff={} seq={} rank={} res_rank={}",
                    c.name, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.max_seq_len,
                    c.rank, c.residual_rank
                );
            }
            println!("artifacts:");
            for a in &man.artifacts {
                println!(
                    "  {:<28} {:>3} in / {:>3} out   {}",
                    a.name,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.file
                );
            }
            let tc = TrainConfig::default();
            println!("default train config: {tc:?}");
            Ok(())
        }
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other}\n\n{USAGE}"),
    }
}
