//! The pretraining corpus: a seeded mixture of easy arithmetic, fact
//! sentences and filler prose. The base model "knows" 2-digit arithmetic
//! and the MCQ fact universe after pretraining; fine-tuning specializes.

use super::math_task::MathTask;
use super::mcq_task::McqTask;
use crate::util::rng::Rng;

/// Corpus sampler.
pub struct CorpusGen {
    math: MathTask,
    mcq: McqTask,
    facts: Vec<String>,
    rng: Rng,
    math_index: u64,
}

const FILLER_WORDS: [&str; 16] = [
    "the", "model", "weight", "sparse", "dense", "prune", "adapter", "rank",
    "low", "matrix", "value", "token", "layer", "norm", "train", "infer",
];

impl CorpusGen {
    pub fn new(seed: u64) -> CorpusGen {
        let mcq = McqTask::default_task();
        let facts = mcq.all_facts();
        CorpusGen {
            math: MathTask::pretrain(),
            mcq,
            facts,
            rng: Rng::new(seed),
            math_index: 0,
        }
    }

    /// Next corpus line.
    pub fn next_line(&mut self) -> String {
        match self.rng.below(10) {
            // 40%: easy arithmetic with answers.
            0..=3 => {
                self.math_index += 1;
                self.math.example(self.math_index).full_text()
            }
            // 30%: fact sentences (the MCQ knowledge base).
            4..=6 => self.facts[self.rng.below(self.facts.len())].clone(),
            // 20%: MCQ-formatted questions with answers (teaches format).
            7..=8 => {
                let e = self.mcq.example(self.rng.next_u64() % (1 << 19));
                e.full_text()
            }
            // 10%: filler prose.
            _ => {
                let n = 4 + self.rng.below(8);
                let mut s = String::new();
                for i in 0..n {
                    if i > 0 {
                        s.push(' ');
                    }
                    s.push_str(FILLER_WORDS[self.rng.below(FILLER_WORDS.len())]);
                }
                s.push_str(".\n");
                s
            }
        }
    }

    /// Fill a fixed-length token window (concatenated lines, truncated).
    pub fn next_window(&mut self, len: usize) -> Vec<i32> {
        let mut toks = Vec::with_capacity(len + 64);
        while toks.len() < len {
            toks.extend(super::tokenize(&self.next_line()));
        }
        toks.truncate(len);
        toks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_have_exact_length_and_mixture() {
        let mut gen = CorpusGen::new(9);
        let mut saw_math = false;
        let mut saw_fact = false;
        for _ in 0..30 {
            let w = gen.next_window(128);
            assert_eq!(w.len(), 128);
            let text = super::super::detokenize(&w);
            saw_math |= text.contains('=') && text.contains("Q ");
            saw_fact |= text.contains("F e");
        }
        assert!(saw_math && saw_fact);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGen::new(5);
        let mut b = CorpusGen::new(5);
        for _ in 0..10 {
            assert_eq!(a.next_line(), b.next_line());
        }
    }
}
