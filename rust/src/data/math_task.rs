//! The GSM8K stand-in: multi-digit arithmetic word problems, graded by
//! exact match on the generated answer string. Difficulty is controlled by
//! digit count and operator mix; the fine-tuning set uses harder problems
//! than the pretraining corpus so adaptation is actually required.

use crate::util::rng::Rng;

/// One arithmetic example.
#[derive(Clone, Debug)]
pub struct MathExample {
    pub prompt: String,
    /// Canonical decimal answer (e.g. "105").
    pub answer: String,
    /// Training/generation target: zero-padded to 3 digits, reversed
    /// (LSB first) — the standard trick that makes char-level arithmetic
    /// learnable for small decoder-only models.
    pub target: String,
}

impl MathExample {
    /// Full text (prompt + target) for training.
    pub fn full_text(&self) -> String {
        format!("{}{}\n", self.prompt, self.target)
    }
}

/// Encode an answer value as the reversed zero-padded target string.
pub fn encode_answer(v: i64) -> String {
    format!("{:03}", v.max(0)).chars().rev().collect()
}

/// Decode a generated string back to the numeric answer (reads the first
/// three digits, un-reverses).
pub fn decode_answer(s: &str) -> Option<i64> {
    let digits: String = s
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .take(3)
        .collect();
    if digits.len() < 3 {
        return None;
    }
    let canonical: String = digits.chars().rev().collect();
    canonical.parse().ok()
}

/// Generator configuration for the math task.
#[derive(Clone, Debug)]
pub struct MathTask {
    pub min_val: i64,
    pub max_val: i64,
    /// Include two-step problems (a op b op c).
    pub two_step: bool,
    pub seed: u64,
}

impl MathTask {
    /// The distribution seeding the pretraining corpus: 2-digit add/sub.
    /// The base model acquires the skill under-trained (math is only ~40%
    /// of the corpus) — fine-tuning then sharpens it, mirroring the
    /// paper's Llama + MetaMath setting where the base model already has
    /// partial capability.
    pub fn pretrain() -> MathTask {
        MathTask {
            min_val: 0,
            max_val: 99,
            two_step: false,
            seed: 1234,
        }
    }

    /// The fine-tuning distribution: same task family, disjoint examples
    /// (different seed/index space).
    pub fn finetune() -> MathTask {
        MathTask {
            min_val: 10,
            max_val: 99,
            two_step: false,
            seed: 5678,
        }
    }

    /// Deterministic i-th example (disjoint train/test via index ranges).
    pub fn example(&self, index: u64) -> MathExample {
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let span = (self.max_val - self.min_val + 1) as usize;
        let a = self.min_val + rng.below(span) as i64;
        let b = self.min_val + rng.below(span) as i64;
        let (expr, mut value) = match rng.below(2) {
            0 => (format!("{a:02}+{b:02}"), a + b),
            _ => {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                (format!("{hi:02}-{lo:02}"), hi - lo)
            }
        };
        let expr = if self.two_step && rng.below(2) == 0 {
            let c = self.min_val + rng.below(span.min(90)) as i64;
            value += c;
            format!("{expr}+{c}")
        } else {
            expr
        };
        MathExample {
            prompt: format!("Q {expr}="),
            answer: format!("{value}"),
            target: encode_answer(value),
        }
    }

    /// A batch of training examples (indices 0..n are the train split;
    /// test uses indices >= 1<<20 so the splits never collide).
    pub fn train_examples(&self, n: usize) -> Vec<MathExample> {
        (0..n as u64).map(|i| self.example(i)).collect()
    }

    pub fn test_examples(&self, n: usize) -> Vec<MathExample> {
        (0..n as u64).map(|i| self.example((1 << 20) + i)).collect()
    }
}

/// Grade a generated continuation against the gold canonical answer:
/// exact match after decoding the reversed-padded digits (the GSM8K
/// protocol, adapted to the target encoding).
pub fn grade(generated: &str, gold: &str) -> bool {
    match (decode_answer(generated), gold.parse::<i64>()) {
        (Some(got), Ok(want)) => got == want,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_are_deterministic_and_correct() {
        let task = MathTask::finetune();
        for i in 0..200 {
            let e1 = task.example(i);
            let e2 = task.example(i);
            assert_eq!(e1.prompt, e2.prompt);
            // Parse the expression and check the recorded answer.
            let expr = e1
                .prompt
                .strip_prefix("Q ")
                .unwrap()
                .strip_suffix('=')
                .unwrap();
            let val = eval_expr(expr);
            assert_eq!(val.to_string(), e1.answer, "{expr}");
            // Target is the reversed zero-padded answer.
            assert_eq!(e1.target, encode_answer(val));
            assert_eq!(decode_answer(&e1.target), Some(val));
        }
    }

    fn eval_expr(expr: &str) -> i64 {
        // Left-to-right with * taking immediate operands (matches the
        // generator's construction: products never mix with +/- wrongly
        // because * only appears as the first op).
        let mut total = 0i64;
        let mut pending_op = '+';
        let mut cur = String::new();
        let mut chars = expr.chars().peekable();
        let mut terms: Vec<(char, i64)> = Vec::new();
        while let Some(c) = chars.next() {
            cur.push(c);
            let next_is_op = matches!(chars.peek(), Some('+') | Some('-') | Some('*') | None)
                && !cur.is_empty();
            if next_is_op || chars.peek().is_none() {
                if let Some(&op) = chars.peek() {
                    let v: i64 = cur.parse().unwrap();
                    terms.push((pending_op, v));
                    pending_op = op;
                    cur.clear();
                    chars.next();
                } else {
                    let v: i64 = cur.parse().unwrap();
                    terms.push((pending_op, v));
                }
            }
        }
        // Apply * first, then +/-.
        let mut reduced: Vec<(char, i64)> = Vec::new();
        for (op, v) in terms {
            if op == '*' {
                let (lop, lv) = reduced.pop().unwrap();
                reduced.push((lop, lv * v));
            } else {
                reduced.push((op, v));
            }
        }
        for (op, v) in reduced {
            match op {
                '+' => total += v,
                '-' => total -= v,
                _ => unreachable!(),
            }
        }
        total
    }

    #[test]
    fn splits_are_disjoint() {
        let task = MathTask::pretrain();
        let train = task.train_examples(50);
        let test = task.test_examples(50);
        let train_set: std::collections::HashSet<_> =
            train.iter().map(|e| e.prompt.clone()).collect();
        let overlap = test.iter().filter(|e| train_set.contains(&e.prompt)).count();
        assert!(overlap <= 2, "overlap={overlap}"); // tiny collision chance
    }

    #[test]
    fn grading() {
        // "95" encodes as "590"; "105" as "501".
        assert_eq!(encode_answer(95), "590");
        assert!(grade("590", "95"));
        assert!(grade(" 590\nQ", "95"));
        assert!(grade("501", "105"));
        assert!(!grade("593", "95")); // decodes to 395
        assert!(!grade("59", "95")); // too short
        assert!(grade("000", "0"));
    }
}
