//! Synthetic data: the stand-ins for MetaMath/GSM8K (arithmetic word
//! problems, exact-match graded) and MMLU (multiple-choice over seeded
//! facts), plus the mixed pretraining corpus. See DESIGN.md §Substitutions.

mod batch;
mod corpus;
mod math_task;
mod mcq_task;
mod tokenizer;

pub use batch::{Batch, BatchBuilder};
pub use corpus::CorpusGen;
pub use math_task::{grade, MathExample, MathTask};
pub use mcq_task::{McqExample, McqTask, CHOICES};
pub use tokenizer::{detokenize, token_byte, tokenize, PAD, VOCAB_SIZE};

/// The letter of the i-th multiple-choice option.
pub fn mcq_letter(i: usize) -> char {
    CHOICES[i]
}
