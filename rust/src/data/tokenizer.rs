//! Byte-level tokenizer: token id = byte value. Vocab 256; byte 0 (NUL,
//! never produced by the generators) doubles as PAD.

/// Vocabulary size (all byte values).
pub const VOCAB_SIZE: usize = 256;

/// Padding token (id 0).
pub const PAD: i32 = 0;

/// Encode a string's bytes as token ids.
pub fn tokenize(s: &str) -> Vec<i32> {
    s.bytes().map(|b| b as i32).collect()
}

/// The text byte a token id contributes when decoding (`None` for PAD and
/// out-of-range ids, which contribute nothing). Shared by [`detokenize`]
/// and the server's incremental stream decoder so the two paths can never
/// disagree about which tokens carry bytes.
pub fn token_byte(t: i32) -> Option<u8> {
    (t > 0 && t < 256).then_some(t as u8)
}

/// Decode token ids back to a string (PAD and invalid bytes dropped).
pub fn detokenize(toks: &[i32]) -> String {
    let bytes: Vec<u8> = toks.iter().filter_map(|&t| token_byte(t)).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Q: 12+34=? A: 46\n";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn pad_dropped() {
        let mut toks = tokenize("ab");
        toks.push(PAD);
        toks.insert(0, PAD);
        assert_eq!(detokenize(&toks), "ab");
    }
}
