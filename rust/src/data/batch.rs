//! Fixed-shape token batches matching the AOT train-step signature:
//! `tokens: i32[B, S]`, `loss_mask: f32[B, S]` (1 where the position's
//! *target* contributes to the loss).

use super::tokenizer::{tokenize, PAD};
use crate::util::rng::Rng;

/// A training batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub loss_mask: Vec<f32>,
}

impl Batch {
    pub fn zeros(batch: usize, seq: usize) -> Batch {
        Batch {
            batch,
            seq,
            tokens: vec![PAD; batch * seq],
            loss_mask: vec![0.0; batch * seq],
        }
    }
}

/// Assembles batches from (prompt, answer) pairs or raw windows.
pub struct BatchBuilder {
    pub batch: usize,
    pub seq: usize,
}

impl BatchBuilder {
    pub fn new(batch: usize, seq: usize) -> BatchBuilder {
        BatchBuilder { batch, seq }
    }

    /// Batch of raw corpus windows — every position contributes to loss.
    pub fn from_windows(&self, windows: &[Vec<i32>]) -> Batch {
        assert_eq!(windows.len(), self.batch);
        let mut b = Batch::zeros(self.batch, self.seq);
        for (i, w) in windows.iter().enumerate() {
            assert_eq!(w.len(), self.seq);
            b.tokens[i * self.seq..(i + 1) * self.seq].copy_from_slice(w);
            b.loss_mask[i * self.seq..(i + 1) * self.seq].fill(1.0);
        }
        b
    }

    /// Supervised batch: loss only on the answer (+ newline) tokens —
    /// standard SFT masking. Examples longer than `seq` are truncated from
    /// the left (keeping the answer).
    pub fn from_pairs(&self, pairs: &[(String, String)]) -> Batch {
        assert_eq!(pairs.len(), self.batch);
        let mut b = Batch::zeros(self.batch, self.seq);
        for (i, (prompt, answer)) in pairs.iter().enumerate() {
            let p_toks = tokenize(prompt);
            let a_toks = tokenize(&format!("{answer}\n"));
            let total = p_toks.len() + a_toks.len();
            let (p_keep, offset) = if total > self.seq {
                let cut = total - self.seq;
                (&p_toks[cut.min(p_toks.len())..], 0usize)
            } else {
                (&p_toks[..], 0usize)
            };
            let row = &mut b.tokens[i * self.seq..(i + 1) * self.seq];
            let mrow = &mut b.loss_mask[i * self.seq..(i + 1) * self.seq];
            let mut pos = offset;
            for &t in p_keep {
                row[pos] = t;
                pos += 1;
            }
            for &t in &a_toks {
                if pos >= self.seq {
                    break;
                }
                row[pos] = t;
                mrow[pos] = 1.0;
                pos += 1;
            }
        }
        b
    }

    /// Sample `batch` training pairs by index with an rng.
    pub fn sample_pairs<'a, T>(
        &self,
        examples: &'a [T],
        rng: &mut Rng,
        to_pair: impl Fn(&'a T) -> (String, String),
    ) -> Batch {
        let pairs: Vec<(String, String)> = (0..self.batch)
            .map(|_| to_pair(&examples[rng.below(examples.len())]))
            .collect();
        self.from_pairs(&pairs)
    }

    /// Packed SFT batch: each row concatenates as many (prompt, answer)
    /// pairs as fit, with loss on answer (+ newline) tokens only — ~6-8x
    /// the supervision density of one-pair-per-row padding.
    pub fn sample_packed<'a, T>(
        &self,
        examples: &'a [T],
        rng: &mut Rng,
        to_pair: impl Fn(&'a T) -> (String, String),
    ) -> Batch {
        let mut b = Batch::zeros(self.batch, self.seq);
        for row_i in 0..self.batch {
            let row = &mut b.tokens[row_i * self.seq..(row_i + 1) * self.seq];
            let mrow = &mut b.loss_mask[row_i * self.seq..(row_i + 1) * self.seq];
            let mut pos = 0usize;
            loop {
                let (prompt, answer) = to_pair(&examples[rng.below(examples.len())]);
                let p_toks = tokenize(&prompt);
                let a_toks = tokenize(&format!("{answer}\n"));
                if pos + p_toks.len() + a_toks.len() > self.seq {
                    break;
                }
                for &t in &p_toks {
                    row[pos] = t;
                    pos += 1;
                }
                for &t in &a_toks {
                    row[pos] = t;
                    mrow[pos] = 1.0;
                    pos += 1;
                }
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::detokenize;

    #[test]
    fn pair_batch_masks_answer_only() {
        let bb = BatchBuilder::new(2, 32);
        let b = bb.from_pairs(&[
            ("Q: 1+1=? A: ".to_string(), "2".to_string()),
            ("Q: 30-7=? A: ".to_string(), "23".to_string()),
        ]);
        // Row 0: mask exactly covers "2\n".
        let row0_text = detokenize(&b.tokens[..32]);
        assert!(row0_text.starts_with("Q: 1+1=? A: 2\n"));
        let masked: usize = b.loss_mask[..32].iter().map(|&m| m as usize).sum();
        assert_eq!(masked, 2); // "2" + "\n"
        let prompt_len = "Q: 1+1=? A: ".len();
        assert_eq!(b.loss_mask[prompt_len], 1.0);
        assert_eq!(b.loss_mask[prompt_len - 1], 0.0);
    }

    #[test]
    fn window_batch_full_mask() {
        let bb = BatchBuilder::new(1, 8);
        let b = bb.from_windows(&[vec![65, 66, 67, 68, 69, 70, 71, 72]]);
        assert!(b.loss_mask.iter().all(|&m| m == 1.0));
        assert_eq!(detokenize(&b.tokens), "ABCDEFGH");
    }

    #[test]
    fn truncation_keeps_answer() {
        let bb = BatchBuilder::new(1, 16);
        let long_prompt = "x".repeat(40);
        let b = bb.from_pairs(&[(format!("{long_prompt}A: "), "77".to_string())]);
        let text = detokenize(&b.tokens);
        assert!(text.ends_with("77\n"), "{text}");
    }
}
