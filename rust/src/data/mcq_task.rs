//! The MMLU stand-in: multiple-choice questions over a seeded universe of
//! synthetic facts ("attribute of entity k is v"). The facts appear in the
//! pretraining corpus; the MC benchmark asks for them with four lettered
//! choices, scored by choice log-likelihood — the MMLU protocol.

use crate::util::rng::Rng;

const ATTRIBUTES: [&str; 6] = ["color", "shape", "size", "mood", "rank", "kind"];
const VALUES: [&str; 8] = [
    "red", "blue", "green", "gold", "round", "flat", "tall", "tiny",
];
pub const CHOICES: [char; 4] = ['A', 'B', 'C', 'D'];

/// One multiple-choice example (cloze form: the prompt is the fact prefix
/// "F e123.color=", the options are candidate values, scored by the
/// likelihood of each continuation — the MMLU choice-scoring protocol over
/// knowledge the pretraining corpus actually carries).
#[derive(Clone, Debug)]
pub struct McqExample {
    /// The fact prefix to complete.
    pub prompt: String,
    /// The four candidate values.
    pub options: [String; 4],
    /// Index of the correct choice (0..4).
    pub correct: usize,
    /// The fact sentence as it appears in the pretraining corpus.
    pub fact: String,
}

impl McqExample {
    /// Training text: prompt + correct value (i.e. the fact itself).
    pub fn full_text(&self) -> String {
        format!("{}{}\n", self.prompt, self.options[self.correct])
    }

    /// The SFT answer string.
    pub fn answer(&self) -> &str {
        &self.options[self.correct]
    }
}

/// Generator over a fixed universe of `n_entities` facts.
#[derive(Clone, Debug)]
pub struct McqTask {
    pub n_entities: usize,
    pub seed: u64,
}

impl McqTask {
    pub fn default_task() -> McqTask {
        McqTask {
            n_entities: 400,
            seed: 424242,
        }
    }

    /// The ground-truth value of (entity, attribute) — a deterministic
    /// function of the seed, so corpus and benchmark agree.
    fn fact_value(&self, entity: usize, attr: usize) -> usize {
        let mut rng = Rng::new(
            self.seed ^ (entity as u64) << 20 ^ (attr as u64).wrapping_mul(0x1000_0193),
        );
        rng.below(VALUES.len())
    }

    /// The i-th benchmark question.
    pub fn example(&self, index: u64) -> McqExample {
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(0xDEAD_BEEF_CAFE_F00D) ^ 0x51);
        let entity = rng.below(self.n_entities);
        let attr = rng.below(ATTRIBUTES.len());
        let correct_value = self.fact_value(entity, attr);
        // Three distinct distractors.
        let mut options = vec![correct_value];
        while options.len() < 4 {
            let d = rng.below(VALUES.len());
            if !options.contains(&d) {
                options.push(d);
            }
        }
        rng.shuffle(&mut options);
        let correct = options.iter().position(|&v| v == correct_value).unwrap();
        let fact = format!(
            "F e{}.{}={}\n",
            entity, ATTRIBUTES[attr], VALUES[correct_value]
        );
        let prompt = format!("F e{}.{}=", entity, ATTRIBUTES[attr]);
        let opts: Vec<String> = options.iter().map(|&v| VALUES[v].to_string()).collect();
        McqExample {
            prompt,
            options: [
                opts[0].clone(),
                opts[1].clone(),
                opts[2].clone(),
                opts[3].clone(),
            ],
            correct,
            fact,
        }
    }

    /// All fact sentences (the knowledge the pretraining corpus carries).
    pub fn all_facts(&self) -> Vec<String> {
        let mut out = Vec::new();
        for e in 0..self.n_entities {
            for a in 0..ATTRIBUTES.len() {
                out.push(format!(
                    "F e{}.{}={}\n",
                    e,
                    ATTRIBUTES[a],
                    VALUES[self.fact_value(e, a)]
                ));
            }
        }
        out
    }

    pub fn train_examples(&self, n: usize) -> Vec<McqExample> {
        (0..n as u64).map(|i| self.example(i)).collect()
    }

    pub fn test_examples(&self, n: usize) -> Vec<McqExample> {
        (0..n as u64).map(|i| self.example((1 << 20) + i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn questions_are_consistent_with_facts() {
        let task = McqTask::default_task();
        for i in 0..100 {
            let e = task.example(i);
            // prompt + correct option reconstructs the corpus fact line.
            assert_eq!(format!("{}{}\n", e.prompt, e.answer()), e.fact);
        }
    }

    #[test]
    fn four_distinct_options() {
        let task = McqTask::default_task();
        for i in 0..50 {
            let e = task.example(i);
            let set: std::collections::HashSet<_> = e.options.iter().collect();
            assert_eq!(set.len(), 4);
        }
    }

    #[test]
    fn deterministic() {
        let task = McqTask::default_task();
        assert_eq!(task.example(7).prompt, task.example(7).prompt);
        assert_eq!(task.all_facts().len(), 400 * 6);
    }
}
