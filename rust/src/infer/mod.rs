//! Native inference engine: the deployment path where SALR's sparsity
//! actually pays. The transformer forward runs in rust with KV-cached
//! decode; adapted linears execute either densely (LoRA baseline) or via
//! the bitmap two-stage pipeline (SALR), so Table 4's tokens/s compares
//! the same engine with different weight formats.
//!
//! Besides run-to-completion [`Engine::generate_batch`], the engine
//! exposes the iteration-level [`Engine::prefill`] /
//! [`Engine::decode_step`] API over a [`KvSlotPool`], which is what the
//! server's continuous-batching scheduler drives: sequences join and
//! leave the decode batch between steps, reusing freed KV slots.
//!
//! KV state is **paged**: slots are views over chains of fixed-size
//! blocks from the [`cache`] subsystem, and with the prefix cache enabled
//! ([`KvCacheConfig::prefix_cache`], the `--prefix-cache` flag) requests
//! sharing a prompt head attach the cached head's blocks instead of
//! re-running prefill over identical tokens.

//!
//! Decode can run **speculatively** ([`spec`]): a cheap drafter proposes
//! k tokens, one batched [`Engine::decode_verify`] forward greedily
//! checks them, and the KV chain rolls back to the accepted length —
//! exact verification keeps the token stream bitwise identical to
//! non-speculative decode.

pub mod cache;
mod engine;
mod kv_cache;
pub mod spec;

pub use engine::{Backend, Engine, EngineWeights, VerifyOutcome};
pub use kv_cache::{KvCacheConfig, KvSlotPool, KvView};
pub use spec::{Drafter, RadixDrafter, SelfDrafter, SpecMode};
