//! The transformer inference engine (KV-cached, batched greedy decode).
//!
//! Mirrors the L2 jax forward exactly (RMSNorm ε=1e-5, tanh-GELU, learned
//! positions, causal MHA) so logits agree with the `eval_*` HLO artifacts;
//! integration tests assert that agreement. The adapted linears dispatch
//! on [`Backend`]: dense merged weights (LoRA deployment) vs bitmap-sparse
//! + fused adapters through the two-stage pipeline (SALR deployment).

use super::kv_cache::KvCache;
use crate::gemm::dense::gemm_f32_pool;
use crate::gemm::pipeline::PipelineConfig;
use crate::model::ParamStore;
use crate::prune::{prune_nm, NmPattern};
use crate::runtime::ModelCfg;
use crate::salr::SalrLayer;
use crate::sparse::BitmapMatrix;
use crate::tensor::{argmax, gelu, Tensor};
use crate::util::pool::WorkerPool;
use std::sync::Arc;

/// How the adapted linears execute.
#[derive(Clone, Copy, Debug)]
pub enum Backend {
    /// Dense merged weights, blocked GEMM (the LoRA deployment).
    Dense,
    /// Bitmap decode + GEMM, sequential (ablation: no overlap).
    BitmapSequential,
    /// The paper's two-stage pipelined decode+GEMM.
    BitmapPipelined(PipelineConfig),
}

/// One adapted linear in deployment form.
enum LinearW {
    Dense(Tensor),
    Salr(SalrLayer),
}

impl LinearW {

    fn storage_bytes(&self) -> usize {
        match self {
            LinearW::Dense(w) => w.len() * 4,
            LinearW::Salr(l) => l.storage_bytes(),
        }
    }
}

struct LayerWeights {
    wq: LinearW,
    wk: LinearW,
    wv: LinearW,
    wo: LinearW,
    w_in: LinearW,
    w_out: LinearW,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// All deployed weights.
pub struct EngineWeights {
    pub cfg: ModelCfg,
    embed: Tensor,
    pos_embed: Tensor,
    lm_head: Tensor,
    final_norm: Vec<f32>,
    layers: Vec<LayerWeights>,
}

impl EngineWeights {
    /// Dense deployment: merge `W0 + s·A·B (+ A_res·B_res)` per layer.
    /// With `adapters = None` this is the raw (pre-finetune) model.
    pub fn dense_merged(
        cfg: &ModelCfg,
        base: &ParamStore,
        adapters: Option<&ParamStore>,
    ) -> EngineWeights {
        Self::build(cfg, base, |name, w| {
            let mut merged = w.clone();
            if let Some(ad) = adapters {
                merge_adapters_into(cfg, ad, name, &mut merged);
            }
            LinearW::Dense(merged)
        })
    }

    /// SALR deployment: bitmap-encode the (pruned) base weights, keep the
    /// adapters factored and concatenated. `nm` optionally re-prunes to an
    /// N:M pattern first (the Table-4 2:4 protocol).
    pub fn salr(
        cfg: &ModelCfg,
        pruned_base: &ParamStore,
        adapters: &ParamStore,
        nm: Option<NmPattern>,
    ) -> EngineWeights {
        Self::build(cfg, pruned_base, |name, w| {
            let mut w_hat = w.clone();
            if let Some(pat) = nm {
                prune_nm(&mut w_hat, pat);
            }
            let la = adapters.get(&format!("{name}.lora_a")).expect("lora_a");
            let lb = adapters.get(&format!("{name}.lora_b")).expect("lora_b");
            let res = match (
                adapters.get(&format!("{name}.res_a")),
                adapters.get(&format!("{name}.res_b")),
            ) {
                (Some(a), Some(b)) => Some((a, b)),
                _ => None,
            };
            LinearW::Salr(SalrLayer::new(
                BitmapMatrix::encode(&w_hat),
                la,
                lb,
                cfg.lora_scaling(),
                res,
            ))
        })
    }

    fn build(
        cfg: &ModelCfg,
        base: &ParamStore,
        mut make: impl FnMut(&str, &Tensor) -> LinearW,
    ) -> EngineWeights {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let lw = |lin: &str, make: &mut dyn FnMut(&str, &Tensor) -> LinearW| {
                let name = format!("layer{i}.{lin}");
                make(&name, base.get(&name).expect("linear"))
            };
            layers.push(LayerWeights {
                wq: lw("wq", &mut make),
                wk: lw("wk", &mut make),
                wv: lw("wv", &mut make),
                wo: lw("wo", &mut make),
                w_in: lw("w_in", &mut make),
                w_out: lw("w_out", &mut make),
                attn_norm: base
                    .get(&format!("layer{i}.attn_norm"))
                    .unwrap()
                    .data()
                    .to_vec(),
                mlp_norm: base
                    .get(&format!("layer{i}.mlp_norm"))
                    .unwrap()
                    .data()
                    .to_vec(),
            });
        }
        EngineWeights {
            cfg: cfg.clone(),
            embed: base.get("embed").unwrap().clone(),
            pos_embed: base.get("pos_embed").unwrap().clone(),
            lm_head: base.get("lm_head").unwrap().clone(),
            final_norm: base.get("final_norm").unwrap().data().to_vec(),
            layers,
        }
    }

    /// Deployment storage across the adapted linears (the Table-4 "model"
    /// that sparsity compresses).
    pub fn linear_storage_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.storage_bytes()
                    + l.wk.storage_bytes()
                    + l.wv.storage_bytes()
                    + l.wo.storage_bytes()
                    + l.w_in.storage_bytes()
                    + l.w_out.storage_bytes()
            })
            .sum()
    }
}

fn merge_adapters_into(cfg: &ModelCfg, adapters: &ParamStore, name: &str, w: &mut Tensor) {
    let s = cfg.lora_scaling();
    if let (Some(a), Some(b)) = (
        adapters.get(&format!("{name}.lora_a")),
        adapters.get(&format!("{name}.lora_b")),
    ) {
        let mut ab = crate::tensor::matmul(a, b);
        ab.scale(s);
        crate::tensor::axpy(w, 1.0, &ab);
    }
    if let (Some(a), Some(b)) = (
        adapters.get(&format!("{name}.res_a")),
        adapters.get(&format!("{name}.res_b")),
    ) {
        let ab = crate::tensor::matmul(a, b);
        crate::tensor::axpy(w, 1.0, &ab);
    }
}

/// The engine: weights + backend + the worker pool its GEMMs run on.
pub struct Engine {
    pub weights: EngineWeights,
    pub backend: Backend,
    /// Pool for the dense linears and the logit GEMM; the pipelined
    /// backend resolves its own pool from `PipelineConfig::num_threads`.
    pool: Arc<WorkerPool>,
}

impl Engine {
    pub fn new(weights: EngineWeights, backend: Backend) -> Engine {
        Engine::with_threads(weights, backend, 0)
    }

    /// Engine pinned to `num_threads` GEMM workers (0 = the process-global
    /// pool, i.e. every available core). Also aligns the pipelined
    /// backend's thread knob so both execution paths agree.
    pub fn with_threads(weights: EngineWeights, mut backend: Backend, num_threads: usize) -> Engine {
        if num_threads > 0 {
            if let Backend::BitmapPipelined(cfg) = &mut backend {
                cfg.num_threads = num_threads;
            }
        }
        Engine {
            weights,
            backend,
            pool: WorkerPool::with_threads(num_threads),
        }
    }

    /// Re-point the engine at an `num_threads`-wide pool (0 = global).
    pub fn set_threads(&mut self, num_threads: usize) {
        self.pool = WorkerPool::with_threads(num_threads);
        if let Backend::BitmapPipelined(cfg) = &mut self.backend {
            cfg.num_threads = num_threads;
        }
    }

    /// Execution contexts the engine's GEMMs use.
    pub fn num_threads(&self) -> usize {
        self.pool.threads()
    }

    fn linear(&self, w: &LinearW, x: &[f32], m: usize, out: &mut [f32]) {
        match (w, self.backend) {
            (LinearW::Dense(t), _) => {
                gemm_f32_pool(x, t.data(), out, m, t.rows(), t.cols(), &self.pool);
            }
            (LinearW::Salr(l), Backend::BitmapPipelined(cfg)) => {
                l.forward(x, m, out, cfg);
            }
            (LinearW::Salr(l), _) => {
                // Sequential: decode fully, then GEMM, then adapters — all
                // on the engine's pool so the thread knob is honored.
                let mut scratch = Vec::new();
                crate::gemm::sparse::bitmap_gemm_sequential_pool(
                    x, &l.w_hat, out, m, &mut scratch, &self.pool,
                );
                l.adapters.apply_fused_acc_pool(x, m, out, &self.pool);
            }
        }
    }

    /// Rotary position embedding, half-split layout — mirrors the L2 jax
    /// `_rope` exactly so logits agree with the HLO artifacts.
    fn apply_rope(x: &mut [f32], pos: &[usize], m: usize, heads: usize, hd: usize) {
        let half = hd / 2;
        for i in 0..m {
            let p = pos[i] as f32;
            for h in 0..heads {
                let base = i * heads * hd + h * hd;
                for j in 0..half {
                    let freq = 1.0 / 10000f32.powf(j as f32 / half as f32);
                    let (sin, cos) = (p * freq).sin_cos();
                    let a = x[base + j];
                    let b = x[base + half + j];
                    x[base + j] = a * cos - b * sin;
                    x[base + half + j] = a * sin + b * cos;
                }
            }
        }
    }

    fn rms_norm_rows(x: &mut [f32], gamma: &[f32], m: usize, d: usize) {
        for i in 0..m {
            let row = &mut x[i * d..(i + 1) * d];
            let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + 1e-5).sqrt();
            for (v, g) in row.iter_mut().zip(gamma) {
                *v = *v * inv * *g;
            }
        }
    }

    /// Process `m` token rows at absolute positions `pos[i]`, appending
    /// K/V to each sequence's caches and returning the hidden states.
    /// `caches[seq][layer]`.
    fn forward_rows(
        &self,
        tokens: &[i32],
        pos: &[usize],
        caches: &mut [Vec<KvCache>],
        seq_of_row: &[usize],
    ) -> Vec<f32> {
        let cfg = &self.weights.cfg;
        let (m, d) = (tokens.len(), cfg.d_model);
        let heads = cfg.n_heads;
        let hd = cfg.head_dim();
        // x = embed[token] + pos_embed[pos]
        let mut x = vec![0.0f32; m * d];
        for i in 0..m {
            let tok = tokens[i].clamp(0, cfg.vocab_size as i32 - 1) as usize;
            let erow = self.weights.embed.row(tok);
            let prow = self.weights.pos_embed.row(pos[i]);
            for j in 0..d {
                x[i * d + j] = erow[j] + prow[j];
            }
        }
        let mut h = vec![0.0f32; m * d];
        let mut q = vec![0.0f32; m * d];
        let mut k = vec![0.0f32; m * d];
        let mut v = vec![0.0f32; m * d];
        let mut att_out = vec![0.0f32; m * d];
        let mut ff = vec![0.0f32; m * cfg.d_ff];
        let mut ff_out = vec![0.0f32; m * d];
        for (li, layer) in self.weights.layers.iter().enumerate() {
            // --- attention ---
            h.copy_from_slice(&x);
            Self::rms_norm_rows(&mut h, &layer.attn_norm, m, d);
            self.linear(&layer.wq, &h, m, &mut q);
            self.linear(&layer.wk, &h, m, &mut k);
            self.linear(&layer.wv, &h, m, &mut v);
            // Rotary embedding on q/k (row layout [m, heads*hd] matches the
            // per-head slicing used below).
            Self::apply_rope(&mut q, pos, m, heads, hd);
            Self::apply_rope(&mut k, pos, m, heads, hd);
            // Append K/V to caches, then attend over each row's history.
            for i in 0..m {
                let c = &mut caches[seq_of_row[i]][li];
                debug_assert_eq!(c.len, pos[i], "cache length must equal position");
                c.push(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
            }
            let scale = (hd as f32).powf(-0.5);
            for i in 0..m {
                let c = &caches[seq_of_row[i]][li];
                // Causal: row i sees history up to and including its own
                // position (during prefill the cache already holds the
                // whole prompt, so clamp — no future leakage).
                let t_len = (pos[i] + 1).min(c.len);
                let qrow = &q[i * d..(i + 1) * d];
                let orow = &mut att_out[i * d..(i + 1) * d];
                orow.fill(0.0);
                for hix in 0..heads {
                    let qh = &qrow[hix * hd..(hix + 1) * hd];
                    // Scores over history.
                    let mut scores = Vec::with_capacity(t_len);
                    let mut maxs = f32::NEG_INFINITY;
                    for t in 0..t_len {
                        let kh = &c.key(t)[hix * hd..(hix + 1) * hd];
                        let s: f32 =
                            qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                        maxs = maxs.max(s);
                        scores.push(s);
                    }
                    let mut sum = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - maxs).exp();
                        sum += *s;
                    }
                    let inv = 1.0 / sum;
                    let oh = &mut orow[hix * hd..(hix + 1) * hd];
                    for t in 0..t_len {
                        let w = scores[t] * inv;
                        let vh = &c.value(t)[hix * hd..(hix + 1) * hd];
                        for j in 0..hd {
                            oh[j] += w * vh[j];
                        }
                    }
                }
            }
            self.linear(&layer.wo, &att_out, m, &mut h);
            for i in 0..m * d {
                x[i] += h[i];
            }
            // --- mlp ---
            h.copy_from_slice(&x);
            Self::rms_norm_rows(&mut h, &layer.mlp_norm, m, d);
            self.linear(&layer.w_in, &h, m, &mut ff);
            for f in ff.iter_mut() {
                *f = gelu(*f);
            }
            self.linear(&layer.w_out, &ff, m, &mut ff_out);
            for i in 0..m * d {
                x[i] += ff_out[i];
            }
        }
        Self::rms_norm_rows(&mut x, &self.weights.final_norm, m, d);
        x
    }

    /// Logits for hidden rows.
    fn logits(&self, hidden: &[f32], m: usize) -> Vec<f32> {
        let cfg = &self.weights.cfg;
        let mut out = vec![0.0f32; m * cfg.vocab_size];
        gemm_f32_pool(
            hidden,
            self.weights.lm_head.data(),
            &mut out,
            m,
            cfg.d_model,
            cfg.vocab_size,
            &self.pool,
        );
        out
    }

    /// Fresh per-layer caches for one sequence.
    pub fn new_caches(&self) -> Vec<KvCache> {
        let cfg = &self.weights.cfg;
        (0..cfg.n_layers)
            .map(|_| KvCache::new(cfg.max_seq_len, cfg.d_model))
            .collect()
    }

    /// Greedy generation for a batch of prompts. Prompts are prefilled
    /// token-sequentially per sequence; decode steps run the whole batch
    /// through the linears together (the m-row GEMMs the batcher feeds).
    pub fn generate_batch(&self, prompts: &[Vec<i32>], max_new: usize) -> Vec<Vec<i32>> {
        let cfg = &self.weights.cfg;
        let nseq = prompts.len();
        let mut caches: Vec<Vec<KvCache>> = (0..nseq).map(|_| self.new_caches()).collect();
        // Prefill each prompt (rows = prompt tokens of one sequence).
        let mut last_hidden: Vec<Vec<f32>> = Vec::with_capacity(nseq);
        for (s, prompt) in prompts.iter().enumerate() {
            assert!(!prompt.is_empty(), "empty prompt");
            assert!(
                prompt.len() + max_new <= cfg.max_seq_len,
                "prompt + generation exceeds max_seq_len"
            );
            let pos: Vec<usize> = (0..prompt.len()).collect();
            let rows = vec![s; prompt.len()];
            let hidden = self.forward_rows(prompt, &pos, &mut caches, &rows);
            let d = cfg.d_model;
            last_hidden.push(hidden[(prompt.len() - 1) * d..prompt.len() * d].to_vec());
        }
        // First sampled token per sequence.
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); nseq];
        let mut current: Vec<i32> = Vec::with_capacity(nseq);
        for s in 0..nseq {
            let lg = self.logits(&last_hidden[s], 1);
            current.push(argmax(&lg) as i32);
            outputs[s].push(current[s]);
        }
        // Batched decode steps.
        for _step in 1..max_new {
            let pos: Vec<usize> = (0..nseq).map(|s| caches[s][0].len).collect();
            let rows: Vec<usize> = (0..nseq).collect();
            let hidden = self.forward_rows(&current, &pos, &mut caches, &rows);
            let lg = self.logits(&hidden, nseq);
            for s in 0..nseq {
                let next =
                    argmax(&lg[s * cfg.vocab_size..(s + 1) * cfg.vocab_size]) as i32;
                current[s] = next;
                outputs[s].push(next);
            }
        }
        outputs
    }

    /// Full-sequence logits (no cache reuse) — the reference used by tests
    /// to compare against the HLO eval artifacts.
    pub fn full_logits(&self, tokens: &[i32]) -> Tensor {
        let mut caches = vec![self.new_caches()];
        let pos: Vec<usize> = (0..tokens.len()).collect();
        let rows = vec![0usize; tokens.len()];
        let hidden = self.forward_rows(tokens, &pos, &mut caches, &rows);
        let lg = self.logits(&hidden, tokens.len());
        Tensor::from_vec(&[tokens.len(), self.weights.cfg.vocab_size], lg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 24,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 8,
            batch_size: 2,
            ctx_keep: 0.5,
        }
    }

    #[test]
    fn kv_cached_generation_matches_full_forward() {
        let cfg = test_cfg();
        let mut rng = Rng::new(400);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let prompt: Vec<i32> = vec![10, 20, 30, 40];
        let gen = engine.generate_batch(&[prompt.clone()], 4);
        // Re-derive greedily using full (uncached) forwards.
        let mut toks = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..4 {
            let lg = engine.full_logits(&toks);
            let next = argmax(lg.row(toks.len() - 1)) as i32;
            want.push(next);
            toks.push(next);
        }
        assert_eq!(gen[0], want, "KV cache must not change the numbers");
    }

    #[test]
    fn salr_backend_matches_dense_when_merged() {
        let cfg = test_cfg();
        let mut rng = Rng::new(401);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        for (k, v) in build.residual_adapters.iter() {
            adapters.insert(k, v.clone());
        }
        // Dense engine over merged weights == SALR engine over factored.
        let mut merged = build.params.clone();
        for name in cfg.adapted_layers() {
            merge_adapters_into(&cfg, &adapters, &name, merged.get_mut(&name).unwrap());
        }
        let dense = Engine::new(
            EngineWeights::dense_merged(&cfg, &merged, None),
            Backend::Dense,
        );
        let salr = Engine::new(
            EngineWeights::salr(&cfg, &build.params, &adapters, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
        );
        let tokens: Vec<i32> = vec![5, 9, 13, 17, 21];
        let a = dense.full_logits(&tokens);
        let b = salr.full_logits(&tokens);
        let diff = crate::tensor::max_abs_diff(&a, &b);
        assert!(diff < 2e-2, "diff={diff}");
        // And generations agree.
        let ga = dense.generate_batch(&[tokens.clone()], 5);
        let gb = salr.generate_batch(&[tokens], 5);
        assert_eq!(ga, gb);
    }

    #[test]
    fn batched_equals_single_sequence() {
        let cfg = test_cfg();
        let mut rng = Rng::new(402);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine =
            Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let p1: Vec<i32> = vec![1, 2, 3];
        let p2: Vec<i32> = vec![50, 51, 52, 53, 54];
        let joint = engine.generate_batch(&[p1.clone(), p2.clone()], 4);
        let solo1 = engine.generate_batch(&[p1], 4);
        let solo2 = engine.generate_batch(&[p2], 4);
        assert_eq!(joint[0], solo1[0]);
        assert_eq!(joint[1], solo2[0]);
    }

    #[test]
    fn thread_knob_reaches_backend_and_pool() {
        let cfg = test_cfg();
        let mut rng = Rng::new(404);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let mut e = Engine::with_threads(
            EngineWeights::dense_merged(&cfg, &base, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
            3,
        );
        assert_eq!(e.num_threads(), 3);
        match e.backend {
            Backend::BitmapPipelined(c) => assert_eq!(c.num_threads, 3),
            _ => unreachable!(),
        }
        e.set_threads(2);
        assert_eq!(e.num_threads(), 2);
        // Generation still works on the resized pool.
        let gen = e.generate_batch(&[vec![1, 2, 3]], 2);
        assert_eq!(gen[0].len(), 2);
    }

    #[test]
    fn sparse_storage_smaller_than_dense() {
        // Needs realistic layer sizes: at d_model=32 the adapters dominate.
        let cfg = ModelCfg {
            d_model: 128,
            d_ff: 256,
            n_heads: 4,
            rank: 4,
            residual_rank: 8,
            ..test_cfg()
        };
        let mut rng = Rng::new(403);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 4);
        let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        for (k, v) in build.residual_adapters.iter() {
            adapters.insert(k, v.clone());
        }
        let dense = EngineWeights::dense_merged(&cfg, &base, None);
        let sparse = EngineWeights::salr(&cfg, &build.params, &adapters, None);
        assert!(sparse.linear_storage_bytes() < dense.linear_storage_bytes());
    }
}
