//! The transformer inference engine (KV-cached, batched greedy decode).
//!
//! Mirrors the L2 jax forward exactly (RMSNorm ε=1e-5, tanh-GELU, learned
//! positions, causal MHA) so logits agree with the `eval_*` HLO artifacts;
//! integration tests assert that agreement. The adapted linears dispatch
//! on [`Backend`]: dense merged weights (LoRA deployment) vs bitmap-sparse
//! + fused adapters through the two-stage pipeline (SALR deployment).
//!
//! Generation is exposed at two granularities:
//!
//! * [`Engine::generate_batch`] — decode a static batch to completion
//!   (experiments, eval, benches);
//! * [`Engine::prefill`] + [`Engine::decode_step`] over a
//!   [`KvSlotPool`] — one decode iteration at a time, with batch
//!   membership free to change between steps. This is the primitive the
//!   server's continuous-batching scheduler drives.
//!
//! Every per-sequence result is independent of which other sequences
//! share the batch: the linears compute each output row from its input
//! row alone (fixed k-accumulation order, row-band partitioning), RMSNorm
//! and attention are per-row, and greedy sampling is per-row argmax — so
//! a sequence's token stream is bitwise identical whether it decodes
//! alone, in a static batch, or in a continuously mutating batch.
//! `generate_batch` is itself implemented on the step API, and the server
//! integration tests assert the equivalence end to end.
//!
//! KV state is **paged**: attention walks each sequence's block chain
//! ([`KvView`](crate::infer::KvView)) instead of one flat buffer, and
//! with the prefix cache enabled [`Engine::prefill`] skips straight past
//! the cached head of a prompt — the skipped tokens' prefill GEMMs never
//! run, only the forward of the remaining tail (and the logit GEMM on the
//! final chunk). Cache hits replay bitwise-identical K/V rows, so the
//! token stream never depends on whether a prefix was cached.

use super::kv_cache::{KvCacheConfig, KvSlotPool};
use crate::gemm::dense::gemm_f32_pool;
use crate::gemm::pipeline::PipelineConfig;
use crate::util::arena::{scratch_undef, Scratch};
use crate::model::ParamStore;
use crate::prune::{prune_nm, NmPattern};
use crate::runtime::ModelCfg;
use crate::model::{WeightFormat, WeightStore};
use crate::salr::SalrLayer;
use crate::tensor::{argmax, gelu, Tensor};
use crate::util::pool::WorkerPool;
use std::sync::Arc;

/// How the adapted linears execute.
#[derive(Clone, Copy, Debug)]
pub enum Backend {
    /// Dense merged weights, blocked GEMM (the LoRA deployment).
    Dense,
    /// Bitmap decode + GEMM, sequential (ablation: no overlap).
    BitmapSequential,
    /// The paper's two-stage pipelined decode+GEMM.
    BitmapPipelined(PipelineConfig),
}

/// Result of one speculative [`Engine::decode_verify`] call.
///
/// The emitted token stream for the step is `draft[..accepted] ++ [next]`
/// — always at least one token, so decode progresses even when the whole
/// draft is rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Length of the longest draft prefix that matched greedy decode.
    pub accepted: usize,
    /// The greedy token after the accepted prefix: the correction on a
    /// mismatch, or the free bonus token when every draft was accepted.
    pub next: i32,
}

/// One adapted linear in deployment form.
enum LinearW {
    Dense(Tensor),
    Salr(SalrLayer),
}

impl LinearW {

    fn storage_bytes(&self) -> usize {
        match self {
            LinearW::Dense(w) => w.len() * 4,
            LinearW::Salr(l) => l.storage_bytes(),
        }
    }
}

struct LayerWeights {
    wq: LinearW,
    wk: LinearW,
    wv: LinearW,
    wo: LinearW,
    w_in: LinearW,
    w_out: LinearW,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
}

/// All deployed weights.
pub struct EngineWeights {
    /// Model geometry (shared with the training/eval side).
    pub cfg: ModelCfg,
    embed: Tensor,
    pos_embed: Tensor,
    lm_head: Tensor,
    final_norm: Vec<f32>,
    layers: Vec<LayerWeights>,
}

impl EngineWeights {
    /// Dense deployment: merge `W0 + s·A·B (+ A_res·B_res)` per layer.
    /// With `adapters = None` this is the raw (pre-finetune) model.
    pub fn dense_merged(
        cfg: &ModelCfg,
        base: &ParamStore,
        adapters: Option<&ParamStore>,
    ) -> EngineWeights {
        Self::build(cfg, base, |name, w| {
            let mut merged = w.clone();
            if let Some(ad) = adapters {
                merge_adapters_into(cfg, ad, name, &mut merged);
            }
            LinearW::Dense(merged)
        })
    }

    /// SALR deployment: compress the (pruned) base weights into the
    /// session's resident format (`SALR_WEIGHT_FORMAT`, default bitmap),
    /// keep the adapters factored and concatenated. `nm` optionally
    /// re-prunes to an N:M pattern first (the Table-4 2:4 protocol).
    pub fn salr(
        cfg: &ModelCfg,
        pruned_base: &ParamStore,
        adapters: &ParamStore,
        nm: Option<NmPattern>,
    ) -> EngineWeights {
        Self::salr_with_format(cfg, pruned_base, adapters, nm, WeightFormat::env_default())
    }

    /// [`EngineWeights::salr`] with an explicit resident weight format
    /// (the `--weight-format` CLI flag). With a compressed format the
    /// pruned base never exists as a resident dense f32 matrix: each
    /// linear's `Ŵ` is encoded straight into a [`WeightStore`] and the
    /// GEMM tiers decode it per tile/panel. `Nf4` additionally quantizes
    /// the kept values (lossy — tests comparing against a dense engine
    /// must pin `F32` or `Bitmap`).
    pub fn salr_with_format(
        cfg: &ModelCfg,
        pruned_base: &ParamStore,
        adapters: &ParamStore,
        nm: Option<NmPattern>,
        fmt: WeightFormat,
    ) -> EngineWeights {
        Self::build(cfg, pruned_base, |name, w| {
            let mut w_hat = w.clone();
            if let Some(pat) = nm {
                prune_nm(&mut w_hat, pat);
            }
            let la = adapters.get(&format!("{name}.lora_a")).expect("lora_a");
            let lb = adapters.get(&format!("{name}.lora_b")).expect("lora_b");
            let res = match (
                adapters.get(&format!("{name}.res_a")),
                adapters.get(&format!("{name}.res_b")),
            ) {
                (Some(a), Some(b)) => Some((a, b)),
                _ => None,
            };
            LinearW::Salr(SalrLayer::new(
                WeightStore::encode(&w_hat, fmt),
                la,
                lb,
                cfg.lora_scaling(),
                res,
            ))
        })
    }

    fn build(
        cfg: &ModelCfg,
        base: &ParamStore,
        mut make: impl FnMut(&str, &Tensor) -> LinearW,
    ) -> EngineWeights {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let lw = |lin: &str, make: &mut dyn FnMut(&str, &Tensor) -> LinearW| {
                let name = format!("layer{i}.{lin}");
                make(&name, base.get(&name).expect("linear"))
            };
            layers.push(LayerWeights {
                wq: lw("wq", &mut make),
                wk: lw("wk", &mut make),
                wv: lw("wv", &mut make),
                wo: lw("wo", &mut make),
                w_in: lw("w_in", &mut make),
                w_out: lw("w_out", &mut make),
                attn_norm: base
                    .get(&format!("layer{i}.attn_norm"))
                    .unwrap()
                    .data()
                    .to_vec(),
                mlp_norm: base
                    .get(&format!("layer{i}.mlp_norm"))
                    .unwrap()
                    .data()
                    .to_vec(),
            });
        }
        EngineWeights {
            cfg: cfg.clone(),
            embed: base.get("embed").unwrap().clone(),
            pos_embed: base.get("pos_embed").unwrap().clone(),
            lm_head: base.get("lm_head").unwrap().clone(),
            final_norm: base.get("final_norm").unwrap().data().to_vec(),
            layers,
        }
    }

    /// Deployment storage across the adapted linears (the Table-4 "model"
    /// that sparsity compresses).
    pub fn linear_storage_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wq.storage_bytes()
                    + l.wk.storage_bytes()
                    + l.wv.storage_bytes()
                    + l.wo.storage_bytes()
                    + l.w_in.storage_bytes()
                    + l.w_out.storage_bytes()
            })
            .sum()
    }
}

fn merge_adapters_into(cfg: &ModelCfg, adapters: &ParamStore, name: &str, w: &mut Tensor) {
    let s = cfg.lora_scaling();
    if let (Some(a), Some(b)) = (
        adapters.get(&format!("{name}.lora_a")),
        adapters.get(&format!("{name}.lora_b")),
    ) {
        let mut ab = crate::tensor::matmul(a, b);
        ab.scale(s);
        crate::tensor::axpy(w, 1.0, &ab);
    }
    if let (Some(a), Some(b)) = (
        adapters.get(&format!("{name}.res_a")),
        adapters.get(&format!("{name}.res_b")),
    ) {
        let ab = crate::tensor::matmul(a, b);
        crate::tensor::axpy(w, 1.0, &ab);
    }
}

/// The engine: weights + backend + the worker pool its GEMMs run on.
///
/// Weights are held behind an [`Arc`], so [`Engine::fork`] clones are
/// cheap: the server's engine workers share one copy of the deployed
/// model while each owning their own KV slots and (optionally) their own
/// slice of the machine's worker threads.
pub struct Engine {
    /// Deployed weights, shared by every fork of this engine.
    pub weights: Arc<EngineWeights>,
    /// How the adapted linears execute.
    pub backend: Backend,
    /// Pool every linear runs on: the dense GEMMs, the small-m sparse
    /// decode path, the logit GEMM *and* the pipelined prefill stages —
    /// `SalrLayer::forward` threads this pool through to the pipeline, so
    /// `--threads 1` ablations are apples-to-apples on every path.
    pool: Arc<WorkerPool>,
}

impl Engine {
    /// Engine on the process-global worker pool (every available core).
    pub fn new(weights: EngineWeights, backend: Backend) -> Engine {
        Engine::with_threads(weights, backend, 0)
    }

    /// Engine pinned to `num_threads` GEMM workers (0 = the process-global
    /// pool, i.e. every available core). Also aligns the pipelined
    /// backend's thread knob so both execution paths agree; `0` is kept
    /// as-is so both resolve to the *same* global pool instance rather
    /// than a duplicate full-width one.
    pub fn with_threads(
        weights: EngineWeights,
        mut backend: Backend,
        num_threads: usize,
    ) -> Engine {
        if num_threads > 0 {
            if let Backend::BitmapPipelined(cfg) = &mut backend {
                cfg.num_threads = num_threads;
            }
        }
        Engine {
            weights: Arc::new(weights),
            backend,
            pool: WorkerPool::with_threads(num_threads),
        }
    }

    /// Engine on an explicit (possibly private, un-registered) pool — the
    /// server gives each engine worker a disjoint share of the machine
    /// this way.
    pub fn with_pool(weights: EngineWeights, backend: Backend, pool: Arc<WorkerPool>) -> Engine {
        let mut e = Engine {
            weights: Arc::new(weights),
            backend,
            pool,
        };
        e.align_backend_threads();
        e
    }

    /// A second engine over the *same* weights (Arc-shared) with the same
    /// backend and pool. Forks are independent for everything mutable —
    /// KV slots, backend knobs, pool assignment.
    pub fn fork(&self) -> Engine {
        Engine {
            weights: self.weights.clone(),
            backend: self.backend,
            pool: self.pool.clone(),
        }
    }

    /// Re-point the engine at an `num_threads`-wide pool (0 = global).
    pub fn set_threads(&mut self, num_threads: usize) {
        self.pool = WorkerPool::with_threads(num_threads);
        if let Backend::BitmapPipelined(cfg) = &mut self.backend {
            cfg.num_threads = num_threads;
        }
    }

    /// Re-point the engine at an explicit pool (e.g. a private per-worker
    /// pool that is not in the global size registry).
    pub fn set_pool(&mut self, pool: Arc<WorkerPool>) {
        self.pool = pool;
        self.align_backend_threads();
    }

    /// Keep the pipelined backend's thread knob consistent with the
    /// engine pool so both execution paths use the same parallel width.
    fn align_backend_threads(&mut self) {
        let t = self.pool.threads();
        if let Backend::BitmapPipelined(cfg) = &mut self.backend {
            cfg.num_threads = t;
        }
    }

    /// Execution contexts the engine's GEMMs use.
    pub fn num_threads(&self) -> usize {
        self.pool.threads()
    }

    /// The worker pool the engine's linears run on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    fn linear(&self, w: &LinearW, x: &[f32], m: usize, out: &mut [f32]) {
        match (w, self.backend) {
            (LinearW::Dense(t), _) => {
                gemm_f32_pool(x, t.data(), out, m, t.rows(), t.cols(), &self.pool);
            }
            (LinearW::Salr(l), Backend::BitmapPipelined(cfg)) => {
                l.forward(x, m, out, cfg, &self.pool);
            }
            (LinearW::Salr(l), _) => {
                // Non-pipelined: the fused pack-decode blocked GEMM (the
                // base decodes per tile inside the B pack — no dense
                // scratch copy of Ŵ), then adapters — all on the engine's
                // pool so the thread knob is honored.
                l.adapters.apply_with_base_pool(x, &l.base, m, out, &self.pool);
            }
        }
    }

    /// The *draft* linear: sparse base only, adapters skipped. On a SALR
    /// deployment this is the paper-native cheap approximation that
    /// `forward_base_only` provides; on a Dense deployment the adapters
    /// are already merged into the weight, so the "base" degenerates to
    /// the full linear — self-drafting then drafts with the full model
    /// (every draft accepted, no speedup, still byte-correct). The spec
    /// harness exercises that degenerate path deliberately: correctness
    /// must not depend on the drafter being weaker than the verifier.
    fn linear_base(&self, w: &LinearW, x: &[f32], m: usize, out: &mut [f32]) {
        match w {
            LinearW::Dense(t) => {
                gemm_f32_pool(x, t.data(), out, m, t.rows(), t.cols(), &self.pool);
            }
            LinearW::Salr(l) => l.forward_base_only(x, m, out, &self.pool),
        }
    }

    /// Rotary position embedding, half-split layout — mirrors the L2 jax
    /// `_rope` exactly so logits agree with the HLO artifacts.
    fn apply_rope(x: &mut [f32], pos: &[usize], m: usize, heads: usize, hd: usize) {
        let half = hd / 2;
        for i in 0..m {
            let p = pos[i] as f32;
            for h in 0..heads {
                let base = i * heads * hd + h * hd;
                for j in 0..half {
                    let freq = 1.0 / 10000f32.powf(j as f32 / half as f32);
                    let (sin, cos) = (p * freq).sin_cos();
                    let a = x[base + j];
                    let b = x[base + half + j];
                    x[base + j] = a * cos - b * sin;
                    x[base + half + j] = a * sin + b * cos;
                }
            }
        }
    }

    fn rms_norm_rows(x: &mut [f32], gamma: &[f32], m: usize, d: usize) {
        for i in 0..m {
            let row = &mut x[i * d..(i + 1) * d];
            let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + 1e-5).sqrt();
            for (v, g) in row.iter_mut().zip(gamma) {
                *v = *v * inv * *g;
            }
        }
    }

    /// Process `m` token rows at absolute positions `pos[i]`, appending
    /// K/V to each sequence's block chain (`seq_of_row[i]` is row `i`'s
    /// KV slot) and returning the hidden states.
    ///
    /// Attention walks each sequence's **block table**: scores and the
    /// weighted value sum iterate the chain block by block, reading each
    /// block's populated rows as one contiguous slice — cached (shared)
    /// blocks and privately written ones are indistinguishable here, which
    /// is the core of the prefix-cache determinism argument.
    ///
    /// Every working buffer — hidden states, per-layer activations, the
    /// attention score row — is borrowed from the calling thread's scratch
    /// arena, so a steady-state decode loop performs no heap allocation in
    /// this function (the returned guard hands the hidden-state slab back
    /// when the caller drops it).
    ///
    /// `base_only = true` routes every adapted linear through
    /// [`Engine::linear_base`] (sparse base, fused adapters skipped) —
    /// the speculative self-drafting forward. Draft rows still append K/V
    /// (attention needs the chain to grow position by position); the
    /// drafter truncates them away before verification, so base-quality
    /// K/V never survives into verified state.
    fn forward_rows(
        &self,
        tokens: &[i32],
        pos: &[usize],
        kv: &mut KvSlotPool,
        seq_of_row: &[usize],
        base_only: bool,
    ) -> Scratch {
        let cfg = &self.weights.cfg;
        let (m, d) = (tokens.len(), cfg.d_model);
        let heads = cfg.n_heads;
        let hd = cfg.head_dim();
        // x = embed[token] + pos_embed[pos] — fully overwritten below, as
        // is every other scratch_undef checkout here (the linears
        // zero-fill or overwrite their outputs internally).
        let mut x = scratch_undef(m * d);
        for i in 0..m {
            let tok = tokens[i].clamp(0, cfg.vocab_size as i32 - 1) as usize;
            let erow = self.weights.embed.row(tok);
            let prow = self.weights.pos_embed.row(pos[i]);
            for j in 0..d {
                x[i * d + j] = erow[j] + prow[j];
            }
        }
        let mut h = scratch_undef(m * d);
        let mut q = scratch_undef(m * d);
        let mut k = scratch_undef(m * d);
        let mut v = scratch_undef(m * d);
        let mut att_out = scratch_undef(m * d);
        let mut ff = scratch_undef(m * cfg.d_ff);
        let mut ff_out = scratch_undef(m * d);
        // One score row shared by every (row, head): sized to the slot
        // capacity rather than the current history so the slab never
        // regrows as sequences lengthen mid-decode (after the push below,
        // row i attends over pos[i]+1 ≤ max_seq_len cached entries).
        let max_hist = pos.iter().map(|&p| p + 1).max().unwrap_or(0);
        let mut scores = scratch_undef(cfg.max_seq_len.max(max_hist));
        // Full vs draft-quality linears, chosen once for the whole forward.
        let lin = |w: &LinearW, x: &[f32], m: usize, out: &mut [f32]| {
            if base_only {
                self.linear_base(w, x, m, out);
            } else {
                self.linear(w, x, m, out);
            }
        };
        for (li, layer) in self.weights.layers.iter().enumerate() {
            // --- attention ---
            h.copy_from_slice(&x);
            Self::rms_norm_rows(&mut h, &layer.attn_norm, m, d);
            lin(&layer.wq, &h, m, &mut q);
            lin(&layer.wk, &h, m, &mut k);
            lin(&layer.wv, &h, m, &mut v);
            // Rotary embedding on q/k (row layout [m, heads*hd] matches the
            // per-head slicing used below).
            Self::apply_rope(&mut q, pos, m, heads, hd);
            Self::apply_rope(&mut k, pos, m, heads, hd);
            // Append K/V to each row's block chain, then attend over each
            // row's history.
            for i in 0..m {
                let slot = seq_of_row[i];
                debug_assert_eq!(
                    kv.layer_len(slot, li),
                    pos[i],
                    "cache length must equal position"
                );
                kv.push(slot, li, &k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
            }
            let scale = (hd as f32).powf(-0.5);
            for i in 0..m {
                // Causal: row i sees history up to and including its own
                // position (during prefill the cache already holds the
                // whole prompt, so clamp — no future leakage).
                let chain = kv.view(seq_of_row[i], li);
                let t_len = (pos[i] + 1).min(chain.len());
                let bs = chain.block_size();
                let qrow = &q[i * d..(i + 1) * d];
                let orow = &mut att_out[i * d..(i + 1) * d];
                orow.fill(0.0);
                for hix in 0..heads {
                    let qh = &qrow[hix * hd..(hix + 1) * hd];
                    // Scores over history, in the hoisted arena row —
                    // walking the chain one block of contiguous rows at a
                    // time (the final block may be partially filled).
                    let sc = &mut scores[..t_len];
                    let mut maxs = f32::NEG_INFINITY;
                    let (mut t, mut blk) = (0, 0);
                    while t < t_len {
                        let rows = bs.min(t_len - t);
                        let kb = chain.key_rows(blk, rows);
                        for r in 0..rows {
                            let kh = &kb[r * d + hix * hd..r * d + (hix + 1) * hd];
                            let s: f32 =
                                qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                            maxs = maxs.max(s);
                            sc[t] = s;
                            t += 1;
                        }
                        blk += 1;
                    }
                    let mut sum = 0.0f32;
                    for s in sc.iter_mut() {
                        *s = (*s - maxs).exp();
                        sum += *s;
                    }
                    let inv = 1.0 / sum;
                    let oh = &mut orow[hix * hd..(hix + 1) * hd];
                    let (mut t, mut blk) = (0, 0);
                    while t < t_len {
                        let rows = bs.min(t_len - t);
                        let vb = chain.value_rows(blk, rows);
                        for r in 0..rows {
                            let w = sc[t] * inv;
                            let vh = &vb[r * d + hix * hd..r * d + (hix + 1) * hd];
                            for j in 0..hd {
                                oh[j] += w * vh[j];
                            }
                            t += 1;
                        }
                        blk += 1;
                    }
                }
            }
            lin(&layer.wo, &att_out, m, &mut h);
            for i in 0..m * d {
                x[i] += h[i];
            }
            // --- mlp ---
            h.copy_from_slice(&x);
            Self::rms_norm_rows(&mut h, &layer.mlp_norm, m, d);
            lin(&layer.w_in, &h, m, &mut ff);
            for f in ff.iter_mut() {
                *f = gelu(*f);
            }
            lin(&layer.w_out, &ff, m, &mut ff_out);
            for i in 0..m * d {
                x[i] += ff_out[i];
            }
        }
        Self::rms_norm_rows(&mut x, &self.weights.final_norm, m, d);
        x
    }

    /// Logits for hidden rows, into a caller-provided `m × vocab` buffer
    /// (the GEMM zero-fills it). The decode path hands in arena scratch so
    /// the logit GEMM allocates nothing.
    fn logits_into(&self, hidden: &[f32], m: usize, out: &mut [f32]) {
        let cfg = &self.weights.cfg;
        gemm_f32_pool(
            hidden,
            self.weights.lm_head.data(),
            out,
            m,
            cfg.d_model,
            cfg.vocab_size,
            &self.pool,
        );
    }

    /// Logits for hidden rows (allocating convenience for the test /
    /// full-forward paths).
    fn logits(&self, hidden: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * self.weights.cfg.vocab_size];
        self.logits_into(hidden, m, &mut out);
        out
    }

    /// A KV slot pool sized for this engine (`slots` concurrent
    /// sequences, each with full-context block chains for every layer),
    /// configured by [`KvCacheConfig::env_default`].
    pub fn new_slot_pool(&self, slots: usize) -> KvSlotPool {
        self.new_slot_pool_with(slots, KvCacheConfig::env_default())
    }

    /// A KV slot pool with an explicit cache configuration — the serving
    /// layer routes its `--kv-block-size` / `--prefix-cache` knobs here.
    pub fn new_slot_pool_with(&self, slots: usize, cache: KvCacheConfig) -> KvSlotPool {
        let cfg = &self.weights.cfg;
        KvSlotPool::with_config(slots, cfg.n_layers, cfg.max_seq_len, cfg.d_model, cache)
    }

    /// Prefill `prompt` into `slot` of `kv` (which must be freshly
    /// allocated, i.e. empty) and greedily sample the sequence's first
    /// token. With the prefix cache enabled, the cached head of the
    /// prompt is attached instead of recomputed (its prefill GEMMs are
    /// skipped entirely) and the prompt's full blocks are registered for
    /// later requests; the forward then covers only the uncached tail.
    ///
    /// Implemented as a single [`Engine::prefill_chunk`]; panics if the
    /// prompt does not fit the slot — use `prefill_chunk` directly for the
    /// error-returning form (and [`KvSlotPool::attach_prefix`] /
    /// [`KvSlotPool::register_prefix`] for the cache hooks the batcher
    /// calls around its chunk loop).
    pub fn prefill(&self, prompt: &[i32], slot: usize, kv: &mut KvSlotPool) -> i32 {
        assert_eq!(kv.seq_len(slot), 0, "prefill into a non-empty slot");
        let hit = kv.attach_prefix(slot, prompt);
        let tok = self
            .prefill_chunk(&prompt[hit..], slot, kv, true)
            .expect("prompt fits the KV slot")
            .expect("final chunk yields a token");
        kv.register_prefix(slot, prompt);
        tok
    }

    /// Resumable prefill: append `chunk` prompt tokens to `slot`'s caches,
    /// continuing from whatever the slot already holds. The scheduler
    /// feeds a long prompt through repeated calls — bounded chunks — so
    /// running sequences keep taking decode steps between chunks instead
    /// of stalling behind one long prefill.
    ///
    /// Pass `last = true` on the final chunk to greedily sample the
    /// sequence's first generated token (`Ok(Some(tok))`); intermediate
    /// chunks skip the logit GEMM entirely and return `Ok(None)`.
    ///
    /// Determinism: every hidden row depends only on its own input row and
    /// the slot's cache prefix (per-row linears with fixed k-accumulation
    /// order, per-row norms/attention), so the token stream is identical
    /// whichever way the prompt is split into chunks — `prefill` is
    /// literally one maximal chunk. See DESIGN.md "Serving layer".
    ///
    /// Errors (instead of panicking) when the chunk is empty or would
    /// overflow the slot, so a mis-sized request costs the server an error
    /// reply, not an engine worker. On error the slot's caches are
    /// untouched; the caller decides whether to free the slot.
    ///
    /// Panic safety (the contract `Batcher::supervised_worker_loop`
    /// leans on): an unwind out of this call — an engine bug or an
    /// injected `SALR_FAULT` — may leave the slot's per-layer cache
    /// lengths inconsistent, but never corrupts the *pool*: block
    /// refcounts only move inside `KvSlotPool`'s own methods, so
    /// `KvSlotPool::free` afterwards releases the slot's chain exactly.
    pub fn prefill_chunk(
        &self,
        chunk: &[i32],
        slot: usize,
        kv: &mut KvSlotPool,
        last: bool,
    ) -> anyhow::Result<Option<i32>> {
        use anyhow::ensure;
        let cfg = &self.weights.cfg;
        ensure!(!chunk.is_empty(), "empty prefill chunk");
        let start = kv.seq_len(slot);
        ensure!(
            chunk.len() <= kv.remaining(slot) && start + chunk.len() <= cfg.max_seq_len,
            "prompt overflows KV slot: {} cached + {} new tokens > {} capacity",
            start,
            chunk.len(),
            cfg.max_seq_len.min(start + kv.remaining(slot)),
        );
        let pos: Vec<usize> = (start..start + chunk.len()).collect();
        let rows = vec![slot; chunk.len()];
        let hidden = self.forward_rows(chunk, &pos, kv, &rows, false);
        if !last {
            return Ok(None);
        }
        let d = cfg.d_model;
        let lastrow = &hidden[(chunk.len() - 1) * d..chunk.len() * d];
        let mut lg = scratch_undef(cfg.vocab_size);
        self.logits_into(lastrow, 1, &mut lg);
        Ok(Some(argmax(&lg) as i32))
    }

    /// One decode iteration for the sequences in `slots`: feed each
    /// sequence's `current` token at its cache position, append K/V, and
    /// return the next greedy token per sequence (same order as `slots`).
    ///
    /// The batch composition is free to change between calls — each
    /// output row depends only on its own input row and its own slot's
    /// cache, so admitting or retiring other sequences never changes a
    /// sequence's tokens (the continuous-batching determinism argument;
    /// see DESIGN.md "Serving layer").
    ///
    /// Every GEMM/decode buffer on this path (activations, logits, the
    /// sparse kernels' working sets) lives in the scratch arena: after a
    /// warmup step, the steady-state loop performs no heap allocation
    /// beyond the few-words-long position/token vectors.
    ///
    /// Panic safety: same contract as [`Engine::prefill_chunk`] — an
    /// unwind mid-step can leave the stepped slots' per-layer lengths
    /// inconsistent (some layers appended, some not) but block
    /// accounting intact, so the supervisor's `KvSlotPool::free` per
    /// in-flight slot restores the pool exactly.
    pub fn decode_step(&self, current: &[i32], slots: &[usize], kv: &mut KvSlotPool) -> Vec<i32> {
        let cfg = &self.weights.cfg;
        let m = current.len();
        assert_eq!(m, slots.len(), "one slot per sequence");
        if m == 0 {
            return Vec::new();
        }
        let pos: Vec<usize> = slots.iter().map(|&s| kv.seq_len(s)).collect();
        let hidden = self.forward_rows(current, &pos, kv, slots, false);
        let mut lg = scratch_undef(m * cfg.vocab_size);
        self.logits_into(&hidden, m, &mut lg);
        (0..m)
            .map(|i| argmax(&lg[i * cfg.vocab_size..(i + 1) * cfg.vocab_size]) as i32)
            .collect()
    }

    /// Speculatively verify `draft` for one sequence: a single batched
    /// forward over `[current, draft…]`, greedy-checked position by
    /// position, with the KV chain rolled back to exactly the accepted
    /// length.
    ///
    /// Exactness argument (the byte-identity invariant the spec suite
    /// pins): the forward feeds `current` at the slot's frontier and each
    /// drafted token at the following positions — identical inputs, at
    /// identical positions, over an identical cache prefix, to what a
    /// sequential [`Engine::decode_step`] chain would feed, because
    /// attention row `i` only sees rows `≤ i` (the causal clamp) and
    /// every linear/norm is per-row. Row `i`'s argmax `g_i` is therefore
    /// *the* greedy token after `draft[..i]`; we accept `draft[i]` while
    /// it equals `g_i` and stop at the first mismatch, so the emitted
    /// stream `draft[..accepted] ++ [next]` is bitwise what sequential
    /// decode emits — for any draft from any source, correct or garbage.
    ///
    /// KV rollback: the forward appended `1 + draft.len()` rows per
    /// layer, but only `current` and the accepted drafts are real history
    /// — the chain is truncated to `pre + 1 + accepted`, releasing
    /// now-dead private tail blocks (COW guarantees the speculative rows
    /// were never written into shared prefix blocks; see
    /// [`KvSlotPool::truncate`]). Rejected-token K/V thus never pollutes
    /// later attention, and the slot's block accounting is exact.
    ///
    /// Each call emits `accepted + 1` tokens (`≥ 1`: the corrected token
    /// always lands, so decode progresses even on total rejection —
    /// `accepted == draft.len()` means every draft matched and `next` is
    /// the bonus token from the final row). The caller must leave
    /// headroom: `1 + draft.len() ≤ kv.remaining(slot)`.
    ///
    /// Panic safety: same contract as [`Engine::decode_step`] — an unwind
    /// leaves lengths inconsistent but block accounting intact, so the
    /// supervisor's `KvSlotPool::free` restores the pool exactly.
    pub fn decode_verify(
        &self,
        current: i32,
        draft: &[i32],
        slot: usize,
        kv: &mut KvSlotPool,
    ) -> VerifyOutcome {
        let cfg = &self.weights.cfg;
        let m = 1 + draft.len();
        assert!(
            m <= kv.remaining(slot),
            "verify batch overflows the KV slot"
        );
        let pre = kv.seq_len(slot);
        let mut tokens = Vec::with_capacity(m);
        tokens.push(current);
        tokens.extend_from_slice(draft);
        let pos: Vec<usize> = (pre..pre + m).collect();
        let rows = vec![slot; m];
        let hidden = self.forward_rows(&tokens, &pos, kv, &rows, false);
        let mut lg = scratch_undef(m * cfg.vocab_size);
        self.logits_into(&hidden, m, &mut lg);
        let greedy =
            |i: usize| argmax(&lg[i * cfg.vocab_size..(i + 1) * cfg.vocab_size]) as i32;
        let mut accepted = 0;
        while accepted < draft.len() && greedy(accepted) == draft[accepted] {
            accepted += 1;
        }
        let next = greedy(accepted);
        kv.truncate(slot, pre + 1 + accepted);
        VerifyOutcome { accepted, next }
    }

    /// Draft `k` tokens for one sequence with the sparse-base-only
    /// forward (adapters skipped — the paper's cheap approximation of the
    /// full model), leaving the KV chain exactly as found.
    ///
    /// Runs `k` sequential single-row base-only forwards, chaining each
    /// argmax into the next position. The draft rows' K/V is
    /// base-quality, so it is truncated away before returning — the
    /// subsequent [`Engine::decode_verify`] recomputes those positions
    /// with the full model. On a Dense backend the base *is* the full
    /// model (adapters merged), so drafts are simply correct; the
    /// degenerate case costs speed, never bytes.
    pub fn draft_self(
        &self,
        current: i32,
        k: usize,
        slot: usize,
        kv: &mut KvSlotPool,
    ) -> Vec<i32> {
        let cfg = &self.weights.cfg;
        assert!(k <= kv.remaining(slot), "draft overflows the KV slot");
        let pre = kv.seq_len(slot);
        let mut draft = Vec::with_capacity(k);
        let mut cur = current;
        let mut lg = scratch_undef(cfg.vocab_size);
        for i in 0..k {
            let hidden = self.forward_rows(&[cur], &[pre + i], kv, &[slot], true);
            self.logits_into(&hidden, 1, &mut lg);
            cur = argmax(&lg) as i32;
            draft.push(cur);
        }
        kv.truncate(slot, pre);
        draft
    }

    /// Greedy generation for a static batch of prompts, decoded to
    /// completion (every sequence gets exactly `max_new` tokens).
    ///
    /// Implemented on the step API: prompts are prefilled sequentially
    /// per sequence, then every decode step runs the whole batch through
    /// the linears together (the m-row GEMMs the batcher feeds).
    pub fn generate_batch(&self, prompts: &[Vec<i32>], max_new: usize) -> Vec<Vec<i32>> {
        let cfg = &self.weights.cfg;
        let nseq = prompts.len();
        let mut kv = self.new_slot_pool(nseq);
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); nseq];
        let mut current: Vec<i32> = Vec::with_capacity(nseq);
        let slots: Vec<usize> = prompts
            .iter()
            .map(|prompt| {
                assert!(
                    prompt.len() + max_new <= cfg.max_seq_len,
                    "prompt + generation exceeds max_seq_len"
                );
                kv.alloc().expect("slot pool sized for the batch")
            })
            .collect();
        for (s, prompt) in prompts.iter().enumerate() {
            let first = self.prefill(prompt, slots[s], &mut kv);
            current.push(first);
            outputs[s].push(first);
        }
        for _step in 1..max_new {
            let next = self.decode_step(&current, &slots, &mut kv);
            for s in 0..nseq {
                current[s] = next[s];
                outputs[s].push(next[s]);
            }
        }
        outputs
    }

    /// Full-sequence logits (no cache reuse) — the reference used by tests
    /// to compare against the HLO eval artifacts. Runs over a throwaway
    /// single-slot, single-block, prefix-cache-off pool, so its numbers
    /// are independent of any serving-cache configuration.
    pub fn full_logits(&self, tokens: &[i32]) -> Tensor {
        let cfg = &self.weights.cfg;
        let mut kv = KvSlotPool::with_config(
            1,
            cfg.n_layers,
            tokens.len().max(1),
            cfg.d_model,
            KvCacheConfig {
                block_size: tokens.len().max(1),
                prefix_cache: false,
                extra_blocks: 0,
            },
        );
        let slot = kv.alloc().expect("fresh pool has a slot");
        let pos: Vec<usize> = (0..tokens.len()).collect();
        let rows = vec![slot; tokens.len()];
        let hidden = self.forward_rows(tokens, &pos, &mut kv, &rows, false);
        let lg = self.logits(&hidden, tokens.len());
        Tensor::from_vec(&[tokens.len(), self.weights.cfg.vocab_size], lg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 24,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 8,
            batch_size: 2,
            ctx_keep: 0.5,
        }
    }

    #[test]
    fn kv_cached_generation_matches_full_forward() {
        let cfg = test_cfg();
        let mut rng = Rng::new(400);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let prompt: Vec<i32> = vec![10, 20, 30, 40];
        let gen = engine.generate_batch(&[prompt.clone()], 4);
        // Re-derive greedily using full (uncached) forwards.
        let mut toks = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..4 {
            let lg = engine.full_logits(&toks);
            let next = argmax(lg.row(toks.len() - 1)) as i32;
            want.push(next);
            toks.push(next);
        }
        assert_eq!(gen[0], want, "KV cache must not change the numbers");
    }

    #[test]
    fn salr_backend_matches_dense_when_merged() {
        let cfg = test_cfg();
        let mut rng = Rng::new(401);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        for (k, v) in build.residual_adapters.iter() {
            adapters.insert(k, v.clone());
        }
        // Dense engine over merged weights == SALR engine over factored.
        let mut merged = build.params.clone();
        for name in cfg.adapted_layers() {
            merge_adapters_into(&cfg, &adapters, &name, merged.get_mut(&name).unwrap());
        }
        let dense = Engine::new(
            EngineWeights::dense_merged(&cfg, &merged, None),
            Backend::Dense,
        );
        // Pinned to the (lossless) bitmap format: this test compares SALR
        // numerically against a dense-merged engine, so it must not pick
        // up a lossy NF4 default from the CI matrix's SALR_WEIGHT_FORMAT.
        let salr = Engine::new(
            EngineWeights::salr_with_format(
                &cfg,
                &build.params,
                &adapters,
                None,
                WeightFormat::Bitmap,
            ),
            Backend::BitmapPipelined(PipelineConfig::default()),
        );
        let tokens: Vec<i32> = vec![5, 9, 13, 17, 21];
        let a = dense.full_logits(&tokens);
        let b = salr.full_logits(&tokens);
        let diff = crate::tensor::max_abs_diff(&a, &b);
        assert!(diff < 2e-2, "diff={diff}");
        // And generations agree.
        let ga = dense.generate_batch(&[tokens.clone()], 5);
        let gb = salr.generate_batch(&[tokens], 5);
        assert_eq!(ga, gb);
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        // Splitting the prompt into chunks of any size must not change a
        // single bit of the sequence's token stream: per-row linears plus
        // per-row attention over the cache prefix make `prefill` one
        // maximal chunk.
        let cfg = test_cfg();
        let mut rng = Rng::new(410);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
        let reference = engine.generate_batch(&[prompt.clone()], 6)[0].clone();
        for &chunk in &[1usize, 2, 3, 5, prompt.len()] {
            let mut kv = engine.new_slot_pool(1);
            let slot = kv.alloc().unwrap();
            let mut fed = 0;
            let mut first = None;
            while fed < prompt.len() {
                let take = chunk.min(prompt.len() - fed);
                let last = fed + take == prompt.len();
                first = engine
                    .prefill_chunk(&prompt[fed..fed + take], slot, &mut kv, last)
                    .unwrap();
                fed += take;
            }
            let mut out = vec![first.expect("final chunk samples")];
            for _ in 1..6 {
                let next = engine.decode_step(&[*out.last().unwrap()], &[slot], &mut kv);
                out.push(next[0]);
            }
            assert_eq!(out, reference, "chunk={chunk} changed the tokens");
            kv.free(slot);
        }
    }

    #[test]
    fn overlong_prompt_is_rejected_not_panicking() {
        let cfg = test_cfg(); // max_seq_len = 24
        let mut rng = Rng::new(411);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let mut kv = engine.new_slot_pool(1);
        let slot = kv.alloc().unwrap();
        // Whole prompt longer than the slot: error, caches untouched.
        let long = vec![1i32; cfg.max_seq_len + 1];
        assert!(engine.prefill_chunk(&long, slot, &mut kv, true).is_err());
        assert_eq!(kv.seq_len(slot), 0, "failed prefill must not touch the cache");
        // Mid-prefill overflow: first chunk fits, the next would not.
        let head = vec![2i32; cfg.max_seq_len - 2];
        assert!(engine.prefill_chunk(&head, slot, &mut kv, false).is_ok());
        assert!(engine.prefill_chunk(&[1, 2, 3], slot, &mut kv, true).is_err());
        assert!(engine.prefill_chunk(&[], slot, &mut kv, true).is_err());
        // The slot is still usable after freeing: alloc resets lengths and
        // a normal sequence decodes to the same tokens as a fresh engine.
        kv.free(slot);
        let again = kv.alloc().unwrap();
        assert_eq!(again, slot);
        let prompt: Vec<i32> = vec![7, 8, 9];
        let first = engine.prefill(&prompt, again, &mut kv);
        assert_eq!(first, engine.generate_batch(&[prompt], 1)[0][0]);
    }

    #[test]
    fn batched_equals_single_sequence() {
        let cfg = test_cfg();
        let mut rng = Rng::new(402);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine =
            Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let p1: Vec<i32> = vec![1, 2, 3];
        let p2: Vec<i32> = vec![50, 51, 52, 53, 54];
        let joint = engine.generate_batch(&[p1.clone(), p2.clone()], 4);
        let solo1 = engine.generate_batch(&[p1], 4);
        let solo2 = engine.generate_batch(&[p2], 4);
        assert_eq!(joint[0], solo1[0]);
        assert_eq!(joint[1], solo2[0]);
    }

    #[test]
    fn thread_knob_reaches_backend_and_pool() {
        let cfg = test_cfg();
        let mut rng = Rng::new(404);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let mut e = Engine::with_threads(
            EngineWeights::dense_merged(&cfg, &base, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
            3,
        );
        assert_eq!(e.num_threads(), 3);
        match e.backend {
            Backend::BitmapPipelined(c) => assert_eq!(c.num_threads, 3),
            _ => unreachable!(),
        }
        e.set_threads(2);
        assert_eq!(e.num_threads(), 2);
        // Generation still works on the resized pool.
        let gen = e.generate_batch(&[vec![1, 2, 3]], 2);
        assert_eq!(gen[0].len(), 2);
    }

    #[test]
    fn step_api_with_changing_membership_matches_static_batches() {
        // Continuous-batching determinism: a sequence decoded while batch
        // membership churns around it produces exactly the tokens it
        // produces alone.
        let cfg = test_cfg();
        let mut rng = Rng::new(405);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine =
            Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let p1: Vec<i32> = vec![1, 2, 3];
        let p2: Vec<i32> = vec![50, 51, 52, 53];
        let p3: Vec<i32> = vec![9, 8];
        let solo1 = engine.generate_batch(&[p1.clone()], 5)[0].clone();
        let solo2 = engine.generate_batch(&[p2.clone()], 4)[0].clone();
        let solo3 = engine.generate_batch(&[p3.clone()], 3)[0].clone();

        // Drive the step API by hand: start seq1, admit seq2 after two
        // steps, retire seq2 early, admit seq3 into seq2's freed slot.
        let mut kv = engine.new_slot_pool(2);
        let s1 = kv.alloc().unwrap();
        let mut out1 = vec![engine.prefill(&p1, s1, &mut kv)];
        for _ in 0..2 {
            let next = engine.decode_step(&[*out1.last().unwrap()], &[s1], &mut kv);
            out1.push(next[0]);
        }
        let s2 = kv.alloc().unwrap();
        let mut out2 = vec![engine.prefill(&p2, s2, &mut kv)];
        for _ in 0..3 {
            let cur = [*out1.last().unwrap(), *out2.last().unwrap()];
            let next = engine.decode_step(&cur, &[s1, s2], &mut kv);
            // seq1 hits its 5-token budget after the second joint step.
            if out1.len() < 5 {
                out1.push(next[0]);
            }
            out2.push(next[1]);
        }
        kv.free(s1);
        let s3 = kv.alloc().unwrap();
        assert_eq!(s3, s1, "freed KV slot must be reused");
        let mut out3 = vec![engine.prefill(&p3, s3, &mut kv)];
        for _ in 0..2 {
            let cur = [*out3.last().unwrap()];
            let next = engine.decode_step(&cur, &[s3], &mut kv);
            out3.push(next[0]);
        }
        assert_eq!(out1, solo1, "seq1 tokens changed under churn");
        assert_eq!(out2, solo2, "seq2 tokens changed under churn");
        assert_eq!(out3, solo3, "seq3 tokens changed in a reused slot");
    }

    #[test]
    fn engine_uses_the_configured_pool() {
        // `with_pool` must wire the exact pool instance through to the
        // linears (SalrLayer::forward takes it by reference now — no
        // global-registry lookup on the small-m decode path).
        let cfg = test_cfg();
        let mut rng = Rng::new(406);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        let private = Arc::new(WorkerPool::new(3));
        let engine = Engine::with_pool(
            EngineWeights::salr(&cfg, &build.params, &adapters, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
            private.clone(),
        );
        assert!(Arc::ptr_eq(engine.pool(), &private));
        assert_eq!(engine.num_threads(), 3);
        match engine.backend {
            Backend::BitmapPipelined(c) => assert_eq!(c.num_threads, 3),
            _ => unreachable!(),
        }
        // Decode (small-m SALR path) runs fine on the private pool and
        // matches the same engine on the global pool.
        let reference = Engine::new(
            EngineWeights::salr(&cfg, &build.params, &adapters, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
        );
        let prompt: Vec<i32> = vec![4, 9, 14];
        assert_eq!(
            engine.generate_batch(&[prompt.clone()], 4),
            reference.generate_batch(&[prompt], 4)
        );
    }

    fn salr_engine(threads: usize, seed: u64) -> Engine {
        let cfg = test_cfg();
        let mut rng = Rng::new(seed);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        Engine::with_pool(
            EngineWeights::salr(&cfg, &build.params, &adapters, None),
            Backend::BitmapPipelined(PipelineConfig::default()),
            Arc::new(WorkerPool::new(threads)),
        )
    }

    fn salr_engine_fmt(threads: usize, seed: u64, fmt: WeightFormat) -> Engine {
        let cfg = test_cfg();
        let mut rng = Rng::new(seed);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        Engine::with_pool(
            EngineWeights::salr_with_format(&cfg, &build.params, &adapters, None, fmt),
            Backend::BitmapPipelined(PipelineConfig::default()),
            Arc::new(WorkerPool::new(threads)),
        )
    }

    #[test]
    fn steady_state_decode_does_not_grow_the_arena() {
        // The PR's zero-allocation acceptance bar: after ONE warmup
        // decode step, repeated decode_step calls must not grow the
        // scratch arena — every GEMM/decode buffer (activations, the
        // direct kernel's transposed working set, adapter intermediates,
        // logits, attention scores) is slab-resident. A 1-thread engine
        // pool keeps every checkout on this test's thread, so the
        // thread-local counter sees the whole path.
        let engine = salr_engine(1, 408);
        let mut kv = engine.new_slot_pool(3);
        let slots: Vec<usize> = (0..3).map(|_| kv.alloc().unwrap()).collect();
        let mut current: Vec<i32> = Vec::new();
        for (s, prompt) in [vec![1i32, 2, 3], vec![9, 8], vec![4, 4, 4, 4]].iter().enumerate() {
            current.push(engine.prefill(prompt, slots[s], &mut kv));
        }
        // One warmup step sizes the slabs for this batch geometry.
        current = engine.decode_step(&current, &slots, &mut kv);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            current = engine.decode_step(&current, &slots, &mut kv);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "decode_step allocated arena slabs in steady state"
        );
    }

    #[test]
    fn steady_state_decode_zero_alloc_with_tracing_enabled() {
        // The observability acceptance bar: tracing must not perturb the
        // zero-allocation decode loop. Span recording writes into
        // pre-sized per-thread rings (seqlock slots, no Vec growth) and
        // never touches the scratch arena, so the same steady-state
        // counter check as above must hold with tracing on and a live
        // trace context. The ring itself is heap-allocated once at lazy
        // registration — the warmup step (run with tracing already
        // enabled) covers that, exactly like it covers slab sizing.
        crate::util::trace::set_enabled(true);
        let engine = salr_engine(1, 433);
        let mut kv = engine.new_slot_pool(2);
        let slots: Vec<usize> = (0..2).map(|_| kv.alloc().unwrap()).collect();
        let mut current: Vec<i32> = Vec::new();
        for (s, prompt) in [vec![2i32, 7, 1], vec![8, 2, 8]].iter().enumerate() {
            current.push(crate::util::trace::with_trace(0xA11C_E700 + s as u64, || {
                engine.prefill(prompt, slots[s], &mut kv)
            }));
        }
        current = engine.decode_step(&current, &slots, &mut kv);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            current = crate::util::trace::with_trace(0xA11C_E7FF, || {
                engine.decode_step(&current, &slots, &mut kv)
            });
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "decode_step with tracing enabled allocated arena slabs in steady state"
        );
        // And the kernel tiers actually recorded under the trace context.
        let spans = crate::util::trace::spans_for(0xA11C_E7FF);
        assert!(
            spans
                .iter()
                .any(|(_, s)| s.kind == crate::util::trace::TraceKind::GemmCall),
            "traced decode steps must record gemm_call spans"
        );
    }

    #[test]
    fn steady_state_decode_zero_alloc_on_wide_pool() {
        // Same bar with a 4-thread engine pool and a single sequence: the
        // direct kernel's column stripes borrow the caller's working set
        // (they check nothing out themselves), so the caller-side counter
        // still covers every slab on the path.
        let engine = salr_engine(4, 409);
        let mut kv = engine.new_slot_pool(1);
        let slot = kv.alloc().unwrap();
        let mut cur = vec![engine.prefill(&[5, 6, 7], slot, &mut kv)];
        cur = engine.decode_step(&cur, &[slot], &mut kv);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            cur = engine.decode_step(&cur, &[slot], &mut kv);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "wide-pool decode allocated caller-side arena slabs"
        );
    }

    #[test]
    fn prefix_cache_skips_prefill_without_changing_tokens() {
        // Requests sharing a prompt head must produce byte-identical
        // token streams with the prefix cache on and off — at several
        // block sizes, sequentially (retire-then-reuse) and with both
        // sequences live at once (shared immutable blocks + private
        // tails) — while the hit counter proves prefill work was skipped.
        let cfg = test_cfg();
        let mut rng = Rng::new(412);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        // 16-token shared head (≥ one block at every size below) + 2-token
        // tails; 18 prompt + 4 generated tokens fit max_seq_len = 24.
        let head: Vec<i32> = vec![7, 3, 9, 1, 4, 4, 2, 8, 6, 1, 9, 2, 5, 5, 3, 7];
        let mut p1 = head.clone();
        p1.extend([5, 6]);
        let mut p2 = head.clone();
        p2.extend([11, 12]);
        let prompts = [p1.clone(), p2.clone(), p1.clone()];

        let run = |block_size: usize, prefix_cache: bool| {
            let cache = KvCacheConfig {
                block_size,
                prefix_cache,
                extra_blocks: 0,
            };
            let mut kv = engine.new_slot_pool_with(prompts.len(), cache);
            let mut outs = Vec::new();
            for p in &prompts {
                let slot = kv.alloc().unwrap();
                let mut toks = vec![engine.prefill(p, slot, &mut kv)];
                for _ in 1..4 {
                    let next = engine.decode_step(&[*toks.last().unwrap()], &[slot], &mut kv);
                    toks.push(next[0]);
                }
                outs.push(toks);
                kv.free(slot);
            }
            (outs, kv.prefix_hit_tokens())
        };

        let (reference, cold_hits) = run(4, false);
        assert_eq!(cold_hits, 0, "cache off must never hit");
        for &bs in &[3usize, 4, 16] {
            let (outs, hits) = run(bs, true);
            assert_eq!(outs, reference, "block_size={bs} changed the tokens");
            assert!(
                hits > 0,
                "block_size={bs}: shared heads must be served from cache"
            );
        }
        // Both sequences live at once: the second attaches the first's
        // registered head while the first keeps decoding into its private
        // tail. Joint decode must match the sequential reference.
        let cache = KvCacheConfig {
            block_size: 4,
            prefix_cache: true,
            extra_blocks: 0,
        };
        let mut kv = engine.new_slot_pool_with(2, cache);
        let s1 = kv.alloc().unwrap();
        let s2 = kv.alloc().unwrap();
        let mut o1 = vec![engine.prefill(&p1, s1, &mut kv)];
        let mut o2 = vec![engine.prefill(&p2, s2, &mut kv)];
        assert!(kv.prefix_hit_tokens() >= 8, "p2 must attach p1's head");
        for _ in 1..4 {
            let next = engine.decode_step(
                &[*o1.last().unwrap(), *o2.last().unwrap()],
                &[s1, s2],
                &mut kv,
            );
            o1.push(next[0]);
            o2.push(next[1]);
        }
        assert_eq!(vec![o1, o2], reference[..2].to_vec());
    }

    #[test]
    fn fork_shares_weights() {
        let cfg = test_cfg();
        let mut rng = Rng::new(407);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine =
            Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let fork = engine.fork();
        assert!(Arc::ptr_eq(&engine.weights, &fork.weights));
        let p: Vec<i32> = vec![7, 7, 7];
        assert_eq!(
            engine.generate_batch(&[p.clone()], 3),
            fork.generate_batch(&[p], 3)
        );
    }

    #[test]
    fn decode_verify_matches_sequential_decode_for_any_draft() {
        // The exactness core: whatever the draft source proposes —
        // correct continuations, garbage, or a half-right mix — the
        // emitted stream must be bitwise the sequential greedy stream,
        // and the KV chain must land at exactly the emitted length.
        let cfg = test_cfg();
        let mut rng = Rng::new(420);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let engine = Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense);
        let prompt: Vec<i32> = vec![3, 14, 15, 9];
        let max_new = 8;
        let want = engine.generate_batch(&[prompt.clone()], max_new)[0].clone();
        for k in [1usize, 2, 4] {
            for policy in 0..3 {
                let mut kv = engine.new_slot_pool(1);
                let slot = kv.alloc().unwrap();
                let mut out = vec![engine.prefill(&prompt, slot, &mut kv)];
                let (mut drafted, mut accepted) = (0usize, 0usize);
                while out.len() < max_new {
                    // The batcher's clamp: emitted = accepted+1 ≤ kk+1
                    // can never push out past the budget or the slot.
                    let kk = k
                        .min(max_new - out.len() - 1)
                        .min(kv.remaining(slot) - 1);
                    let cur = *out.last().unwrap();
                    // `want[out.len()..]` is the true continuation of cur.
                    let truth = &want[out.len()..(out.len() + kk).min(want.len())];
                    let draft: Vec<i32> = match policy {
                        0 => truth.to_vec(),
                        1 => truth.iter().map(|t| (t + 1) % 64).collect(),
                        _ => truth
                            .iter()
                            .enumerate()
                            .map(|(i, t)| if i % 2 == 0 { *t } else { (t + 1) % 64 })
                            .collect(),
                    };
                    let v = engine.decode_verify(cur, &draft, slot, &mut kv);
                    assert!(v.accepted <= draft.len());
                    drafted += draft.len();
                    accepted += v.accepted;
                    out.extend_from_slice(&draft[..v.accepted]);
                    out.push(v.next);
                    assert_eq!(
                        kv.seq_len(slot),
                        prompt.len() + out.len() - 1,
                        "rollback must land on the emitted length"
                    );
                }
                assert_eq!(out, want, "k={k} policy={policy} changed the bytes");
                assert!(accepted <= drafted);
                if policy == 0 {
                    assert_eq!(accepted, drafted, "correct drafts must all land");
                }
                if policy == 1 && k > 0 {
                    assert_eq!(accepted, 0, "wrong-first drafts must all reject");
                }
                kv.free(slot);
                assert_eq!(kv.blocks_in_use(), 0, "speculation leaked blocks");
            }
        }
    }

    #[test]
    fn self_drafted_speculation_is_byte_identical_on_the_salr_backend() {
        // End-to-end paper-native speculation: sparse-base drafts, full
        // SALR verify. The draft pass must leave the chain exactly as
        // found (its base-quality K/V truncated away), and the stream
        // must match plain sequential decode bitwise.
        let engine = salr_engine(2, 421);
        let prompt: Vec<i32> = vec![5, 9, 13];
        let max_new = 8;
        let want = engine.generate_batch(&[prompt.clone()], max_new)[0].clone();
        for k in [1usize, 2, 4] {
            let mut kv = engine.new_slot_pool(1);
            let slot = kv.alloc().unwrap();
            let mut out = vec![engine.prefill(&prompt, slot, &mut kv)];
            while out.len() < max_new {
                let kk = k
                    .min(max_new - out.len() - 1)
                    .min(kv.remaining(slot) - 1);
                let cur = *out.last().unwrap();
                let pre = kv.seq_len(slot);
                let draft = engine.draft_self(cur, kk, slot, &mut kv);
                assert_eq!(draft.len(), kk);
                assert_eq!(kv.seq_len(slot), pre, "drafting must not grow the chain");
                let v = engine.decode_verify(cur, &draft, slot, &mut kv);
                out.extend_from_slice(&draft[..v.accepted]);
                out.push(v.next);
            }
            assert_eq!(out, want, "k={k}: self-drafting changed the bytes");
            kv.free(slot);
            assert_eq!(kv.blocks_in_use(), 0);
        }
    }

    #[test]
    fn sparse_storage_smaller_than_dense() {
        // Needs realistic layer sizes: at d_model=32 the adapters dominate.
        let cfg = ModelCfg {
            d_model: 128,
            d_ff: 256,
            n_heads: 4,
            rank: 4,
            residual_rank: 8,
            ..test_cfg()
        };
        let mut rng = Rng::new(403);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 4);
        let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        for (k, v) in build.residual_adapters.iter() {
            adapters.insert(k, v.clone());
        }
        let dense = EngineWeights::dense_merged(&cfg, &base, None);
        // Pinned formats: the size inequalities below are format-specific,
        // so the env-defaulted constructor (the CI matrix axis) would
        // invalidate them on its f32 and nf4 legs.
        let sparse = EngineWeights::salr_with_format(
            &cfg,
            &build.params,
            &adapters,
            None,
            WeightFormat::Bitmap,
        );
        assert!(sparse.linear_storage_bytes() < dense.linear_storage_bytes());
        // NF4 shrinks the linears further still.
        let nf4 = EngineWeights::salr_with_format(
            &cfg,
            &build.params,
            &adapters,
            None,
            WeightFormat::Nf4,
        );
        assert!(nf4.linear_storage_bytes() < sparse.linear_storage_bytes());
    }

    #[test]
    fn compressed_modes_keep_no_resident_dense_base() {
        // The tentpole's memory acceptance bar: in a compressed mode no
        // persistent dense f32 copy of any Ŵ survives engine
        // construction. WeightStore registers every resident
        // representation with thread-local byte counters, and engine
        // construction happens entirely on this thread, so the deltas are
        // exact: compressed formats must add zero resident dense-weight
        // bytes and a positive number of compressed bytes; the F32 format
        // is the control that shows the dense counter does fire.
        let cfg = test_cfg();
        let mut rng = Rng::new(430);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let build = crate::salr::build_salr(&cfg, &base, 0.5, 3);
        let adapters = ParamStore::init_adapters(&cfg, &mut rng, true);
        for fmt in [WeightFormat::Bitmap, WeightFormat::Nf4] {
            let dense0 = crate::util::mem::dense_weight_bytes();
            let comp0 = crate::util::mem::compressed_weight_bytes();
            let engine = Engine::with_pool(
                EngineWeights::salr_with_format(&cfg, &build.params, &adapters, None, fmt),
                Backend::BitmapPipelined(PipelineConfig::default()),
                Arc::new(WorkerPool::new(1)),
            );
            assert_eq!(
                crate::util::mem::dense_weight_bytes() - dense0,
                0,
                "{fmt:?}: a resident dense f32 base survived engine construction"
            );
            assert!(
                crate::util::mem::compressed_weight_bytes() - comp0 > 0,
                "{fmt:?}: no compressed weights registered"
            );
            // The engine actually works in this mode.
            let out = engine.generate_batch(&[vec![1, 2, 3]], 2);
            assert_eq!(out[0].len(), 2);
            drop(engine);
            assert_eq!(crate::util::mem::compressed_weight_bytes(), comp0);
        }
        let dense0 = crate::util::mem::dense_weight_bytes();
        let w = EngineWeights::salr_with_format(
            &cfg,
            &build.params,
            &adapters,
            None,
            WeightFormat::F32,
        );
        assert!(
            crate::util::mem::dense_weight_bytes() - dense0 > 0,
            "F32 control: dense counter must register the resident base"
        );
        drop(w);
        assert_eq!(crate::util::mem::dense_weight_bytes(), dense0);
    }

    #[test]
    fn nf4_engine_is_deterministic_and_zero_alloc_in_steady_state() {
        // The lossy format still satisfies the runtime invariants: decode
        // is bitwise reproducible across thread counts, and the fused
        // pack-decode path stays zero-allocation once slabs are warm.
        let e1 = salr_engine_fmt(1, 431, WeightFormat::Nf4);
        let e3 = salr_engine_fmt(3, 431, WeightFormat::Nf4);
        let prompt: Vec<i32> = vec![6, 2, 9, 1];
        let g1 = e1.generate_batch(&[prompt.clone()], 5);
        let g3 = e3.generate_batch(&[prompt.clone()], 5);
        assert_eq!(g1, g3, "nf4 decode must be thread-count invariant");
        let mut kv = e1.new_slot_pool(1);
        let slot = kv.alloc().unwrap();
        let mut cur = vec![e1.prefill(&prompt, slot, &mut kv)];
        cur = e1.decode_step(&cur, &[slot], &mut kv);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            cur = e1.decode_step(&cur, &[slot], &mut kv);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "nf4 decode_step allocated arena slabs in steady state"
        );
    }
}
