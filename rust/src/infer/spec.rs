//! Speculative-decoding draft sources.
//!
//! Speculation here is *exact*: drafts are proposals, verification is one
//! batched greedy forward ([`Engine::decode_verify`]), and a wrong draft
//! costs only wasted work — never a changed byte. That puts all the
//! freedom in the drafter, which is what this module abstracts:
//!
//! * [`RadixDrafter`] — prompt-lookup drafting off the prefix cache. The
//!   radix tree already stores previously generated block chains keyed by
//!   their token paths; after a prefix hit the cached continuation *is* a
//!   draft, read straight from the tree's edge labels with no forward
//!   pass at all. Free drafts, high acceptance on repeated traffic.
//! * [`SelfDrafter`] — the paper-native drafter. A SALR layer is a
//!   sparse base plus a fused low-rank correction; running the base alone
//!   ([`Engine::draft_self`] → `SalrLayer::forward_base_only`) skips
//!   every adapter GEMM and yields a cheap approximation of the full
//!   model. The verify pass restores exactly the correction the draft
//!   dropped.
//!
//! The scheduler picks a source per [`SpecMode`] (`--spec-decode`, or
//! `SALR_SPEC` for CI matrices) and drives draft → verify per sequence
//! per iteration; `server/batcher.rs` owns that loop and the
//! `drafted_tokens` / `accepted_tokens` / `spec_rollbacks` counters.

use super::engine::Engine;
use super::kv_cache::KvSlotPool;

/// Which speculative draft source the scheduler runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecMode {
    /// No speculation: one token per decode forward (the default).
    Off,
    /// Draft cached continuations from the radix prefix cache.
    Radix,
    /// Draft with the sparse-base-only forward (adapters skipped).
    SelfDraft,
}

impl SpecMode {
    /// Parse a mode name as spelled on `--spec-decode` / `SALR_SPEC`.
    pub fn parse(s: &str) -> Option<SpecMode> {
        match s {
            "off" => Some(SpecMode::Off),
            "radix" => Some(SpecMode::Radix),
            "self" => Some(SpecMode::SelfDraft),
            _ => None,
        }
    }

    /// The flag spelling (`off` / `radix` / `self`).
    pub fn name(self) -> &'static str {
        match self {
            SpecMode::Off => "off",
            SpecMode::Radix => "radix",
            SpecMode::SelfDraft => "self",
        }
    }

    /// Mode from `SALR_SPEC`, defaulting to [`SpecMode::Off`]. Panics on
    /// a malformed value — a typo'd CI matrix leg must fail loudly, not
    /// silently run without speculation (same contract as `SALR_FAULT`).
    pub fn env_default() -> SpecMode {
        match std::env::var("SALR_SPEC") {
            Ok(s) => SpecMode::parse(&s)
                .unwrap_or_else(|| panic!("SALR_SPEC: unknown mode {s:?} (off|radix|self)")),
            Err(_) => SpecMode::Off,
        }
    }

    /// The draft source for this mode, or `None` for [`SpecMode::Off`].
    pub fn drafter(self) -> Option<Box<dyn Drafter>> {
        match self {
            SpecMode::Off => None,
            SpecMode::Radix => Some(Box::new(RadixDrafter)),
            SpecMode::SelfDraft => Some(Box::new(SelfDrafter)),
        }
    }
}

/// A speculative draft source.
///
/// Contract: return up to `k` proposed next tokens for the sequence whose
/// full token history (prompt plus generated output, ending with the
/// token about to be fed) is `history`, leaving `kv.seq_len(slot)`
/// exactly as found. Returning fewer than `k` tokens (or none) is always
/// legal — the scheduler verifies whatever comes back, and an empty draft
/// degenerates to a plain decode step. Drafts may be arbitrarily wrong;
/// exact verification makes quality a throughput knob, not a correctness
/// one.
pub trait Drafter: Send {
    /// Propose up to `k` tokens to follow `history`.
    fn draft(
        &self,
        engine: &Engine,
        kv: &mut KvSlotPool,
        slot: usize,
        history: &[i32],
        k: usize,
    ) -> Vec<i32>;
}

/// Prompt-lookup drafting from the radix prefix cache: propose the cached
/// continuation of `history` read from the tree's edge labels. No forward
/// pass, no KV traffic, read-only on the cache (eviction order is
/// untouched). Misses — cache off, no matching chain, or `history` ends
/// mid-divergence — yield an empty or short draft.
pub struct RadixDrafter;

impl Drafter for RadixDrafter {
    fn draft(
        &self,
        _engine: &Engine,
        kv: &mut KvSlotPool,
        _slot: usize,
        history: &[i32],
        k: usize,
    ) -> Vec<i32> {
        kv.propose_continuation(history, k)
    }
}

/// Paper-native self-drafting: k chained single-row sparse-base-only
/// forwards through [`Engine::draft_self`]. The draft rows' base-quality
/// K/V is truncated away before returning, so the chain is exactly as
/// found. On a Dense backend (adapters merged) the base is the full
/// model and drafting degenerates to correct-but-not-cheaper — the spec
/// test matrix runs it anyway to pin that correctness never depends on
/// the drafter being weak.
pub struct SelfDrafter;

impl Drafter for SelfDrafter {
    fn draft(
        &self,
        engine: &Engine,
        kv: &mut KvSlotPool,
        slot: usize,
        history: &[i32],
        k: usize,
    ) -> Vec<i32> {
        let cur = *history.last().expect("history ends with the current token");
        engine.draft_self(cur, k, slot, kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_round_trip() {
        for m in [SpecMode::Off, SpecMode::Radix, SpecMode::SelfDraft] {
            assert_eq!(SpecMode::parse(m.name()), Some(m));
        }
        assert_eq!(SpecMode::parse("radixx"), None);
        assert_eq!(SpecMode::parse(""), None);
        assert!(SpecMode::Off.drafter().is_none());
        assert!(SpecMode::Radix.drafter().is_some());
        assert!(SpecMode::SelfDraft.drafter().is_some());
    }
}
