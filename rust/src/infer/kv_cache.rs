//! Per-sequence key/value caches for autoregressive decode, plus the
//! fixed-capacity slot pool the continuous-batching scheduler allocates
//! sequences from.

/// KV cache for one transformer layer and one sequence: rows are time
/// steps, `d_model` columns split across heads by the engine.
#[derive(Clone, Debug)]
pub struct KvCache {
    /// Cached keys, row-major `[len, d_model]` (rows beyond `len` are free).
    pub keys: Vec<f32>,
    /// Cached values, same layout as `keys`.
    pub values: Vec<f32>,
    /// Number of time steps currently cached.
    pub len: usize,
    d_model: usize,
    capacity: usize,
}

impl KvCache {
    /// Cache with room for `capacity` time steps of width `d_model`.
    pub fn new(capacity: usize, d_model: usize) -> KvCache {
        KvCache {
            keys: vec![0.0; capacity * d_model],
            values: vec![0.0; capacity * d_model],
            len: 0,
            d_model,
            capacity,
        }
    }

    /// Append one time step.
    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert!(self.len < self.capacity, "kv cache overflow");
        assert_eq!(k.len(), self.d_model);
        assert_eq!(v.len(), self.d_model);
        let off = self.len * self.d_model;
        self.keys[off..off + self.d_model].copy_from_slice(k);
        self.values[off..off + self.d_model].copy_from_slice(v);
        self.len += 1;
    }

    /// Key row at time `t`.
    #[inline]
    pub fn key(&self, t: usize) -> &[f32] {
        &self.keys[t * self.d_model..(t + 1) * self.d_model]
    }

    /// Value row at time `t`.
    #[inline]
    pub fn value(&self, t: usize) -> &[f32] {
        &self.values[t * self.d_model..(t + 1) * self.d_model]
    }

    /// Forget all cached steps (the backing storage is reused, not freed).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Maximum number of time steps this cache can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// A fixed pool of KV-cache *slots* for continuous batching.
///
/// Each slot holds one sequence's per-layer caches (`[n_layers]` of
/// [`KvCache`]), all allocated up front. The scheduler admits a request by
/// [`alloc`](KvSlotPool::alloc)-ing a slot, decodes it for as many steps
/// as it needs, and [`free`](KvSlotPool::free)-s the slot when the
/// sequence retires — the freed cache rows are reused by the next
/// admission without touching the allocator, so batch membership can
/// change between decode steps at zero allocation cost.
#[derive(Debug)]
pub struct KvSlotPool {
    slots: Vec<Vec<KvCache>>,
    free: Vec<usize>,
}

impl KvSlotPool {
    /// Pool of `slots` sequences × `n_layers` caches, each with room for
    /// `capacity` steps of width `d_model`.
    pub fn new(slots: usize, n_layers: usize, capacity: usize, d_model: usize) -> KvSlotPool {
        KvSlotPool {
            slots: (0..slots)
                .map(|_| (0..n_layers).map(|_| KvCache::new(capacity, d_model)).collect())
                .collect(),
            // Pop from the back; keep ascending order so slot 0 is handed
            // out first (stable, deterministic slot assignment).
            free: (0..slots).rev().collect(),
        }
    }

    /// Claim a free slot (its caches reset to length 0), or `None` when
    /// every slot is occupied.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        for c in &mut self.slots[slot] {
            c.reset();
        }
        Some(slot)
    }

    /// Return `slot` to the free list. The cache rows are reused as-is by
    /// the next [`alloc`](KvSlotPool::alloc) (which resets the lengths).
    pub fn free(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double free of kv slot {slot}");
        self.free.push(slot);
        // Keep descending so pops hand out the lowest free slot first.
        self.free.sort_unstable_by(|a, b| b.cmp(a));
    }

    /// Number of currently free slots.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Total number of slots (free + occupied).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// All slots' per-layer caches, indexed `[slot][layer]` — the shape
    /// [`Engine::decode_step`](crate::infer::Engine::decode_step) expects.
    pub fn slots_mut(&mut self) -> &mut [Vec<KvCache>] {
        &mut self.slots
    }

    /// Cached sequence length of `slot` (its next decode position).
    pub fn seq_len(&self, slot: usize) -> usize {
        self.slots[slot].first().map(|c| c.len).unwrap_or(0)
    }

    /// Remaining time-step capacity of `slot` — how many more tokens can
    /// be appended before the slot overflows. The engine's chunked
    /// prefill checks this before every chunk so an over-long prompt is
    /// rejected with an error instead of panicking mid-forward.
    pub fn remaining(&self, slot: usize) -> usize {
        self.slots[slot]
            .first()
            .map(|c| c.capacity() - c.len)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 3);
        c.push(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        c.push(&[7.0, 8.0, 9.0], &[1.5, 2.5, 3.5]);
        assert_eq!(c.len, 2);
        assert_eq!(c.key(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.value(1), &[1.5, 2.5, 3.5]);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 2);
        c.push(&[0.0, 0.0], &[0.0, 0.0]);
        c.push(&[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn slot_pool_alloc_free_reuses_lowest_first() {
        let mut pool = KvSlotPool::new(3, 2, 4, 2);
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.available(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!((a, b), (0, 1));
        // Write into slot 0, free it, re-alloc: caches come back reset.
        pool.slots_mut()[a][0].push(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(pool.seq_len(a), 1);
        pool.free(a);
        let c = pool.alloc().unwrap();
        assert_eq!(c, 0, "lowest free slot is handed out first");
        assert_eq!(pool.seq_len(c), 0, "realloc must reset lengths");
        let d = pool.alloc().unwrap();
        assert_eq!(d, 2);
        assert_eq!(pool.alloc(), None, "pool exhausted");
        pool.free(b);
        assert_eq!(pool.available(), 1);
        assert_eq!(pool.alloc(), Some(1));
    }

    #[test]
    fn remaining_tracks_pushes_and_realloc() {
        let mut pool = KvSlotPool::new(2, 1, 4, 2);
        let s = pool.alloc().unwrap();
        assert_eq!(pool.remaining(s), 4);
        pool.slots_mut()[s][0].push(&[1.0, 2.0], &[3.0, 4.0]);
        pool.slots_mut()[s][0].push(&[5.0, 6.0], &[7.0, 8.0]);
        assert_eq!(pool.remaining(s), 2);
        // Freeing and re-allocating restores full capacity (lengths reset).
        pool.free(s);
        let s2 = pool.alloc().unwrap();
        assert_eq!(s2, s);
        assert_eq!(pool.remaining(s2), 4);
    }
}
