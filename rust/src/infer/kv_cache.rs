//! Sequence-slot KV caches as **views over paged block chains**, plus
//! the radix-tree prefix cache that shares prompt-head blocks across
//! requests.
//!
//! Storage lives in a refcounted [`BlockPool`](crate::infer::cache::BlockPool)
//! of fixed-size token blocks; each slot holds a *block table* (the chain
//! of block ids covering its cached positions) and per-layer lengths.
//! With the prefix cache enabled, a freshly allocated slot can
//! [`attach`](KvSlotPool::attach_prefix) the longest cached prefix of its
//! prompt — full blocks are shared by reference (refcount bump, zero
//! copy), a mid-block divergence is copy-on-write — and a finished
//! prefill [`registers`](KvSlotPool::register_prefix) its full prompt
//! blocks so later requests hit them. Chains no live slot references are
//! reclaimed lazily, LRU-first, when the pool runs out of free blocks.
//!
//! Determinism: a cache hit replays K/V rows that a cold prefill of the
//! same prefix would have produced **bitwise** (same kernels, same
//! k-accumulation order, positions identical), and shared blocks are
//! immutable, so attaching a prefix changes which GEMMs run but never a
//! single output byte. The off path (`prefix_cache: false`) differs from
//! the pre-paging flat layout only in where rows live, not in any value
//! read or written.

use super::cache::{BlockPool, RadixTree};

/// Construction knobs for [`KvSlotPool`] (the `--kv-block-size` /
/// `--prefix-cache` serve flags land here).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Token positions per KV block (the paging granularity; also the
    /// prefix-sharing granularity — only whole blocks are shared without
    /// copying).
    pub block_size: usize,
    /// Enable the radix-tree prefix cache. Off keeps allocation paged but
    /// never shares or retains blocks across sequences — bitwise
    /// identical serving behavior to the pre-cache engine.
    pub prefix_cache: bool,
    /// Extra blocks beyond the `slots × blocks-per-sequence` floor, as
    /// headroom for retaining cached chains while every slot is busy
    /// (env `SALR_KV_EXTRA`; default 0). The floor alone already
    /// guarantees live sequences can always allocate (cached chains are
    /// evicted on demand).
    pub extra_blocks: usize,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            block_size: 16,
            prefix_cache: false,
            extra_blocks: 0,
        }
    }
}

impl KvCacheConfig {
    /// The default configuration with environment overrides applied:
    /// `SALR_PREFIX_CACHE=1|0` forces the prefix cache on/off (the CI
    /// matrix legs), `SALR_KV_BLOCK=N` overrides the block size and
    /// `SALR_KV_EXTRA=N` adds cache-retention headroom blocks. Callers
    /// that pin an explicit config are unaffected.
    pub fn env_default() -> KvCacheConfig {
        let base = KvCacheConfig::default();
        let prefix_cache = match std::env::var("SALR_PREFIX_CACHE") {
            Ok(v) => crate::util::truthy(&v),
            Err(_) => base.prefix_cache,
        };
        let block_size = std::env::var("SALR_KV_BLOCK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(base.block_size);
        let extra_blocks = std::env::var("SALR_KV_EXTRA")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(base.extra_blocks);
        KvCacheConfig {
            block_size,
            prefix_cache,
            extra_blocks,
        }
    }
}

/// One sequence slot: a chain of block ids plus per-layer lengths.
#[derive(Debug)]
struct SeqKv {
    /// Block ids covering positions `[0, max(len))`, in order. Allocated
    /// with full capacity up front so pushes never reallocate mid-decode.
    table: Vec<usize>,
    /// Cached positions per layer (layers fill in order within one
    /// forward, so `len[0] >= len[l]` for all `l` mid-forward and all
    /// entries agree between forwards).
    len: Vec<usize>,
    /// Leading blocks that are shared with the radix tree or another
    /// sequence — immutable; writes may only land at indices `>= shared`
    /// (a mid-block COW tail is private and sits exactly at `shared`).
    shared: usize,
}

/// A fixed pool of KV-cache *slots* for continuous batching, backed by a
/// paged [`BlockPool`] and (optionally) a [`RadixTree`] prefix cache.
///
/// The scheduler admits a request by [`alloc`](KvSlotPool::alloc)-ing a
/// slot, optionally [`attach_prefix`](KvSlotPool::attach_prefix)-ing the
/// cached head of its prompt, decodes it for as many steps as it needs,
/// [`register_prefix`](KvSlotPool::register_prefix)-es the prompt once
/// prefilled, and [`free`](KvSlotPool::free)-s the slot when the sequence
/// retires. The pool is sized so a live sequence can always get a block:
/// `slots × ⌈capacity/block_size⌉` plus configured headroom, with cached
/// chains evicted LRU-first under pressure.
#[derive(Debug)]
pub struct KvSlotPool {
    pool: BlockPool,
    tree: Option<RadixTree>,
    slots: Vec<SeqKv>,
    free: Vec<usize>,
    /// Max token positions per sequence.
    seq_capacity: usize,
    /// Prompt tokens served from the prefix cache instead of prefill
    /// forwards, over the pool's lifetime.
    prefix_hit_tokens: u64,
    /// Prefix lookups that matched at least one token.
    prefix_hits: u64,
    /// Prefix lookups attempted.
    prefix_lookups: u64,
}

impl KvSlotPool {
    /// Pool of `slots` sequences × `n_layers` caches, each with room for
    /// `capacity` steps of width `d_model`, using
    /// [`KvCacheConfig::env_default`].
    pub fn new(slots: usize, n_layers: usize, capacity: usize, d_model: usize) -> KvSlotPool {
        Self::with_config(slots, n_layers, capacity, d_model, KvCacheConfig::env_default())
    }

    /// Pool with an explicit [`KvCacheConfig`].
    pub fn with_config(
        slots: usize,
        n_layers: usize,
        capacity: usize,
        d_model: usize,
        cfg: KvCacheConfig,
    ) -> KvSlotPool {
        let bs = cfg.block_size.max(1).min(capacity.max(1));
        let blocks_per_seq = capacity.div_ceil(bs).max(1);
        let num_blocks = slots * blocks_per_seq + cfg.extra_blocks;
        KvSlotPool {
            pool: BlockPool::new(num_blocks, n_layers, bs, d_model),
            tree: cfg.prefix_cache.then(|| RadixTree::new(bs)),
            slots: (0..slots)
                .map(|_| SeqKv {
                    table: Vec::with_capacity(blocks_per_seq),
                    len: vec![0; n_layers],
                    shared: 0,
                })
                .collect(),
            // Pop from the back; keep ascending order so slot 0 is handed
            // out first (stable, deterministic slot assignment).
            free: (0..slots).rev().collect(),
            seq_capacity: capacity,
            prefix_hit_tokens: 0,
            prefix_hits: 0,
            prefix_lookups: 0,
        }
    }

    /// Claim a free slot (empty block table, lengths 0), or `None` when
    /// every slot is occupied.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(self.slots[slot].table.is_empty(), "freed slot kept blocks");
        for l in self.slots[slot].len.iter_mut() {
            *l = 0;
        }
        self.slots[slot].shared = 0;
        Some(slot)
    }

    /// Return `slot` to the free list, releasing every block in its
    /// chain. Blocks the radix tree (or another sequence) still
    /// references survive with their refcounts decremented; the rest go
    /// back on the block free list.
    pub fn free(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double free of kv slot {slot}");
        while let Some(b) = self.slots[slot].table.pop() {
            self.pool.release(b);
        }
        for l in self.slots[slot].len.iter_mut() {
            *l = 0;
        }
        self.slots[slot].shared = 0;
        self.free.push(slot);
        // Keep descending so pops hand out the lowest free slot first.
        self.free.sort_unstable_by(|a, b| b.cmp(a));
    }

    /// Number of currently free slots.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Total number of slots (free + occupied).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Maximum token positions one sequence can cache.
    pub fn seq_capacity(&self) -> usize {
        self.seq_capacity
    }

    /// Tokens per KV block.
    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// Whether the radix-tree prefix cache is enabled.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.tree.is_some()
    }

    /// Cached sequence length of `slot` (its next decode position).
    pub fn seq_len(&self, slot: usize) -> usize {
        self.slots[slot].len.first().copied().unwrap_or(0)
    }

    /// Cached length of one `(slot, layer)` — equals
    /// [`seq_len`](KvSlotPool::seq_len) between forwards, lags behind it
    /// for deeper layers mid-forward.
    pub fn layer_len(&self, slot: usize, layer: usize) -> usize {
        self.slots[slot].len[layer]
    }

    /// Remaining time-step capacity of `slot` — how many more tokens can
    /// be appended before the slot overflows. The engine's chunked
    /// prefill checks this before every chunk so an over-long prompt is
    /// rejected with an error instead of panicking mid-forward.
    pub fn remaining(&self, slot: usize) -> usize {
        self.seq_capacity - self.seq_len(slot)
    }

    /// Blocks currently referenced by live chains or the prefix cache.
    pub fn blocks_in_use(&self) -> usize {
        self.pool.blocks_in_use()
    }

    /// Blocks the prefix cache has evicted under pool pressure.
    pub fn evicted_blocks(&self) -> u64 {
        self.tree.as_ref().map_or(0, RadixTree::evicted_blocks)
    }

    /// Prompt tokens served straight from the prefix cache (their prefill
    /// GEMMs were skipped) over the pool's lifetime.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// `(lookups, hits)`: prefix-cache probes attempted and probes that
    /// matched at least one token.
    pub fn prefix_stats(&self) -> (u64, u64) {
        (self.prefix_lookups, self.prefix_hits)
    }

    /// A free block, evicting LRU cached chains if the free list is dry.
    /// Panics only if every block is pinned by a live chain — impossible
    /// for in-capacity sequences given the pool's sizing floor.
    fn grab_block(&mut self) -> usize {
        loop {
            if let Some(b) = self.pool.alloc() {
                return b;
            }
            let evicted = match self.tree.as_mut() {
                Some(t) => t.evict_one(&mut self.pool),
                None => false,
            };
            assert!(evicted, "kv block pool exhausted by live sequences");
        }
    }

    /// Append one K/V row for `(slot, layer)` at its current length,
    /// allocating the next block of the chain on a block boundary.
    pub fn push(&mut self, slot: usize, layer: usize, k: &[f32], v: &[f32]) {
        let t = self.slots[slot].len[layer];
        assert!(t < self.seq_capacity, "kv cache overflow");
        let bs = self.pool.block_size();
        let bi = t / bs;
        if bi == self.slots[slot].table.len() {
            let b = self.grab_block();
            self.slots[slot].table.push(b);
        }
        let s = &self.slots[slot];
        debug_assert!(bi >= s.shared, "write into a shared (immutable) block");
        self.pool.write_row(s.table[bi], layer, t % bs, k, v);
        self.slots[slot].len[layer] = t + 1;
    }

    /// Roll `slot`'s chain back to `new_len` cached positions — the
    /// speculative-decode rollback. The engine's `decode_verify` appends
    /// K/V rows for every drafted token during its batched verify
    /// forward, then truncates the chain to the accepted length; a
    /// self-drafting pass likewise
    /// truncates its base-only rows away before verification. Sets every
    /// per-layer length to `new_len` and releases trailing blocks wholly
    /// past it (a block covering a partial tail stays — its dead rows are
    /// simply overwritten by the next push).
    ///
    /// Rollback only ever cuts **private** territory: drafts are
    /// appended past the verified frontier, which lies at or past the
    /// shared prefix, so shared (immutable, possibly tree-registered)
    /// blocks are never popped — `debug_assert`ed, keeping the operation
    /// COW-safe by construction.
    pub fn truncate(&mut self, slot: usize, new_len: usize) {
        assert!(
            new_len <= self.seq_len(slot),
            "truncate can only shorten a chain"
        );
        let bs = self.pool.block_size();
        debug_assert!(
            new_len >= self.slots[slot].shared * bs,
            "speculative rollback cut into the shared prefix"
        );
        let keep = new_len.div_ceil(bs);
        while self.slots[slot].table.len() > keep {
            let b = self.slots[slot].table.pop().expect("table length checked");
            self.pool.release(b);
        }
        for l in self.slots[slot].len.iter_mut() {
            *l = new_len;
        }
    }

    /// Up to `k` draft tokens continuing `history` (a sequence's full
    /// token stream so far) from the prefix cache's chains — the *radix
    /// drafting* source for speculative decoding. Forward-free and
    /// read-only (no recency bump, so drafting never changes eviction
    /// order); returns an empty draft when the cache is disabled or holds
    /// no continuation.
    pub fn propose_continuation(&self, history: &[i32], k: usize) -> Vec<i32> {
        match &self.tree {
            Some(t) => t.propose(history, k),
            None => Vec::new(),
        }
    }

    /// Blocks currently held by the radix tree (the prefix cache's
    /// retained chains), independent of live-sequence references.
    pub fn cached_blocks(&self) -> usize {
        self.tree.as_ref().map_or(0, RadixTree::len)
    }

    /// Read-only view of one `(slot, layer)` chain — what the attention
    /// kernel walks block by block.
    pub fn view(&self, slot: usize, layer: usize) -> KvView<'_> {
        let s = &self.slots[slot];
        KvView {
            pool: &self.pool,
            table: &s.table,
            layer,
            len: s.len[layer],
        }
    }

    /// Attach the longest cached prefix of `tokens` to freshly allocated
    /// `slot`: full blocks are shared by reference, a mid-block
    /// divergence copies the matching head of the shared block into a
    /// private block (COW). Returns the number of prompt positions now
    /// cached — the caller prefills only `tokens[hit..]`. Always leaves
    /// at least one token to forward (the final hidden state is what
    /// produces the first sampled token), and returns 0 when the prefix
    /// cache is disabled.
    pub fn attach_prefix(&mut self, slot: usize, tokens: &[i32]) -> usize {
        if self.tree.is_none() {
            return 0;
        }
        assert_eq!(self.seq_len(slot), 0, "attach_prefix into a non-empty slot");
        if tokens.len() <= 1 {
            return 0;
        }
        self.prefix_lookups += 1;
        let want = &tokens[..tokens.len() - 1];
        let (full, partial) = self.tree.as_mut().expect("checked above").lookup(want);
        let bs = self.pool.block_size();
        let mut hit = 0;
        for m in &full {
            self.pool.retain(m.block);
            self.slots[slot].table.push(m.block);
            hit += bs;
        }
        self.slots[slot].shared = full.len();
        if let Some(p) = partial {
            // Grabbing a block may evict LRU leaves. The lookup above
            // bumped the source's recency, so the eviction loop reclaims
            // every *other* unreferenced chain first; under total pool
            // pressure the source itself goes last, and its freed storage
            // is handed straight back as the destination — where the rows
            // already sit, so the copy is skipped. (Pinning the source
            // instead would deadlock eviction when it is the only
            // reclaimable block.) Nothing can write between the eviction
            // and the copy: this is one `&mut self` call.
            let dst = self.grab_block();
            if dst != p.block {
                self.pool.copy_rows(p.block, dst, p.matched);
            }
            self.slots[slot].table.push(dst);
            hit += p.matched;
        }
        for l in self.slots[slot].len.iter_mut() {
            *l = hit;
        }
        self.prefix_hit_tokens += hit as u64;
        if hit > 0 {
            self.prefix_hits += 1;
        }
        hit
    }

    /// Register the full blocks covering `tokens` (a completely prefilled
    /// prompt) in the radix tree, so later requests sharing this head
    /// attach them instead of re-running prefill. Blocks already in the
    /// tree are kept; newly registered ones gain a tree reference and
    /// become immutable-shared. No-op with the prefix cache disabled.
    pub fn register_prefix(&mut self, slot: usize, tokens: &[i32]) {
        let bs = self.pool.block_size();
        let n = tokens.len().min(self.seq_len(slot));
        let nb = n / bs;
        if nb == 0 {
            return;
        }
        let KvSlotPool {
            tree, pool, slots, ..
        } = self;
        let Some(tree) = tree.as_mut() else {
            return;
        };
        tree.insert(&tokens[..nb * bs], &slots[slot].table[..nb], pool);
        slots[slot].shared = slots[slot].shared.max(nb);
    }
}

/// Read-only view over one `(slot, layer)` block chain. The attention
/// kernel iterates chains block by block:
/// [`key_rows`](KvView::key_rows)/[`value_rows`](KvView::value_rows)
/// return each block's populated rows as one contiguous slice.
#[derive(Clone, Copy)]
pub struct KvView<'a> {
    pool: &'a BlockPool,
    table: &'a [usize],
    layer: usize,
    len: usize,
}

impl KvView<'_> {
    /// Cached positions in this chain.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// The first `rows` contiguous key rows of chain block `blk`.
    #[inline]
    pub fn key_rows(&self, blk: usize, rows: usize) -> &[f32] {
        self.pool.key_rows(self.table[blk], self.layer, rows)
    }

    /// The first `rows` contiguous value rows of chain block `blk`.
    #[inline]
    pub fn value_rows(&self, blk: usize, rows: usize) -> &[f32] {
        self.pool.value_rows(self.table[blk], self.layer, rows)
    }

    /// Key row at absolute position `t` (convenience; the hot path walks
    /// whole blocks instead).
    #[inline]
    pub fn key(&self, t: usize) -> &[f32] {
        let bs = self.pool.block_size();
        self.pool.key_row(self.table[t / bs], self.layer, t % bs)
    }

    /// Value row at absolute position `t`.
    #[inline]
    pub fn value(&self, t: usize) -> &[f32] {
        let bs = self.pool.block_size();
        self.pool.value_row(self.table[t / bs], self.layer, t % bs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(block_size: usize, prefix: bool) -> KvCacheConfig {
        KvCacheConfig {
            block_size,
            prefix_cache: prefix,
            extra_blocks: 0,
        }
    }

    /// Distinct, position-tagged rows so sharing bugs show up as wrong
    /// values, not just wrong lengths.
    fn row(slot: usize, t: usize) -> (Vec<f32>, Vec<f32>) {
        let base = (slot * 1000 + t) as f32;
        (vec![base, base + 0.5], vec![-base, -base - 0.5])
    }

    #[test]
    fn push_and_read_across_block_boundaries() {
        let mut pool = KvSlotPool::with_config(1, 2, 7, 2, cfg(3, false));
        let s = pool.alloc().unwrap();
        for t in 0..7 {
            for layer in 0..2 {
                let (k, v) = row(layer, t);
                pool.push(s, layer, &k, &v);
            }
        }
        assert_eq!(pool.seq_len(s), 7);
        assert_eq!(pool.remaining(s), 0);
        for layer in 0..2 {
            let view = pool.view(s, layer);
            assert_eq!(view.len(), 7);
            for t in 0..7 {
                let (k, v) = row(layer, t);
                assert_eq!(view.key(t), &k[..], "layer {layer} t {t}");
                assert_eq!(view.value(t), &v[..]);
            }
            // Block-walk form agrees with per-row reads (last block ragged).
            assert_eq!(&view.key_rows(2, 1)[..2], view.key(6));
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut pool = KvSlotPool::with_config(1, 1, 2, 2, cfg(2, false));
        let s = pool.alloc().unwrap();
        for _ in 0..3 {
            pool.push(s, 0, &[0.0, 0.0], &[0.0, 0.0]);
        }
    }

    #[test]
    fn slot_pool_alloc_free_reuses_lowest_first() {
        let mut pool = KvSlotPool::with_config(3, 2, 4, 2, cfg(2, false));
        assert_eq!(pool.capacity(), 3);
        assert_eq!(pool.available(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!((a, b), (0, 1));
        pool.push(a, 0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(pool.seq_len(a), 1);
        assert_eq!(pool.blocks_in_use(), 1);
        pool.free(a);
        assert_eq!(pool.blocks_in_use(), 0, "freed slot returns its blocks");
        let c = pool.alloc().unwrap();
        assert_eq!(c, 0, "lowest free slot is handed out first");
        assert_eq!(pool.seq_len(c), 0, "realloc must reset lengths");
        let d = pool.alloc().unwrap();
        assert_eq!(d, 2);
        assert_eq!(pool.alloc(), None, "pool exhausted");
        pool.free(b);
        assert_eq!(pool.available(), 1);
        assert_eq!(pool.alloc(), Some(1));
    }

    #[test]
    fn remaining_tracks_pushes_and_realloc() {
        let mut pool = KvSlotPool::with_config(2, 1, 4, 2, cfg(4, false));
        let s = pool.alloc().unwrap();
        assert_eq!(pool.remaining(s), 4);
        pool.push(s, 0, &[1.0, 2.0], &[3.0, 4.0]);
        pool.push(s, 0, &[5.0, 6.0], &[7.0, 8.0]);
        assert_eq!(pool.remaining(s), 2);
        pool.free(s);
        let s2 = pool.alloc().unwrap();
        assert_eq!(s2, s);
        assert_eq!(pool.remaining(s2), 4);
    }

    /// Fill `slot` with `n` prompt positions of slot-tagged rows across
    /// every layer (stand-in for a prefill forward).
    fn fill(pool: &mut KvSlotPool, slot: usize, tag: usize, n: usize, layers: usize) {
        for t in pool.seq_len(slot)..n {
            for layer in 0..layers {
                let (k, v) = row(tag, t);
                pool.push(slot, layer, &k, &v);
            }
        }
    }

    #[test]
    fn attach_shares_full_blocks_and_cow_splits_mid_block() {
        let mut pool = KvSlotPool::with_config(3, 2, 16, 2, cfg(4, true));
        let prompt: Vec<i32> = (100..110).collect(); // 10 tokens
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 7, 10, 2);
        pool.register_prefix(a, &prompt);
        assert_eq!(pool.blocks_in_use(), 3, "a's chain: 2 full + 1 partial block");

        // Identical prompt: both full blocks shared by reference (the
        // partial third block is not in the tree — only full blocks are).
        let b = pool.alloc().unwrap();
        let hit = pool.attach_prefix(b, &prompt);
        assert_eq!(hit, 8, "two full blocks hit");
        assert_eq!(pool.seq_len(b), 8);
        assert_eq!(pool.blocks_in_use(), 3, "full hit adds no blocks");
        for layer in 0..2 {
            let (va, vb) = (pool.view(a, layer), pool.view(b, layer));
            for t in 0..8 {
                assert_eq!(va.key(t), vb.key(t), "shared rows must alias");
            }
        }

        // Prompt diverging at token 6 (mid second block): first block
        // shared, second copy-on-written up to the divergence.
        let mut fork = prompt.clone();
        fork[6] = 999;
        let c = pool.alloc().unwrap();
        let hit = pool.attach_prefix(c, &fork);
        assert_eq!(hit, 6, "4 shared + 2 copied rows");
        assert_eq!(pool.blocks_in_use(), 4, "COW allocated one private block");
        // Appending c's divergent rows must not corrupt a's chain.
        fill(&mut pool, c, 9, 10, 2);
        for layer in 0..2 {
            let (va, vc) = (pool.view(a, layer), pool.view(c, layer));
            for t in 0..6 {
                assert_eq!(va.key(t), vc.key(t), "copied head must match");
            }
            let (k7, _) = row(7, 6);
            assert_eq!(va.key(6), &k7[..], "a's block untouched by c's writes");
            let (k9, _) = row(9, 6);
            assert_eq!(vc.key(6), &k9[..], "c wrote its own divergent row");
        }
        let (lookups, hits) = pool.prefix_stats();
        assert_eq!((lookups, hits), (2, 2));
        assert_eq!(pool.prefix_hit_tokens(), 14);
    }

    #[test]
    fn free_then_reuse_keeps_refcounts_exact() {
        let mut pool = KvSlotPool::with_config(2, 1, 8, 2, cfg(4, true));
        let prompt: Vec<i32> = (0..8).collect();
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 1);
        pool.register_prefix(a, &prompt);
        let b = pool.alloc().unwrap();
        assert_eq!(pool.attach_prefix(b, &prompt), 7, "full block + 3-row COW");
        assert_eq!(pool.blocks_in_use(), 3, "2 of a's + b's COW tail");
        // Free b: its COW block frees, the shared block survives (a +
        // tree still hold it).
        pool.free(b);
        assert_eq!(pool.blocks_in_use(), 2);
        // Free a: blocks stay pinned by the tree alone.
        pool.free(a);
        assert_eq!(pool.blocks_in_use(), 2, "tree retains the registered chain");
        // Re-admit the same prompt: full reuse, no new blocks, and the
        // reused slot is the lowest-numbered free one.
        let c = pool.alloc().unwrap();
        assert_eq!(c, 0);
        assert_eq!(pool.attach_prefix(c, &prompt), 7);
        assert_eq!(pool.blocks_in_use(), 3, "one fresh COW block only");
    }

    #[test]
    fn eviction_reclaims_retired_chains_under_pressure() {
        // 2 slots × 8/4 = 4 blocks, no headroom. A registered 2-block
        // chain must be evicted once two fresh sequences need all blocks.
        let mut pool = KvSlotPool::with_config(2, 1, 8, 2, cfg(4, true));
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 1);
        pool.register_prefix(a, &(0..8).collect::<Vec<i32>>());
        pool.free(a);
        assert_eq!(pool.blocks_in_use(), 2, "retired chain retained by the tree");
        // Two sequences with unrelated prompts: 4 blocks needed, only 2
        // free — the cached chain is evicted LRU-first, pushes never fail.
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.attach_prefix(b, &(100..108).collect::<Vec<i32>>()), 0);
        fill(&mut pool, b, 2, 8, 1);
        fill(&mut pool, c, 3, 8, 1);
        assert_eq!(pool.blocks_in_use(), 4);
        assert_eq!(pool.evicted_blocks(), 2, "both cached blocks reclaimed");
        // The data of the live sequences is intact.
        let (k, _) = row(3, 5);
        assert_eq!(pool.view(c, 0).key(5), &k[..]);
    }

    #[test]
    fn eviction_never_drops_a_chain_a_live_slot_references() {
        // 2 slots × 8/4 = 4 blocks. a registers+retires a 2-block chain;
        // b attaches it (1 shared + 1 COW); c then needs 2 fresh blocks
        // with only 1 free — eviction may take the *unreferenced* tail of
        // the cached chain but must leave the block b shares alone.
        let mut pool = KvSlotPool::with_config(2, 1, 8, 2, cfg(4, true));
        let prompt: Vec<i32> = (50..58).collect();
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 1);
        pool.register_prefix(a, &prompt);
        pool.free(a);
        let b = pool.alloc().unwrap();
        assert_eq!(pool.attach_prefix(b, &prompt), 7);
        fill(&mut pool, b, 1, 8, 1); // finish the last position privately
        assert_eq!(pool.blocks_in_use(), 3, "shared + tree tail + COW");
        // c's unrelated 8-token sequence forces one eviction (the tree's
        // unreferenced second block) — and only one.
        let c = pool.alloc().unwrap();
        fill(&mut pool, c, 4, 8, 1);
        assert_eq!(pool.evicted_blocks(), 1, "only the unreferenced tail evicted");
        // b's shared head still reads a's original rows, bit for bit.
        for t in 0..7 {
            let (k, v) = row(1, t);
            assert_eq!(pool.view(b, 0).key(t), &k[..], "live shared chain corrupted");
            assert_eq!(pool.view(b, 0).value(t), &v[..]);
        }
    }

    #[test]
    fn attach_cow_survives_total_pool_pressure() {
        // Regression: under total pool pressure the COW source may be the
        // only evictable block. The eviction loop must be able to take it
        // (it must NOT be pinned — that deadlocks into the exhaustion
        // panic) and hand its storage back as the COW destination, where
        // the rows already sit. 2 slots × 8/2 = 8 blocks, no headroom.
        let mut pool = KvSlotPool::with_config(2, 1, 8, 2, cfg(2, true));
        let a_prompt: Vec<i32> = (10..18).collect();
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 1);
        pool.register_prefix(a, &a_prompt); // all 4 blocks enter the tree
        pool.free(a);
        // An unrelated full-capacity sequence takes the other 4 blocks.
        let g = pool.alloc().unwrap();
        fill(&mut pool, g, 2, 8, 1);
        assert_eq!(pool.blocks_in_use(), 8, "pool fully committed");
        // Attach a prompt sharing 7 of a's 8 tokens: 3 full matches plus
        // a mid-block COW whose only allocatable block is the (evicted)
        // source itself. Must not panic, must keep the rows bit-exact.
        pool.free(g); // g retires; its blocks free up for the tail pushes
        let mut f_prompt = a_prompt.clone();
        f_prompt[7] = 99;
        let f = pool.alloc().unwrap();
        // Re-create total pressure for the COW allocation itself: g's
        // freed blocks get soaked up by a fresh full-capacity sequence.
        let g2 = pool.alloc().unwrap();
        fill(&mut pool, g2, 3, 8, 1);
        let hit = pool.attach_prefix(f, &f_prompt);
        assert_eq!(hit, 7, "3 shared blocks + a 1-row COW");
        assert_eq!(pool.evicted_blocks(), 1, "the source leaf was reclaimed");
        fill(&mut pool, f, 1, 8, 1); // finish the final position
        for t in 0..7 {
            let (k, v) = row(1, t);
            assert_eq!(pool.view(f, 0).key(t), &k[..], "COW rows corrupted");
            assert_eq!(pool.view(f, 0).value(t), &v[..]);
        }
    }

    #[test]
    fn free_after_abnormal_exit_returns_accounting_to_baseline() {
        // The serving tier's failure paths (cancellation, deadline
        // expiry, a worker panic caught mid-forward) free a slot in
        // whatever state the interruption left it: per-layer lengths
        // disagreeing, a prompt half-prefilled, a shared prefix attached
        // with a COW split. `free` must return block accounting exactly
        // to baseline in every such state, and the slot must be reusable.
        let mut pool = KvSlotPool::with_config(2, 2, 8, 2, cfg(2, true));

        // (1) Mid-forward inconsistency: layer 0 has 3 rows, layer 1 has
        // none — the state a panic between layer forwards leaves behind.
        let s = pool.alloc().unwrap();
        for t in 0..3 {
            let (k, v) = row(7, t);
            pool.push(s, 0, &k, &v);
        }
        assert!(pool.blocks_in_use() > 0);
        assert_ne!(pool.layer_len(s, 0), pool.layer_len(s, 1));
        pool.free(s);
        assert_eq!(pool.blocks_in_use(), 0, "partial chain leaked");

        // (2) Shared-prefix baseline: register a retained chain, then
        // kill an attached request mid-flight. The retained blocks are
        // the baseline; the failed request's private tail and COW block
        // must come back exactly.
        let prompt: Vec<i32> = (30..38).collect();
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 2);
        pool.register_prefix(a, &prompt);
        pool.free(a);
        let baseline = pool.blocks_in_use();
        assert!(baseline > 0, "retained cache chain is the baseline");
        let mut diverged = prompt.clone();
        diverged[7] = 99;
        let b = pool.alloc().unwrap();
        assert!(pool.attach_prefix(b, &diverged) > 0);
        fill(&mut pool, b, 1, 8, 2); // private tail past the shared head
        assert!(pool.blocks_in_use() > baseline);
        pool.free(b); // the abnormal exit
        assert_eq!(
            pool.blocks_in_use(),
            baseline,
            "refcounts must return exactly to the retained baseline"
        );

        // (3) Freed capacity is genuinely reusable: both slots fill to
        // sequence capacity afterwards (evicting the retained chain if
        // the allocator needs it — that is its job, not a leak).
        let x = pool.alloc().unwrap();
        let y = pool.alloc().unwrap();
        fill(&mut pool, x, 2, 8, 2);
        fill(&mut pool, y, 3, 8, 2);
        assert_eq!(pool.seq_len(x), 8);
        assert_eq!(pool.seq_len(y), 8);
    }

    #[test]
    fn truncate_releases_trailing_blocks_and_keeps_the_head_bitwise() {
        let mut pool = KvSlotPool::with_config(1, 2, 12, 2, cfg(3, false));
        let s = pool.alloc().unwrap();
        fill(&mut pool, s, 5, 11, 2); // 4 blocks: 3+3+3+2 rows
        assert_eq!(pool.blocks_in_use(), 4);
        // Mid-block rollback: the partially covered block survives.
        pool.truncate(s, 7);
        assert_eq!(pool.seq_len(s), 7);
        assert_eq!(pool.layer_len(s, 1), 7);
        assert_eq!(pool.blocks_in_use(), 3, "only the wholly dead block freed");
        for t in 0..7 {
            let (k, v) = row(5, t);
            assert_eq!(pool.view(s, 0).key(t), &k[..], "head rows must survive");
            assert_eq!(pool.view(s, 1).value(t), &v[..]);
        }
        // Re-pushing past the cut overwrites the dead tail rows in place
        // and regrows the chain — exactly like a fresh decode.
        fill(&mut pool, s, 9, 12, 2);
        assert_eq!(pool.seq_len(s), 12);
        let (k9, _) = row(9, 7);
        assert_eq!(pool.view(s, 0).key(7), &k9[..], "rollback rows overwritten");
        // Boundary rollback frees every trailing block; truncate to the
        // current length is a no-op.
        pool.truncate(s, 6);
        assert_eq!(pool.blocks_in_use(), 2);
        pool.truncate(s, 6);
        assert_eq!(pool.blocks_in_use(), 2);
        pool.truncate(s, 0);
        assert_eq!(pool.blocks_in_use(), 0);
        pool.free(s);
    }

    #[test]
    fn truncate_never_pops_shared_prefix_blocks() {
        // A rollback at the verified frontier of an attached sequence
        // releases only private tail blocks; the shared (tree-referenced)
        // head keeps its refcounts and bytes.
        let mut pool = KvSlotPool::with_config(2, 1, 12, 2, cfg(4, true));
        let prompt: Vec<i32> = (0..8).collect();
        let a = pool.alloc().unwrap();
        fill(&mut pool, a, 1, 8, 1);
        pool.register_prefix(a, &prompt);
        pool.free(a);
        let baseline = pool.blocks_in_use();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.attach_prefix(b, &prompt), 7);
        fill(&mut pool, b, 1, 8, 1); // finish the prompt's last position
        // Simulate a verify forward: 3 speculative rows past the prompt,
        // then roll back to one accepted token.
        fill(&mut pool, b, 2, 11, 1);
        pool.truncate(b, 9);
        assert_eq!(pool.seq_len(b), 9);
        for t in 0..7 {
            let (k, _) = row(1, t);
            assert_eq!(pool.view(b, 0).key(t), &k[..], "shared head corrupted");
        }
        let (k2, _) = row(2, 8);
        assert_eq!(pool.view(b, 0).key(8), &k2[..], "accepted row corrupted");
        pool.free(b);
        assert_eq!(pool.blocks_in_use(), baseline, "rollback leaked blocks");
    }

    #[test]
    fn propose_continuation_is_gated_on_the_cache() {
        let mut off = KvSlotPool::with_config(1, 1, 8, 2, cfg(4, false));
        let s = off.alloc().unwrap();
        assert!(off.propose_continuation(&[1, 2, 3], 4).is_empty());
        assert_eq!(off.cached_blocks(), 0);
        off.free(s);
        let mut on = KvSlotPool::with_config(1, 1, 8, 2, cfg(2, true));
        let s = on.alloc().unwrap();
        let prompt: Vec<i32> = vec![4, 5, 6, 7, 8, 9];
        fill(&mut on, s, 1, 6, 1);
        on.register_prefix(s, &prompt);
        assert_eq!(on.cached_blocks(), 3);
        // A second request that has generated [4,5,6] so far drafts the
        // registered continuation, token-exact.
        assert_eq!(on.propose_continuation(&[4, 5, 6], 2), vec![7, 8]);
        assert_eq!(on.propose_continuation(&[4, 5, 6, 7], 8), vec![8, 9]);
        assert!(on.propose_continuation(&[4, 9], 2).is_empty());
    }

    #[test]
    fn attach_disabled_or_trivial_is_a_no_op() {
        let mut off = KvSlotPool::with_config(1, 1, 8, 2, cfg(4, false));
        let s = off.alloc().unwrap();
        assert_eq!(off.attach_prefix(s, &[1, 2, 3, 4]), 0);
        assert!(!off.prefix_cache_enabled());
        off.register_prefix(s, &[1, 2, 3, 4]); // must not panic
        let mut on = KvSlotPool::with_config(1, 1, 8, 2, cfg(4, true));
        let s = on.alloc().unwrap();
        assert_eq!(on.attach_prefix(s, &[9]), 0, "single-token prompt never hits");
        assert_eq!(on.prefix_stats(), (0, 0), "trivial prompts skip the probe");
    }
}
