//! Per-sequence key/value cache for autoregressive decode.

/// KV cache for one transformer layer and one sequence: rows are time
/// steps, `d_model` columns split across heads by the engine.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub len: usize,
    d_model: usize,
    capacity: usize,
}

impl KvCache {
    pub fn new(capacity: usize, d_model: usize) -> KvCache {
        KvCache {
            keys: vec![0.0; capacity * d_model],
            values: vec![0.0; capacity * d_model],
            len: 0,
            d_model,
            capacity,
        }
    }

    /// Append one time step.
    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert!(self.len < self.capacity, "kv cache overflow");
        assert_eq!(k.len(), self.d_model);
        assert_eq!(v.len(), self.d_model);
        let off = self.len * self.d_model;
        self.keys[off..off + self.d_model].copy_from_slice(k);
        self.values[off..off + self.d_model].copy_from_slice(v);
        self.len += 1;
    }

    /// Key row at time `t`.
    #[inline]
    pub fn key(&self, t: usize) -> &[f32] {
        &self.keys[t * self.d_model..(t + 1) * self.d_model]
    }

    #[inline]
    pub fn value(&self, t: usize) -> &[f32] {
        &self.values[t * self.d_model..(t + 1) * self.d_model]
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 3);
        c.push(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        c.push(&[7.0, 8.0, 9.0], &[1.5, 2.5, 3.5]);
        assert_eq!(c.len, 2);
        assert_eq!(c.key(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.value(1), &[1.5, 2.5, 3.5]);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 2);
        c.push(&[0.0, 0.0], &[0.0, 0.0]);
        c.push(&[0.0, 0.0], &[0.0, 0.0]);
    }
}
