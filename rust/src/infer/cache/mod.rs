//! Paged KV-cache storage: a refcounted [`BlockPool`] of fixed-size
//! token blocks plus a [`RadixTree`] prefix index that maps prompt heads
//! to shared, immutable block chains (copy-on-write at the first
//! divergent block, LRU eviction of unreferenced chains under pool
//! pressure).
//!
//! [`KvSlotPool`](crate::infer::KvSlotPool) composes the two into the
//! sequence-slot API the engine and the continuous-batching scheduler
//! drive; see DESIGN.md "KV cache subsystem" for the block/tree diagram,
//! the sharing rules, and the determinism argument.

mod block;
mod radix;

pub use block::BlockPool;
pub use radix::{FullMatch, PartialMatch, RadixTree};
