//! Radix tree over token-id prefixes at **block granularity** — the
//! index that lets requests sharing a prompt head reuse each other's KV
//! blocks instead of re-running prefill GEMMs over identical tokens.
//!
//! Every node covers exactly one *full* block: `block_size` token ids
//! (the edge label) plus the block holding those positions' K/V state
//! for every layer. A path from the root therefore spells out a prompt
//! prefix in `block_size`-token steps, and the chain of blocks along the
//! path is immutable, shared state (the tree holds one refcount on each
//! node's block).
//!
//! * [`RadixTree::lookup`] walks a prompt down the tree, returning the
//!   chain of fully matching blocks plus — when the prompt diverges
//!   *mid-block* — the deepest partially matching node and how many of
//!   its tokens match, so the caller can copy-on-write the matching head
//!   of that block into a private one.
//! * [`RadixTree::insert`] registers a prefilled prompt's full blocks,
//!   adding refcounts only for nodes that do not already exist (an
//!   identical prefix registered twice keeps the first chain).
//! * [`RadixTree::evict_one`] reclaims the least-recently-used **leaf**
//!   whose block no live sequence references (pool refcount 1 — the
//!   tree's own), so eviction frees real memory, never truncates a chain
//!   a descendant still needs, and never touches data a slot still reads.
//! * [`RadixTree::propose`] reads draft continuations for speculative
//!   decoding straight out of the edge labels: a sequence whose history
//!   walks to a node proposes the tokens spelled by the chain below it.
//!   The walk is read-only — drafting never perturbs eviction order.
//!
//! Recency is a monotonic operation counter, not wall-clock time, so
//! eviction order is a deterministic function of the operation sequence.
//! It is indexed in an ordered set keyed `(last_use, id)` — the exact
//! order the original linear full-node scan minimized over — so
//! [`RadixTree::evict_one`] finds its victim by walking candidates from
//! the LRU end (`O(log n)` per recency update, and the eviction scan
//! touches only the stale end of the order instead of every node).

use super::block::BlockPool;
use std::collections::BTreeSet;

const NO_NODE: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    /// Edge label: exactly `block_size` token ids.
    tokens: Vec<i32>,
    /// The shared KV block holding those positions (tree owns one ref).
    block: usize,
    children: Vec<usize>,
    parent: usize, // NO_NODE for root-level nodes
    last_use: u64,
    live: bool,
}

/// Block-granularity prefix tree with LRU leaf eviction. See the module
/// docs for the sharing and eviction rules.
#[derive(Debug)]
pub struct RadixTree {
    block_size: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    /// Children of the (implicit) root.
    roots: Vec<usize>,
    tick: u64,
    /// Total blocks evicted over the tree's lifetime.
    evicted: u64,
    /// Every live node keyed by `(last_use, id)` — ascending iteration
    /// visits nodes in exactly the order the old linear eviction scan
    /// ranked them, so `evict_one` takes the first eligible entry.
    /// Maintained by [`RadixTree::touch`] on every recency bump.
    by_recency: BTreeSet<(u64, usize)>,
}

/// One fully matched step of a [`RadixTree::lookup`]: the node's block id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FullMatch {
    /// Block holding the matched `block_size` tokens.
    pub block: usize,
}

/// A mid-block divergence found by [`RadixTree::lookup`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartialMatch {
    /// Block whose first `matched` token rows agree with the prompt.
    pub block: usize,
    /// How many leading tokens of that block match (`1..block_size`).
    pub matched: usize,
}

impl RadixTree {
    /// Empty tree for `block_size`-token blocks.
    pub fn new(block_size: usize) -> RadixTree {
        assert!(block_size > 0);
        RadixTree {
            block_size,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            tick: 0,
            evicted: 0,
            by_recency: BTreeSet::new(),
        }
    }

    /// Nodes currently in the tree.
    pub fn len(&self) -> usize {
        self.nodes.len() - self.free_nodes.len()
    }

    /// Whether the tree holds no chains.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks evicted over the tree's lifetime.
    pub fn evicted_blocks(&self) -> u64 {
        self.evicted
    }

    /// Child list of `parent` (`NO_NODE` = the implicit root).
    fn children_of(&self, parent: usize) -> &[usize] {
        if parent == NO_NODE {
            &self.roots
        } else {
            &self.nodes[parent].children
        }
    }

    /// Among `parent`'s children, the node whose `tokens` equal `want`.
    fn find_full(&self, parent: usize, want: &[i32]) -> Option<usize> {
        self.children_of(parent)
            .iter()
            .copied()
            .find(|&c| self.nodes[c].tokens == want)
    }

    /// Bump `id`'s recency to `tick`, keeping the ordered index in sync
    /// (`O(log n)`). The sole place `last_use` ever changes, so the
    /// invariant `by_recency == {(n.last_use, id) : live n}` holds by
    /// construction.
    fn touch(&mut self, id: usize, tick: u64) {
        let prev = self.nodes[id].last_use;
        self.by_recency.remove(&(prev, id));
        self.by_recency.insert((tick, id));
        self.nodes[id].last_use = tick;
    }

    /// Among `parent`'s children, the node sharing the longest non-empty
    /// token prefix with `want` (ties keep the earliest-inserted sibling
    /// — deterministic in the insertion order).
    fn find_partial(&self, parent: usize, want: &[i32]) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for &c in self.children_of(parent) {
            let j = self.nodes[c]
                .tokens
                .iter()
                .zip(want)
                .take_while(|(a, b)| a == b)
                .count();
            let better = match best {
                None => true,
                Some((_, bj)) => j > bj,
            };
            if j > 0 && better {
                best = Some((c, j));
            }
        }
        best
    }

    /// Walk `tokens` down the tree. Returns the chain of fully matched
    /// blocks (in prefix order) and, if the walk ended on a mid-block
    /// divergence, the partially matching block. Bumps recency along the
    /// whole matched path.
    pub fn lookup(&mut self, tokens: &[i32]) -> (Vec<FullMatch>, Option<PartialMatch>) {
        self.tick += 1;
        let tick = self.tick;
        let bs = self.block_size;
        let mut full = Vec::new();
        let mut off = 0;
        let mut parent = NO_NODE;
        while tokens.len() - off >= bs {
            match self.find_full(parent, &tokens[off..off + bs]) {
                Some(c) => {
                    self.touch(c, tick);
                    full.push(FullMatch {
                        block: self.nodes[c].block,
                    });
                    off += bs;
                    parent = c;
                }
                None => break,
            }
        }
        let partial = self.find_partial(parent, &tokens[off..]).map(|(c, j)| {
            (
                c,
                PartialMatch {
                    block: self.nodes[c].block,
                    matched: j,
                },
            )
        });
        if let Some((c, _)) = partial {
            self.touch(c, tick);
        }
        (full, partial.map(|(_, p)| p))
    }

    /// Propose up to `k` draft tokens continuing `history` (a sequence's
    /// full token stream so far, prompt plus generated) from cached
    /// chains: walk the history down the tree, then read continuation
    /// token ids straight out of the edge labels below the walk's end.
    /// At a branch the earliest-inserted child is followed —
    /// deterministic in the insertion order, like the rest of the tree.
    /// Returns an empty draft when the history diverges from every
    /// cached chain (the caller falls back to plain decode).
    ///
    /// Read-only on recency (`&self`): drafting is a hint, and must not
    /// perturb the eviction order that `lookup`/`insert` define —
    /// speculative serving evicts exactly like non-speculative serving.
    pub fn propose(&self, history: &[i32], k: usize) -> Vec<i32> {
        let bs = self.block_size;
        let mut off = 0;
        let mut parent = NO_NODE;
        while history.len() - off >= bs {
            match self.find_full(parent, &history[off..off + bs]) {
                Some(c) => {
                    off += bs;
                    parent = c;
                }
                None => return Vec::new(), // diverged on a full block
            }
        }
        let mut out = Vec::new();
        let rem = &history[off..];
        if !rem.is_empty() {
            // The in-block tail must match a child's label exactly for
            // the label's remainder to be a valid continuation.
            match self
                .children_of(parent)
                .iter()
                .copied()
                .find(|&c| self.nodes[c].tokens[..rem.len()] == *rem)
            {
                Some(c) => {
                    out.extend_from_slice(&self.nodes[c].tokens[rem.len()..]);
                    parent = c;
                }
                None => return out,
            }
        }
        while out.len() < k {
            let children = self.children_of(parent);
            let Some(&c) = children.first() else {
                break;
            };
            out.extend_from_slice(&self.nodes[c].tokens);
            parent = c;
        }
        out.truncate(k);
        out
    }

    /// Register a prefilled prompt: `tokens` must cover exactly
    /// `blocks.len() * block_size` positions and `blocks[i]` must hold
    /// positions `[i*bs, (i+1)*bs)`. Existing nodes along the path are
    /// kept (their blocks stay authoritative); each newly created node
    /// retains its sequence block in `pool`.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[usize], pool: &mut BlockPool) {
        let bs = self.block_size;
        assert_eq!(tokens.len(), blocks.len() * bs, "insert covers full blocks only");
        self.tick += 1;
        let tick = self.tick;
        let mut parent = NO_NODE;
        for (i, &block) in blocks.iter().enumerate() {
            let want = &tokens[i * bs..(i + 1) * bs];
            let next = match self.find_full(parent, want) {
                Some(c) => c,
                None => {
                    pool.retain(block);
                    let id = self.new_node(Node {
                        tokens: want.to_vec(),
                        block,
                        children: Vec::new(),
                        parent,
                        last_use: tick,
                        live: true,
                    });
                    if parent == NO_NODE {
                        self.roots.push(id);
                    } else {
                        self.nodes[parent].children.push(id);
                    }
                    id
                }
            };
            // New nodes enter the index here too: they were created with
            // `last_use == tick`, so touch's remove is a no-op and its
            // insert registers them.
            self.touch(next, tick);
            parent = next;
        }
    }

    fn new_node(&mut self, node: Node) -> usize {
        match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Evict the least-recently-used leaf whose block only the tree
    /// references (pool refcount 1), releasing the block back to `pool`.
    /// Returns `false` when no such leaf exists — every remaining chain
    /// is still pinned by a live sequence. Ties break toward the lowest
    /// node id, so eviction order is deterministic — and **identical to
    /// the original linear full-node scan**, which minimized
    /// `(last_use, id)` over eligible nodes: the recency index iterates
    /// ascending on exactly that key, so the first eligible entry is the
    /// same victim (pinned by a regression test against the old scan).
    /// Recency updates are `O(log n)` and this scan stops at the first
    /// evictable node instead of ranking all of them.
    pub fn evict_one(&mut self, pool: &mut BlockPool) -> bool {
        debug_assert_eq!(self.by_recency.len(), self.len(), "recency index out of sync");
        let victim = self
            .by_recency
            .iter()
            .copied()
            .find(|&(_, id)| {
                let n = &self.nodes[id];
                n.live && n.children.is_empty() && pool.refcount(n.block) == 1
            })
            .map(|(_, id)| id);
        let Some(id) = victim else {
            return false;
        };
        let parent = self.nodes[id].parent;
        if parent == NO_NODE {
            self.roots.retain(|&c| c != id);
        } else {
            self.nodes[parent].children.retain(|&c| c != id);
        }
        let freed = pool.release(self.nodes[id].block);
        debug_assert!(freed, "evicted leaf held the only reference");
        self.by_recency.remove(&(self.nodes[id].last_use, id));
        self.nodes[id].live = false;
        self.nodes[id].children = Vec::new();
        self.nodes[id].tokens = Vec::new();
        self.free_nodes.push(id);
        self.evicted += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(8, 1, 2, 1)
    }

    /// Alloc a block and stamp its first key element for identification.
    fn stamped(p: &mut BlockPool, v: f32) -> usize {
        let b = p.alloc().unwrap();
        p.write_row(b, 0, 0, &[v], &[v]);
        b
    }

    #[test]
    fn lookup_matches_full_and_partial_blocks() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        t.insert(&[10, 11, 12, 13], &[b0, b1], &mut p);
        assert_eq!(p.refcount(b0), 2, "tree retains registered blocks");
        // Full hit on both blocks.
        let (full, partial) = t.lookup(&[10, 11, 12, 13, 99]);
        assert_eq!(full, vec![FullMatch { block: b0 }, FullMatch { block: b1 }]);
        assert_eq!(partial, None);
        // Mid-block divergence in the second block: one token matches.
        let (full, partial) = t.lookup(&[10, 11, 12, 99]);
        assert_eq!(full, vec![FullMatch { block: b0 }]);
        assert_eq!(partial, Some(PartialMatch { block: b1, matched: 1 }));
        // Prompt shorter than one block: partial on the first block.
        let (full, partial) = t.lookup(&[10]);
        assert!(full.is_empty());
        assert_eq!(partial, Some(PartialMatch { block: b0, matched: 1 }));
        // Divergence at the very first token: no match at all.
        let (full, partial) = t.lookup(&[99, 11]);
        assert!(full.is_empty());
        assert_eq!(partial, None);
    }

    #[test]
    fn insert_existing_path_adds_no_refs_or_nodes() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        t.insert(&[1, 2, 3, 4], &[b0, b1], &mut p);
        assert_eq!(t.len(), 2);
        // Same prefix, different physical blocks (a cold duplicate that
        // was prefilled privately): the existing chain stays canonical.
        let (c0, c1) = (stamped(&mut p, 2.0), stamped(&mut p, 3.0));
        t.insert(&[1, 2, 3, 4], &[c0, c1], &mut p);
        assert_eq!(t.len(), 2, "no duplicate nodes");
        assert_eq!(p.refcount(c0), 1, "duplicate blocks not retained");
        assert_eq!(p.refcount(b0), 2);
        // Diverging second block forks the tree under the shared head.
        let d1 = stamped(&mut p, 4.0);
        t.insert(&[1, 2, 7, 8], &[c0, d1], &mut p);
        assert_eq!(t.len(), 3, "one new node for the fork");
        assert_eq!(p.refcount(d1), 2);
        assert_eq!(p.refcount(c0), 1, "existing head node kept its own block");
    }

    #[test]
    fn eviction_is_lru_leaf_only_and_skips_live_blocks() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        let b2 = stamped(&mut p, 2.0);
        t.insert(&[1, 2, 3, 4], &[b0, b1], &mut p);
        t.insert(&[1, 2, 5, 6], &[b0, b2], &mut p);
        // Drop the sequences' own refs: blocks now tree-only.
        for b in [b0, b1, b2] {
            p.release(b);
        }
        assert_eq!(p.blocks_in_use(), 3);
        // Touch the [1,2,5,6] chain so [1,2,3,4]'s leaf is the LRU.
        let _ = t.lookup(&[1, 2, 5, 6]);
        assert!(t.evict_one(&mut p));
        assert_eq!(p.refcount(b1), 0, "LRU leaf b1 evicted first");
        assert_eq!(p.refcount(b0), 1, "interior node survives (has a child)");
        // Pin b2 as a live sequence would; eviction must skip it and,
        // with b0 interior, report nothing evictable.
        p.retain(b2);
        assert!(!t.evict_one(&mut p), "only leaf is live-referenced");
        assert_eq!(p.refcount(b2), 2, "live chain untouched");
        // Unpin: leaf b2 goes, then b0 becomes an evictable leaf.
        p.release(b2);
        assert!(t.evict_one(&mut p));
        assert!(t.evict_one(&mut p));
        assert!(t.is_empty());
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(t.evicted_blocks(), 3);
    }

    #[test]
    fn propose_reads_continuations_from_edge_labels() {
        let mut p = BlockPool::new(8, 1, 2, 1);
        let mut t = RadixTree::new(2);
        let blocks: Vec<usize> = (0..4).map(|i| stamped(&mut p, i as f32)).collect();
        // Chain [1,2][3,4][5,6] plus a fork [1,2][7,8] inserted later.
        t.insert(&[1, 2, 3, 4, 5, 6], &blocks[..3], &mut p);
        t.insert(&[1, 2, 7, 8], &[blocks[0], blocks[3]], &mut p);
        // History ending on a block boundary: continue down the
        // earliest-inserted branch.
        assert_eq!(t.propose(&[1, 2], 4), vec![3, 4, 5, 6]);
        assert_eq!(t.propose(&[1, 2], 3), vec![3, 4, 5]);
        assert_eq!(t.propose(&[1, 2, 3, 4], 8), vec![5, 6], "draft capped by the chain");
        // Mid-block history: the label's remainder comes first.
        assert_eq!(t.propose(&[1, 2, 3], 4), vec![4, 5, 6]);
        assert_eq!(t.propose(&[1, 2, 7], 4), vec![8]);
        // Divergence (full-block or in-block) proposes nothing.
        assert!(t.propose(&[1, 9], 4).is_empty());
        assert!(t.propose(&[1, 2, 9], 4).is_empty());
        assert!(t.propose(&[9, 9, 9], 4).is_empty());
        // Exhausted chain: history walked to a leaf, nothing below.
        assert!(t.propose(&[1, 2, 3, 4, 5, 6], 4).is_empty());
        assert_eq!(t.propose(&[], 3), vec![1, 2, 3], "empty history starts at the root");
    }

    #[test]
    fn propose_is_read_only_on_recency() {
        // Drafting must not perturb eviction order: after proposing from
        // the older chain many times, the older chain still evicts first.
        let mut p = BlockPool::new(8, 1, 2, 1);
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        t.insert(&[1, 2], &[b0], &mut p);
        t.insert(&[5, 6], &[b1], &mut p);
        p.release(b0);
        p.release(b1);
        let _ = t.lookup(&[5, 6]); // [1,2] is now strictly older
        for _ in 0..8 {
            let _ = t.propose(&[1], 2); // would bump [1,2] if it wrote recency
        }
        assert!(t.evict_one(&mut p));
        assert_eq!(p.refcount(b0), 0, "older chain must still evict first");
        assert_eq!(p.refcount(b1), 1);
    }

    /// The pre-BTreeSet eviction policy, verbatim: linear scan over all
    /// nodes minimizing `(last_use, id)` among live, childless,
    /// tree-only-referenced nodes. The regression oracle for the ordered
    /// recency index.
    fn old_scan_victim(t: &RadixTree, p: &BlockPool) -> Option<usize> {
        t.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.live && n.children.is_empty() && p.refcount(n.block) == 1)
            .min_by_key(|(id, n)| (n.last_use, *id))
            .map(|(id, _)| id)
    }

    #[test]
    fn eviction_order_matches_the_old_linear_scan() {
        // Randomized regression: across seeded insert/lookup/pin/unpin
        // churn, every eviction must pick exactly the node the original
        // linear scan would have picked, until both agree nothing is
        // evictable. Catches any divergence between the ordered recency
        // index and the scan it replaced (stale entries, tie-breaks,
        // missed bumps).
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5EC0_11D5);
        for round in 0..20u64 {
            // Sized generously past the worst-case live-node count so the
            // churn itself never exhausts the pool.
            let mut p = BlockPool::new(160, 1, 2, 1);
            let mut t = RadixTree::new(2);
            let mut rng = rng.fork(round);
            // Small alphabet of 2-token labels so paths collide and fork.
            let label = |v: usize| [2 * v as i32, 2 * v as i32 + 1];
            let mut pinned: Vec<usize> = Vec::new();
            for _ in 0..40 {
                match rng.below(10) {
                    0..=4 => {
                        // Insert a random path of depth 1..=3.
                        let depth = rng.range(1, 4);
                        let mut tokens = Vec::new();
                        let mut blocks = Vec::new();
                        for _ in 0..depth {
                            tokens.extend_from_slice(&label(rng.below(4)));
                            blocks.push(p.alloc().expect("pool sized for the churn"));
                        }
                        t.insert(&tokens, &blocks, &mut p);
                        // Drop the "sequence's" own refs: blocks the tree
                        // did not retain (duplicates) free immediately,
                        // the rest become tree-only.
                        for b in blocks {
                            p.release(b);
                        }
                    }
                    5..=7 => {
                        // Recency churn: look up a random path.
                        let depth = rng.range(1, 4);
                        let mut tokens = Vec::new();
                        for _ in 0..depth {
                            tokens.extend_from_slice(&label(rng.below(4)));
                        }
                        let _ = t.lookup(&tokens);
                    }
                    8 => {
                        // Pin a random live node's block, as an attached
                        // sequence would.
                        let live: Vec<usize> =
                            (0..t.nodes.len()).filter(|&i| t.nodes[i].live).collect();
                        if !live.is_empty() {
                            let b = t.nodes[live[rng.below(live.len())]].block;
                            p.retain(b);
                            pinned.push(b);
                        }
                    }
                    _ => {
                        // Interleave an eviction mid-churn.
                        let want = old_scan_victim(&t, &p);
                        let got = t.evict_one(&mut p);
                        match want {
                            Some(id) => {
                                assert!(got);
                                assert!(!t.nodes[id].live, "victim diverged from the old scan");
                            }
                            None => assert!(!got),
                        }
                    }
                }
            }
            // Drain: eviction order must match the old scan node by node.
            loop {
                let want = old_scan_victim(&t, &p);
                let got = t.evict_one(&mut p);
                match want {
                    Some(id) => {
                        assert!(got, "old scan found a victim the index missed");
                        assert!(!t.nodes[id].live, "victim diverged from the old scan");
                    }
                    None => {
                        assert!(!got, "index evicted what the old scan would not");
                        break;
                    }
                }
            }
            // Everything left is pinned; unpin and the tree drains fully.
            for b in pinned {
                p.release(b);
            }
            while t.evict_one(&mut p) {}
            assert!(t.is_empty());
            assert_eq!(p.blocks_in_use(), 0, "round {round} leaked blocks");
        }
    }
}
