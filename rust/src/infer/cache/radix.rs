//! Radix tree over token-id prefixes at **block granularity** — the
//! index that lets requests sharing a prompt head reuse each other's KV
//! blocks instead of re-running prefill GEMMs over identical tokens.
//!
//! Every node covers exactly one *full* block: `block_size` token ids
//! (the edge label) plus the block holding those positions' K/V state
//! for every layer. A path from the root therefore spells out a prompt
//! prefix in `block_size`-token steps, and the chain of blocks along the
//! path is immutable, shared state (the tree holds one refcount on each
//! node's block).
//!
//! * [`RadixTree::lookup`] walks a prompt down the tree, returning the
//!   chain of fully matching blocks plus — when the prompt diverges
//!   *mid-block* — the deepest partially matching node and how many of
//!   its tokens match, so the caller can copy-on-write the matching head
//!   of that block into a private one.
//! * [`RadixTree::insert`] registers a prefilled prompt's full blocks,
//!   adding refcounts only for nodes that do not already exist (an
//!   identical prefix registered twice keeps the first chain).
//! * [`RadixTree::evict_one`] reclaims the least-recently-used **leaf**
//!   whose block no live sequence references (pool refcount 1 — the
//!   tree's own), so eviction frees real memory, never truncates a chain
//!   a descendant still needs, and never touches data a slot still reads.
//!
//! Recency is a monotonic operation counter, not wall-clock time, so
//! eviction order is a deterministic function of the operation sequence.

use super::block::BlockPool;

const NO_NODE: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    /// Edge label: exactly `block_size` token ids.
    tokens: Vec<i32>,
    /// The shared KV block holding those positions (tree owns one ref).
    block: usize,
    children: Vec<usize>,
    parent: usize, // NO_NODE for root-level nodes
    last_use: u64,
    live: bool,
}

/// Block-granularity prefix tree with LRU leaf eviction. See the module
/// docs for the sharing and eviction rules.
#[derive(Debug)]
pub struct RadixTree {
    block_size: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    /// Children of the (implicit) root.
    roots: Vec<usize>,
    tick: u64,
    /// Total blocks evicted over the tree's lifetime.
    evicted: u64,
}

/// One fully matched step of a [`RadixTree::lookup`]: the node's block id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FullMatch {
    /// Block holding the matched `block_size` tokens.
    pub block: usize,
}

/// A mid-block divergence found by [`RadixTree::lookup`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartialMatch {
    /// Block whose first `matched` token rows agree with the prompt.
    pub block: usize,
    /// How many leading tokens of that block match (`1..block_size`).
    pub matched: usize,
}

impl RadixTree {
    /// Empty tree for `block_size`-token blocks.
    pub fn new(block_size: usize) -> RadixTree {
        assert!(block_size > 0);
        RadixTree {
            block_size,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            tick: 0,
            evicted: 0,
        }
    }

    /// Nodes currently in the tree.
    pub fn len(&self) -> usize {
        self.nodes.len() - self.free_nodes.len()
    }

    /// Whether the tree holds no chains.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks evicted over the tree's lifetime.
    pub fn evicted_blocks(&self) -> u64 {
        self.evicted
    }

    /// Child list of `parent` (`NO_NODE` = the implicit root).
    fn children_of(&self, parent: usize) -> &[usize] {
        if parent == NO_NODE {
            &self.roots
        } else {
            &self.nodes[parent].children
        }
    }

    /// Among `parent`'s children, the node whose `tokens` equal `want`.
    fn find_full(&self, parent: usize, want: &[i32]) -> Option<usize> {
        self.children_of(parent)
            .iter()
            .copied()
            .find(|&c| self.nodes[c].tokens == want)
    }

    /// Among `parent`'s children, the node sharing the longest non-empty
    /// token prefix with `want` (ties keep the earliest-inserted sibling
    /// — deterministic in the insertion order).
    fn find_partial(&self, parent: usize, want: &[i32]) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for &c in self.children_of(parent) {
            let j = self.nodes[c]
                .tokens
                .iter()
                .zip(want)
                .take_while(|(a, b)| a == b)
                .count();
            let better = match best {
                None => true,
                Some((_, bj)) => j > bj,
            };
            if j > 0 && better {
                best = Some((c, j));
            }
        }
        best
    }

    /// Walk `tokens` down the tree. Returns the chain of fully matched
    /// blocks (in prefix order) and, if the walk ended on a mid-block
    /// divergence, the partially matching block. Bumps recency along the
    /// whole matched path.
    pub fn lookup(&mut self, tokens: &[i32]) -> (Vec<FullMatch>, Option<PartialMatch>) {
        self.tick += 1;
        let tick = self.tick;
        let bs = self.block_size;
        let mut full = Vec::new();
        let mut off = 0;
        let mut parent = NO_NODE;
        while tokens.len() - off >= bs {
            match self.find_full(parent, &tokens[off..off + bs]) {
                Some(c) => {
                    self.nodes[c].last_use = tick;
                    full.push(FullMatch {
                        block: self.nodes[c].block,
                    });
                    off += bs;
                    parent = c;
                }
                None => break,
            }
        }
        let partial = self.find_partial(parent, &tokens[off..]).map(|(c, j)| {
            self.nodes[c].last_use = tick;
            PartialMatch {
                block: self.nodes[c].block,
                matched: j,
            }
        });
        (full, partial)
    }

    /// Register a prefilled prompt: `tokens` must cover exactly
    /// `blocks.len() * block_size` positions and `blocks[i]` must hold
    /// positions `[i*bs, (i+1)*bs)`. Existing nodes along the path are
    /// kept (their blocks stay authoritative); each newly created node
    /// retains its sequence block in `pool`.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[usize], pool: &mut BlockPool) {
        let bs = self.block_size;
        assert_eq!(tokens.len(), blocks.len() * bs, "insert covers full blocks only");
        self.tick += 1;
        let tick = self.tick;
        let mut parent = NO_NODE;
        for (i, &block) in blocks.iter().enumerate() {
            let want = &tokens[i * bs..(i + 1) * bs];
            let next = match self.find_full(parent, want) {
                Some(c) => c,
                None => {
                    pool.retain(block);
                    let id = self.new_node(Node {
                        tokens: want.to_vec(),
                        block,
                        children: Vec::new(),
                        parent,
                        last_use: tick,
                        live: true,
                    });
                    if parent == NO_NODE {
                        self.roots.push(id);
                    } else {
                        self.nodes[parent].children.push(id);
                    }
                    id
                }
            };
            self.nodes[next].last_use = tick;
            parent = next;
        }
    }

    fn new_node(&mut self, node: Node) -> usize {
        match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Evict the least-recently-used leaf whose block only the tree
    /// references (pool refcount 1), releasing the block back to `pool`.
    /// Returns `false` when no such leaf exists — every remaining chain
    /// is still pinned by a live sequence. Ties break toward the lowest
    /// node id, so eviction order is deterministic.
    pub fn evict_one(&mut self, pool: &mut BlockPool) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.live && n.children.is_empty() && pool.refcount(n.block) == 1)
            .min_by_key(|(id, n)| (n.last_use, *id))
            .map(|(id, _)| id);
        let Some(id) = victim else {
            return false;
        };
        let parent = self.nodes[id].parent;
        if parent == NO_NODE {
            self.roots.retain(|&c| c != id);
        } else {
            self.nodes[parent].children.retain(|&c| c != id);
        }
        let freed = pool.release(self.nodes[id].block);
        debug_assert!(freed, "evicted leaf held the only reference");
        self.nodes[id].live = false;
        self.nodes[id].children = Vec::new();
        self.nodes[id].tokens = Vec::new();
        self.free_nodes.push(id);
        self.evicted += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(8, 1, 2, 1)
    }

    /// Alloc a block and stamp its first key element for identification.
    fn stamped(p: &mut BlockPool, v: f32) -> usize {
        let b = p.alloc().unwrap();
        p.write_row(b, 0, 0, &[v], &[v]);
        b
    }

    #[test]
    fn lookup_matches_full_and_partial_blocks() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        t.insert(&[10, 11, 12, 13], &[b0, b1], &mut p);
        assert_eq!(p.refcount(b0), 2, "tree retains registered blocks");
        // Full hit on both blocks.
        let (full, partial) = t.lookup(&[10, 11, 12, 13, 99]);
        assert_eq!(full, vec![FullMatch { block: b0 }, FullMatch { block: b1 }]);
        assert_eq!(partial, None);
        // Mid-block divergence in the second block: one token matches.
        let (full, partial) = t.lookup(&[10, 11, 12, 99]);
        assert_eq!(full, vec![FullMatch { block: b0 }]);
        assert_eq!(partial, Some(PartialMatch { block: b1, matched: 1 }));
        // Prompt shorter than one block: partial on the first block.
        let (full, partial) = t.lookup(&[10]);
        assert!(full.is_empty());
        assert_eq!(partial, Some(PartialMatch { block: b0, matched: 1 }));
        // Divergence at the very first token: no match at all.
        let (full, partial) = t.lookup(&[99, 11]);
        assert!(full.is_empty());
        assert_eq!(partial, None);
    }

    #[test]
    fn insert_existing_path_adds_no_refs_or_nodes() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        t.insert(&[1, 2, 3, 4], &[b0, b1], &mut p);
        assert_eq!(t.len(), 2);
        // Same prefix, different physical blocks (a cold duplicate that
        // was prefilled privately): the existing chain stays canonical.
        let (c0, c1) = (stamped(&mut p, 2.0), stamped(&mut p, 3.0));
        t.insert(&[1, 2, 3, 4], &[c0, c1], &mut p);
        assert_eq!(t.len(), 2, "no duplicate nodes");
        assert_eq!(p.refcount(c0), 1, "duplicate blocks not retained");
        assert_eq!(p.refcount(b0), 2);
        // Diverging second block forks the tree under the shared head.
        let d1 = stamped(&mut p, 4.0);
        t.insert(&[1, 2, 7, 8], &[c0, d1], &mut p);
        assert_eq!(t.len(), 3, "one new node for the fork");
        assert_eq!(p.refcount(d1), 2);
        assert_eq!(p.refcount(c0), 1, "existing head node kept its own block");
    }

    #[test]
    fn eviction_is_lru_leaf_only_and_skips_live_blocks() {
        let mut p = pool();
        let mut t = RadixTree::new(2);
        let (b0, b1) = (stamped(&mut p, 0.0), stamped(&mut p, 1.0));
        let b2 = stamped(&mut p, 2.0);
        t.insert(&[1, 2, 3, 4], &[b0, b1], &mut p);
        t.insert(&[1, 2, 5, 6], &[b0, b2], &mut p);
        // Drop the sequences' own refs: blocks now tree-only.
        for b in [b0, b1, b2] {
            p.release(b);
        }
        assert_eq!(p.blocks_in_use(), 3);
        // Touch the [1,2,5,6] chain so [1,2,3,4]'s leaf is the LRU.
        let _ = t.lookup(&[1, 2, 5, 6]);
        assert!(t.evict_one(&mut p));
        assert_eq!(p.refcount(b1), 0, "LRU leaf b1 evicted first");
        assert_eq!(p.refcount(b0), 1, "interior node survives (has a child)");
        // Pin b2 as a live sequence would; eviction must skip it and,
        // with b0 interior, report nothing evictable.
        p.retain(b2);
        assert!(!t.evict_one(&mut p), "only leaf is live-referenced");
        assert_eq!(p.refcount(b2), 2, "live chain untouched");
        // Unpin: leaf b2 goes, then b0 becomes an evictable leaf.
        p.release(b2);
        assert!(t.evict_one(&mut p));
        assert!(t.evict_one(&mut p));
        assert!(t.is_empty());
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(t.evicted_blocks(), 3);
    }
}
