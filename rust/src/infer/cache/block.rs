//! Fixed-size, refcounted KV blocks — the storage layer of the paged KV
//! cache.
//!
//! A *block* holds `block_size` consecutive token positions of key/value
//! state for **every** layer of one sequence (layout
//! `[layer][token][d_model]`, keys and values in separate slabs). All
//! blocks live in two flat preallocated slabs, so block allocation is a
//! free-list pop and never touches the heap allocator on the decode hot
//! path.
//!
//! Blocks are **refcounted**: a block is referenced by the sequence
//! slot(s) whose block tables contain it and, once a prompt prefix is
//! registered, by the radix tree ([`super::RadixTree`]). Storage
//! is recycled (pushed back on the free list) only when the count reaches
//! zero, so eviction can never pull data out from under a live sequence.
//! Shared blocks are immutable by construction — writes only ever append
//! at a sequence's current length, which lies strictly past every shared
//! (full) block of its chain.
//!
//! The free list is kept sorted descending so pops hand out the lowest
//! free block id first — the same stable, deterministic reuse order the
//! KV *slot* pool uses.

/// Refcounted pool of fixed-size KV blocks backed by two flat slabs.
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    d_model: usize,
    n_layers: usize,
    /// Floats per (block, layer): `block_size * d_model`.
    layer_stride: usize,
    /// Floats per block: `n_layers * layer_stride`.
    block_stride: usize,
    keys: Vec<f32>,
    values: Vec<f32>,
    refcount: Vec<u32>,
    /// Free block ids, sorted descending (pop returns the lowest id).
    free: Vec<usize>,
}

impl BlockPool {
    /// Pool of `num_blocks` blocks, each spanning `n_layers` layers ×
    /// `block_size` token positions × `d_model` columns.
    pub fn new(num_blocks: usize, n_layers: usize, block_size: usize, d_model: usize) -> BlockPool {
        assert!(block_size > 0, "block size must be positive");
        let layer_stride = block_size * d_model;
        let block_stride = n_layers * layer_stride;
        BlockPool {
            block_size,
            d_model,
            n_layers,
            layer_stride,
            block_stride,
            keys: vec![0.0; num_blocks * block_stride],
            values: vec![0.0; num_blocks * block_stride],
            refcount: vec![0; num_blocks],
            free: (0..num_blocks).rev().collect(),
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total blocks (free + in use).
    pub fn num_blocks(&self) -> usize {
        self.refcount.len()
    }

    /// Blocks currently referenced by at least one owner.
    pub fn blocks_in_use(&self) -> usize {
        self.num_blocks() - self.free.len()
    }

    /// Current reference count of `block` (0 = on the free list).
    pub fn refcount(&self, block: usize) -> u32 {
        self.refcount[block]
    }

    /// Claim a free block (refcount 1, contents unspecified — callers
    /// overwrite rows before reading them). `None` when the pool is
    /// exhausted; the slot pool then asks the radix tree to evict.
    pub fn alloc(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b], 0);
        self.refcount[b] = 1;
        Some(b)
    }

    /// Add one reference to `block` (a second sequence or the radix tree
    /// now shares it).
    pub fn retain(&mut self, block: usize) {
        debug_assert!(self.refcount[block] > 0, "retain of a free block");
        self.refcount[block] += 1;
    }

    /// Drop one reference; when the count hits zero the block returns to
    /// the free list (sorted, lowest-first reuse). Returns `true` exactly
    /// when the block was freed.
    pub fn release(&mut self, block: usize) -> bool {
        debug_assert!(self.refcount[block] > 0, "release of a free block");
        self.refcount[block] -= 1;
        if self.refcount[block] == 0 {
            // Insert keeping descending order; the free list was allocated
            // at full capacity, so this never reallocates.
            let at = self.free.partition_point(|&f| f > block);
            self.free.insert(at, block);
            true
        } else {
            false
        }
    }

    #[inline]
    fn offset(&self, block: usize, layer: usize, t: usize) -> usize {
        debug_assert!(t < self.block_size && layer < self.n_layers);
        block * self.block_stride + layer * self.layer_stride + t * self.d_model
    }

    /// Key row at in-block position `t` of `layer`.
    #[inline]
    pub fn key_row(&self, block: usize, layer: usize, t: usize) -> &[f32] {
        let o = self.offset(block, layer, t);
        &self.keys[o..o + self.d_model]
    }

    /// Value row at in-block position `t` of `layer`.
    #[inline]
    pub fn value_row(&self, block: usize, layer: usize, t: usize) -> &[f32] {
        let o = self.offset(block, layer, t);
        &self.values[o..o + self.d_model]
    }

    /// The first `rows` contiguous key rows of `layer` in `block` — the
    /// attention kernel walks chains block-by-block through this.
    #[inline]
    pub fn key_rows(&self, block: usize, layer: usize, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block_size);
        let o = self.offset(block, layer, 0);
        &self.keys[o..o + rows * self.d_model]
    }

    /// The first `rows` contiguous value rows of `layer` in `block`.
    #[inline]
    pub fn value_rows(&self, block: usize, layer: usize, rows: usize) -> &[f32] {
        debug_assert!(rows <= self.block_size);
        let o = self.offset(block, layer, 0);
        &self.values[o..o + rows * self.d_model]
    }

    /// Write one K/V row at in-block position `t` of `layer`.
    pub fn write_row(&mut self, block: usize, layer: usize, t: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d_model);
        debug_assert_eq!(v.len(), self.d_model);
        let o = self.offset(block, layer, t);
        self.keys[o..o + self.d_model].copy_from_slice(k);
        self.values[o..o + self.d_model].copy_from_slice(v);
    }

    /// Copy the first `rows` token rows of **every** layer from `src`
    /// into `dst` — the copy-on-write step when a prompt diverges from a
    /// cached chain mid-block: the matching head of the shared block is
    /// duplicated into a private block the new sequence then appends to.
    pub fn copy_rows(&mut self, src: usize, dst: usize, rows: usize) {
        debug_assert!(rows <= self.block_size);
        debug_assert_ne!(src, dst, "COW copy onto itself");
        for layer in 0..self.n_layers {
            let s = self.offset(src, layer, 0);
            let d = self.offset(dst, layer, 0);
            let n = rows * self.d_model;
            // Disjoint blocks, same slab: copy_within on both slabs.
            self.keys.copy_within(s..s + n, d);
            self.values.copy_within(s..s + n, d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_lowest_first_and_exhaustion() {
        let mut p = BlockPool::new(3, 1, 4, 2);
        assert_eq!(p.num_blocks(), 3);
        assert_eq!((p.alloc(), p.alloc(), p.alloc()), (Some(0), Some(1), Some(2)));
        assert_eq!(p.alloc(), None, "pool exhausted");
        assert_eq!(p.blocks_in_use(), 3);
        // Free 1 then 0; reuse hands back 0 first.
        assert!(p.release(1));
        assert!(p.release(0));
        assert_eq!(p.alloc(), Some(0));
        assert_eq!(p.alloc(), Some(1));
    }

    #[test]
    fn refcounts_gate_the_free_list() {
        let mut p = BlockPool::new(2, 1, 2, 2);
        let b = p.alloc().unwrap();
        p.retain(b);
        p.retain(b);
        assert_eq!(p.refcount(b), 3);
        assert!(!p.release(b));
        assert!(!p.release(b));
        assert_eq!(p.blocks_in_use(), 1, "still referenced");
        assert!(p.release(b), "last release frees");
        assert_eq!(p.refcount(b), 0);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn rows_roundtrip_and_cow_copy() {
        let mut p = BlockPool::new(2, 2, 4, 3);
        let a = p.alloc().unwrap();
        for t in 0..4 {
            for l in 0..2 {
                let base = (t * 10 + l * 100) as f32;
                p.write_row(a, l, t, &[base, base + 1.0, base + 2.0], &[-base, -base - 1.0, -base - 2.0]);
            }
        }
        assert_eq!(p.key_row(a, 1, 2), &[120.0, 121.0, 122.0]);
        assert_eq!(p.value_row(a, 0, 3), &[-30.0, -31.0, -32.0]);
        assert_eq!(&p.key_rows(a, 0, 2)[3..6], p.key_row(a, 0, 1));
        // COW: copy the first 2 rows of every layer into a fresh block.
        let b = p.alloc().unwrap();
        p.copy_rows(a, b, 2);
        for l in 0..2 {
            for t in 0..2 {
                assert_eq!(p.key_row(b, l, t), p.key_row(a, l, t));
                assert_eq!(p.value_row(b, l, t), p.value_row(a, l, t));
            }
        }
        // Writing past the copied head of `b` leaves `a` untouched.
        p.write_row(b, 0, 2, &[9.0; 3], &[9.0; 3]);
        assert_eq!(p.key_row(a, 0, 2), &[20.0, 21.0, 22.0]);
    }
}
