//! The PJRT executor: compile-once, execute-many wrappers around the `xla`
//! crate, with named-tensor packing that follows the manifest's flat I/O
//! order.

use super::artifact::{ArtifactSpec, Dtype, Manifest};
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// A value bound to one artifact input.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Scalar(f32),
}

impl From<&Tensor> for Value {
    fn from(t: &Tensor) -> Value {
        Value::F32(t.data().to_vec())
    }
}

/// One compiled executable plus its spec.
pub struct Executor {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with inputs supplied by name. Every manifest input must be
    /// bound; shapes are validated against the spec.
    pub fn run(&self, bindings: &HashMap<&str, Value>) -> Result<Vec<Tensor>> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for io in &self.spec.inputs {
            let v = bindings
                .get(io.name.as_str())
                .with_context(|| format!("missing input binding {}", io.name))?;
            literals.push(to_literal(io, v)?);
        }
        self.run_literals(&literals)
    }

    /// Execute with pre-packed literals in manifest order.
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        ensure!(
            literals.len() == self.spec.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            literals.len()
        );
        let result = self.exe.execute::<xla::Literal>(literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: one tuple of all outputs.
        let parts = tuple.to_tuple().context("untupling outputs")?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {} declared {} outputs, got {}",
            self.spec.name,
            self.spec.outputs.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, io) in parts.iter().zip(&self.spec.outputs) {
            let shape = if io.shape.is_empty() {
                vec![1]
            } else {
                io.shape.clone()
            };
            let data: Vec<f32> = match io.dtype {
                Dtype::F32 => lit.to_vec::<f32>()?,
                Dtype::I32 => lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                Dtype::U32 => lit.to_vec::<u32>()?.into_iter().map(|x| x as f32).collect(),
            };
            ensure!(
                data.len() == shape.iter().product::<usize>(),
                "output {} size mismatch",
                io.name
            );
            out.push(Tensor::from_vec(&shape, data));
        }
        Ok(out)
    }

    /// Pack a named input into a literal (public for pipelined callers).
    pub fn literal_for(&self, name: &str, v: &Value) -> Result<xla::Literal> {
        let io = self
            .spec
            .inputs
            .iter()
            .find(|i| i.name == name)
            .with_context(|| format!("no input {name}"))?;
        to_literal(io, v)
    }
}

fn to_literal(io: &super::IoSpec, v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = io.shape.iter().map(|&d| d as i64).collect();
    let lit = match (io.dtype, v) {
        (Dtype::F32, Value::F32(data)) => {
            ensure!(data.len() == io.elems(), "input {} size mismatch", io.name);
            if io.shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
        (Dtype::F32, Value::Scalar(s)) => {
            ensure!(io.shape.is_empty(), "scalar bound to non-scalar {}", io.name);
            xla::Literal::scalar(*s)
        }
        (Dtype::I32, Value::I32(data)) => {
            ensure!(data.len() == io.elems(), "input {} size mismatch", io.name);
            if io.shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
        (Dtype::U32, Value::U32(data)) => {
            ensure!(data.len() == io.elems(), "input {} size mismatch", io.name);
            if io.shape.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        }
        (want, got) => anyhow::bail!(
            "dtype mismatch for input {}: manifest {:?}, bound {:?}",
            io.name,
            want,
            std::mem::discriminant(got)
        ),
    };
    Ok(lit)
}

/// The runtime: a PJRT CPU client plus a compile-once executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executor>>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "pjrt client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile an artifact (cached after the first call).
    pub fn executor(&self, name: &str) -> Result<std::sync::Arc<Executor>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.artifact_path(&spec);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!(
            "compiled {name} in {:.2}s ({} inputs, {} outputs)",
            t0.elapsed().as_secs_f64(),
            spec.inputs.len(),
            spec.outputs.len()
        );
        let executor = std::sync::Arc::new(Executor { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), executor.clone());
        Ok(executor)
    }
}
