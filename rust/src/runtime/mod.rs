//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! HLO **text** is the interchange format (jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids — see DESIGN.md). After `make artifacts`, the rust binary
//! is fully self-contained: python never runs on the request path.

mod artifact;
mod executor;

pub use artifact::{ArtifactSpec, Dtype, IoSpec, Manifest, ModelCfg};
pub use executor::{Executor, Runtime, Value};
