//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Describes every lowered HLO executable — its file, its
//! flat input/output tensor order (jax flattens dicts sorted by key), and
//! the model configuration it was lowered for.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One input or output tensor of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub config: String,
    pub kind: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Index of an input by name.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|i| i.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|i| i.name == name)
    }
}

/// Model configuration mirrored from python's `ModelConfig`.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq_len: usize,
    pub rank: usize,
    pub lora_alpha: f64,
    pub residual_rank: usize,
    pub batch_size: usize,
    pub ctx_keep: f64,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn lora_scaling(&self) -> f32 {
        (self.lora_alpha / self.rank as f64) as f32
    }

    /// Adapted linear names in canonical order (mirrors python).
    pub fn adapted_layers(&self) -> Vec<String> {
        let mut out = Vec::new();
        for layer in 0..self.n_layers {
            for lin in ["wq", "wk", "wv", "wo", "w_in", "w_out"] {
                out.push(format!("layer{layer}.{lin}"));
            }
        }
        out
    }

    /// (d_in, d_out) of an adapted linear by its suffix.
    pub fn linear_shape(&self, lin: &str) -> (usize, usize) {
        match lin {
            "wq" | "wk" | "wv" | "wo" => (self.d_model, self.d_model),
            "w_in" => (self.d_model, self.d_ff),
            "w_out" => (self.d_ff, self.d_model),
            other => panic!("unknown linear {other}"),
        }
    }

    fn from_json(name: &str, j: &Json) -> Result<ModelCfg> {
        let u = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("config {name} missing {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("config {name} missing {k}"))
        };
        Ok(ModelCfg {
            name: name.to_string(),
            vocab_size: u("vocab_size")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            max_seq_len: u("max_seq_len")?,
            rank: u("rank")?,
            lora_alpha: f("lora_alpha")?,
            residual_rank: u("residual_rank")?,
            batch_size: u("batch_size")?,
            ctx_keep: f("ctx_keep")?,
        })
    }
}

/// The parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ModelCfg>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("format").and_then(Json::as_usize) != Some(1) {
            bail!("unsupported manifest format");
        }
        let mut configs = Vec::new();
        for (name, cj) in j.get("configs").and_then(Json::as_obj).context("configs")? {
            configs.push(ModelCfg::from_json(name, cj)?);
        }
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("artifacts")?
        {
            artifacts.push(parse_artifact(a)?);
        }
        Ok(Manifest {
            dir,
            configs,
            artifacts,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ModelCfg> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .with_context(|| format!("config {name} not in manifest"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .context("io name")?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("io shape")?
        .iter()
        .map(|d| d.as_usize().context("shape dim"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        Some("u32") => Dtype::U32,
        other => bail!("unsupported dtype {other:?} for {name}"),
    };
    Ok(IoSpec { name, shape, dtype })
}

fn parse_artifact(j: &Json) -> Result<ArtifactSpec> {
    let s = |k: &str| -> Result<String> {
        Ok(j.get(k)
            .and_then(Json::as_str)
            .with_context(|| format!("artifact field {k}"))?
            .to_string())
    };
    Ok(ArtifactSpec {
        name: s("name")?,
        config: s("config")?,
        kind: s("kind")?,
        file: s("file")?,
        inputs: j
            .get("inputs")
            .and_then(Json::as_arr)
            .context("inputs")?
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?,
        outputs: j
            .get("outputs")
            .and_then(Json::as_arr)
            .context("outputs")?
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?,
    })
}


#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(man.config("tiny").is_ok());
        let cfg = man.config("tiny").unwrap();
        assert_eq!(cfg.d_model % cfg.n_heads, 0);
        for a in &man.artifacts {
            assert!(man.artifact_path(a).exists(), "{} missing", a.file);
            assert!(!a.inputs.is_empty());
            assert!(!a.outputs.is_empty());
        }
        // The SALR train step must expose the residual adapters + eta.
        let salr = man.artifact("train_salr_tiny").unwrap();
        assert!(salr.inputs.iter().any(|i| i.name.ends_with(".res_a")));
        assert!(salr.input_index("eta").is_some());
        assert!(salr.output_index("loss").is_some());
    }

    #[test]
    fn adapted_layer_shapes() {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab_size: 256,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 512,
            max_seq_len: 64,
            rank: 8,
            lora_alpha: 16.0,
            residual_rank: 16,
            batch_size: 16,
            ctx_keep: 0.5,
        };
        assert_eq!(cfg.adapted_layers().len(), 12);
        assert_eq!(cfg.linear_shape("w_in"), (128, 512));
        assert_eq!(cfg.linear_shape("w_out"), (512, 128));
        assert_eq!(cfg.lora_scaling(), 2.0);
    }
}
