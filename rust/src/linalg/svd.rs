//! Singular value decomposition: one-sided Jacobi for small/full problems
//! and a randomized range-finder truncated SVD for the rank-r residual
//! adapters of Theorem 3.

use super::qr::qr_thin;
use crate::tensor::{matmul, Tensor};
use crate::util::rng::Rng;

/// An SVD factorization `A ≈ U · diag(s) · Vᵀ`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// `U[m,r]` — left singular vectors (orthonormal columns).
    pub u: Tensor,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// `Vt[r,n]` — right singular vectors, transposed.
    pub vt: Tensor,
}

impl Svd {
    /// Reconstruct `U diag(s) Vt`.
    pub fn reconstruct(&self) -> Tensor {
        let r = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for j in 0..r {
                let v = us.at(i, j) * self.s[j];
                us.set(i, j, v);
            }
        }
        matmul(&us, &self.vt)
    }

    /// Split into adapter factors `(A, B)` with `A B ≈ input`:
    /// `A = U·diag(√s) ∈ R^{m×r}`, `B = diag(√s)·Vt ∈ R^{r×n}`.
    /// Balanced splitting keeps both factors at comparable scale, which
    /// matters when the residual adapter is subsequently *trained* (Thm 4).
    pub fn into_adapter(self) -> (Tensor, Tensor) {
        let r = self.s.len();
        let mut a = self.u;
        let mut b = self.vt;
        for j in 0..r {
            let sq = self.s[j].max(0.0).sqrt();
            for i in 0..a.rows() {
                let v = a.at(i, j) * sq;
                a.set(i, j, v);
            }
            for k in 0..b.cols() {
                let v = b.at(j, k) * sq;
                b.set(j, k, v);
            }
        }
        (a, b)
    }

    /// Energy captured by the top-i singular values: Σ_{j<=i} σ_j² / Σ σ_j².
    pub fn cumulative_energy(&self) -> Vec<f64> {
        let total: f64 = self.s.iter().map(|&x| (x as f64).powi(2)).sum();
        let mut acc = 0.0;
        self.s
            .iter()
            .map(|&x| {
                acc += (x as f64).powi(2);
                if total > 0.0 {
                    acc / total
                } else {
                    0.0
                }
            })
            .collect()
    }
}

/// Full SVD of `A[m,n]` by one-sided Jacobi on the thinner side.
///
/// Complexity O(min(m,n)² · max(m,n) · sweeps); intended for matrices up to
/// a few hundred on a side (enough for Gram matrices of rank-r factors and
/// the Fig-3 spectra, which operate on residual-correction factors).
pub fn jacobi_svd(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // SVD(Aᵀ) = V S Uᵀ.
        let svd_t = jacobi_svd(&a.transpose());
        return Svd {
            u: svd_t.vt.transpose(),
            s: svd_t.s,
            vt: svd_t.u.transpose(),
        };
    }
    // One-sided Jacobi: orthogonalize columns of W = A (m >= n).
    let mut w = a.clone();
    let mut v = Tensor::eye(n);
    let max_sweeps = 30;
    let tol = 1e-10f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = w.at(i, p) as f64;
                    let wq = w.at(i, q) as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    w.set(i, p, (c * wp as f64 - s * wq as f64) as f32);
                    w.set(i, q, (s * wp as f64 + c * wq as f64) as f32);
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    v.set(i, p, (c * vp as f64 - s * vq as f64) as f32);
                    v.set(i, q, (s * vp as f64 + c * vq as f64) as f32);
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    // Singular values = column norms of W; U = W normalized.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f32; n];
    for j in 0..n {
        let mut s = 0.0f64;
        for i in 0..m {
            s += (w.at(i, j) as f64).powi(2);
        }
        sigmas[j] = s.sqrt() as f32;
    }
    order.sort_by(|&x, &y| sigmas[y].partial_cmp(&sigmas[x]).unwrap());
    let mut u = Tensor::zeros(&[m, n]);
    let mut vt = Tensor::zeros(&[n, n]);
    let mut s_sorted = vec![0.0f32; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let s = sigmas[old_j];
        s_sorted[new_j] = s;
        let inv = if s > 1e-20 { 1.0 / s } else { 0.0 };
        for i in 0..m {
            u.set(i, new_j, w.at(i, old_j) * inv);
        }
        for i in 0..n {
            vt.set(new_j, i, v.at(i, old_j));
        }
    }
    Svd {
        u,
        s: s_sorted,
        vt,
    }
}

/// Randomized truncated SVD: best-effort rank-r approximation of `A[m,n]`.
///
/// Halko–Martinsson–Tropp range finder with `oversample` extra columns and
/// `power_iters` subspace iterations, then an exact Jacobi SVD on the small
/// projected matrix. This is what converts a pruning residual `E = W − Ŵ`
/// into the rank-r sparsity-preservation adapter.
pub fn truncated_svd(a: &Tensor, r: usize, seed: u64) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let r = r.min(m).min(n);
    if r == 0 {
        return Svd {
            u: Tensor::zeros(&[m, 0]),
            s: vec![],
            vt: Tensor::zeros(&[0, n]),
        };
    }
    let oversample = (r / 4).clamp(4, 16);
    let l = (r + oversample).min(m).min(n);
    let power_iters = 2;

    let mut rng = Rng::new(seed ^ 0x5AD1);
    // Range finder: Y = A Ω, Ω ∈ R^{n×l}.
    let omega = Tensor::randn(&[n, l], 1.0, &mut rng);
    let mut y = matmul(a, &omega);
    // Subspace (power) iterations with re-orthogonalization: Y ← A (Aᵀ Q).
    for _ in 0..power_iters {
        let (q, _) = qr_thin(&y);
        let z = matmul(&a.transpose(), &q);
        let (qz, _) = qr_thin(&z);
        y = matmul(a, &qz);
    }
    let (q, _) = qr_thin(&y); // Q[m,l]
    // Project: B = Qᵀ A ∈ R^{l×n}; SVD of small B.
    let b = matmul(&q.transpose(), a);
    let svd_b = jacobi_svd(&b);
    // U = Q · U_b, truncated to r.
    let ub = take_cols(&svd_b.u, r);
    let u = matmul(&q, &ub);
    let s = svd_b.s[..r].to_vec();
    let vt = take_rows(&svd_b.vt, r);
    Svd { u, s, vt }
}

fn take_cols(t: &Tensor, r: usize) -> Tensor {
    let (m, n) = (t.rows(), t.cols());
    let r = r.min(n);
    let mut out = Tensor::zeros(&[m, r]);
    for i in 0..m {
        for j in 0..r {
            out.set(i, j, t.at(i, j));
        }
    }
    out
}

fn take_rows(t: &Tensor, r: usize) -> Tensor {
    let (_m, n) = (t.rows(), t.cols());
    let r = r.min(t.rows());
    let mut out = Tensor::zeros(&[r, n]);
    for i in 0..r {
        out.row_mut(i).copy_from_slice(t.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthogonality_error;
    use crate::tensor::{max_abs_diff, sub};
    use crate::util::prop::Prop;

    fn make_low_rank(m: usize, n: usize, r: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[m, r], 1.0, rng);
        let b = Tensor::randn(&[r, n], 1.0, rng);
        matmul(&a, &b)
    }

    #[test]
    fn jacobi_svd_reconstructs() {
        let mut rng = Rng::new(31);
        for &(m, n) in &[(6, 6), (10, 4), (4, 10), (25, 13)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let svd = jacobi_svd(&a);
            let rec = svd.reconstruct();
            assert!(
                max_abs_diff(&rec, &a) < 1e-3,
                "({m},{n}) diff={}",
                max_abs_diff(&rec, &a)
            );
            // Descending singular values.
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
            assert!(orthogonality_error(&svd.u) < 1e-3);
            assert!(orthogonality_error(&svd.vt.transpose()) < 1e-3);
        }
    }

    #[test]
    fn jacobi_svd_known_diagonal() {
        let a = Tensor::from_vec(&[3, 3], vec![3.0, 0.0, 0.0, 0.0, 7.0, 0.0, 0.0, 0.0, 1.0]);
        let svd = jacobi_svd(&a);
        assert!((svd.s[0] - 7.0).abs() < 1e-4);
        assert!((svd.s[1] - 3.0).abs() < 1e-4);
        assert!((svd.s[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn truncated_svd_recovers_low_rank_exactly() {
        let mut rng = Rng::new(32);
        let a = make_low_rank(40, 30, 5, &mut rng);
        let svd = truncated_svd(&a, 5, 1);
        let rec = svd.reconstruct();
        let rel = sub(&rec, &a).fro_norm() / a.fro_norm();
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn truncated_svd_satisfies_eckart_young_bound_loosely() {
        // Error of rank-r approx must not exceed the tail energy by much.
        let mut rng = Rng::new(33);
        let a = Tensor::randn(&[30, 30], 1.0, &mut rng);
        let full = jacobi_svd(&a);
        for &r in &[1usize, 5, 15] {
            let tr = truncated_svd(&a, r, 2);
            let err = sub(&tr.reconstruct(), &a).sq_sum();
            let tail: f64 = full.s[r..].iter().map(|&x| (x as f64).powi(2)).sum();
            assert!(
                err <= tail * 1.15 + 1e-6,
                "r={r} err={err} tail={tail} (randomized SVD should be near-optimal)"
            );
        }
    }

    #[test]
    fn adapter_split_multiplies_back() {
        let mut rng = Rng::new(34);
        let a = make_low_rank(20, 25, 4, &mut rng);
        let svd = truncated_svd(&a, 4, 3);
        let (fa, fb) = svd.into_adapter();
        assert_eq!(fa.shape(), &[20, 4]);
        assert_eq!(fb.shape(), &[4, 25]);
        let rec = matmul(&fa, &fb);
        let rel = sub(&rec, &a).fro_norm() / a.fro_norm();
        assert!(rel < 1e-3, "rel={rel}");
    }

    #[test]
    fn cumulative_energy_monotone_to_one() {
        let mut rng = Rng::new(35);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let ce = svd.cumulative_energy();
        for w in ce.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((ce.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_rank_is_empty() {
        let a = Tensor::zeros(&[5, 5]);
        let svd = truncated_svd(&a, 0, 0);
        assert!(svd.s.is_empty());
    }

    #[test]
    fn prop_truncated_svd_error_bounded_by_tail() {
        Prop::new(10).check(
            "randomized svd near Eckart-Young",
            |rng| {
                let m = 8 + rng.below(20);
                let n = 8 + rng.below(20);
                let t = Tensor::randn(&[m, n], 1.0, rng);
                let r = 1 + rng.below(6.min(m.min(n)));
                (t, r)
            },
            |(a, r)| {
                let full = jacobi_svd(a);
                let tr = truncated_svd(a, *r, 9);
                let err = sub(&tr.reconstruct(), a).sq_sum();
                let tail: f64 = full.s[*r..].iter().map(|&x| (x as f64).powi(2)).sum();
                if err <= tail * 1.25 + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("err={err} tail={tail}"))
                }
            },
        );
    }
}
