//! Thin QR via modified Gram–Schmidt (with re-orthogonalization) and the
//! power-iteration estimator for `σ_max` used by the Theorem-4 step size.

use crate::tensor::{matmul, Tensor};
use crate::util::rng::Rng;

/// Thin QR of `A[m,n]` (m >= n typically): returns `Q[m,n]` with
/// orthonormal columns and upper-triangular `R[n,n]` with `A ≈ Q R`.
///
/// Modified Gram–Schmidt with one re-orthogonalization pass — numerically
/// adequate for the randomized-SVD range-finder (the only consumer).
pub fn qr_thin(a: &Tensor) -> (Tensor, Tensor) {
    let (m, n) = (a.rows(), a.cols());
    let mut q = a.clone();
    let mut r = Tensor::zeros(&[n, n]);
    for j in 0..n {
        // Two MGS passes for stability.
        for _pass in 0..2 {
            for i in 0..j {
                // proj = q_i . q_j
                let mut dot = 0.0f64;
                for t in 0..m {
                    dot += q.at(t, i) as f64 * q.at(t, j) as f64;
                }
                r.set(i, j, r.at(i, j) + dot as f32);
                for t in 0..m {
                    let v = q.at(t, j) - dot as f32 * q.at(t, i);
                    q.set(t, j, v);
                }
            }
        }
        let mut norm = 0.0f64;
        for t in 0..m {
            norm += (q.at(t, j) as f64).powi(2);
        }
        let norm = norm.sqrt() as f32;
        r.set(j, j, norm);
        if norm > 1e-12 {
            let inv = 1.0 / norm;
            for t in 0..m {
                q.set(t, j, q.at(t, j) * inv);
            }
        } else {
            // Rank-deficient column: replace with a fresh random direction
            // orthogonal to previous ones (keeps Q full column rank).
            let mut rng = Rng::new(0x9E37 + j as u64);
            for t in 0..m {
                q.set(t, j, rng.normal_f32());
            }
            for i in 0..j {
                let mut dot = 0.0f64;
                for t in 0..m {
                    dot += q.at(t, i) as f64 * q.at(t, j) as f64;
                }
                for t in 0..m {
                    let v = q.at(t, j) - dot as f32 * q.at(t, i);
                    q.set(t, j, v);
                }
            }
            let mut nn = 0.0f64;
            for t in 0..m {
                nn += (q.at(t, j) as f64).powi(2);
            }
            let nn = (nn.sqrt() as f32).max(1e-12);
            for t in 0..m {
                q.set(t, j, q.at(t, j) / nn);
            }
        }
    }
    (q, r)
}

/// Power iteration for the dominant singular value of `X[m,n]`.
///
/// This is exactly the estimator Theorem 4 prescribes for the residual
/// step size `η*_SVD = 1/σ_max(X)²`: a few iterations of
/// `v ← normalize(Xᵀ X v)` on a representative mini-batch.
pub struct PowerIter {
    pub iters: usize,
    pub seed: u64,
}

impl Default for PowerIter {
    fn default() -> Self {
        PowerIter { iters: 12, seed: 7 }
    }
}

impl PowerIter {
    /// Estimate `σ_max(x)`.
    pub fn sigma_max(&self, x: &Tensor) -> f64 {
        let (m, n) = (x.rows(), x.cols());
        if m == 0 || n == 0 {
            return 0.0;
        }
        let mut rng = Rng::new(self.seed);
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut sigma = 0.0f64;
        let mut u = vec![0.0f64; m];
        for _ in 0..self.iters {
            // u = X v
            for i in 0..m {
                let row = x.row(i);
                let mut s = 0.0f64;
                for j in 0..n {
                    s += row[j] as f64 * v[j];
                }
                u[i] = s;
            }
            sigma = norm(&u);
            if sigma < 1e-30 {
                return 0.0;
            }
            // v = Xᵀ u / |Xᵀ u|
            for vj in v.iter_mut() {
                *vj = 0.0;
            }
            for i in 0..m {
                let row = x.row(i);
                let ui = u[i];
                for j in 0..n {
                    v[j] += row[j] as f64 * ui;
                }
            }
            normalize(&mut v);
        }
        sigma
    }

    /// The Theorem-4 optimal residual step size `1/σ_max(X)²`.
    pub fn eta_svd(&self, x: &Tensor) -> f64 {
        let s = self.sigma_max(x);
        if s < 1e-30 {
            0.0
        } else {
            1.0 / (s * s)
        }
    }
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 1e-30 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

/// `QᵀQ` deviation from identity, for tests.
pub fn orthogonality_error(q: &Tensor) -> f32 {
    let qtq = matmul(&q.transpose(), q);
    let n = qtq.rows();
    let mut err = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            err = err.max((qtq.at(i, j) - want).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::max_abs_diff;

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        let mut rng = Rng::new(21);
        for &(m, n) in &[(8, 8), (20, 5), (33, 17)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            assert!(orthogonality_error(&q) < 1e-4, "Q not orthonormal");
            let qr = matmul(&q, &r);
            assert!(max_abs_diff(&qr, &a) < 1e-3, "QR != A");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert!(r.at(i, j).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // Two identical columns.
        let a = Tensor::from_vec(&[3, 2], vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        let (q, _r) = qr_thin(&a);
        assert!(orthogonality_error(&q) < 1e-4);
    }

    #[test]
    fn power_iteration_matches_known_sigma() {
        // diag(5, 3, 1) embedded in a rotation-free matrix.
        let a = Tensor::from_vec(
            &[3, 3],
            vec![5.0, 0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 1.0],
        );
        let s = PowerIter::default().sigma_max(&a);
        assert!((s - 5.0).abs() < 1e-3, "sigma={s}");
    }

    #[test]
    fn power_iteration_random_vs_frobenius_bounds() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[40, 30], 1.0, &mut rng);
        let s = PowerIter { iters: 40, seed: 3 }.sigma_max(&a);
        let fro = a.fro_norm();
        // sigma_max <= ||A||_F <= sqrt(rank) * sigma_max
        assert!(s <= fro * 1.0001);
        assert!(fro <= s * (30f64).sqrt() * 1.05);
    }

    #[test]
    fn eta_svd_is_inverse_square() {
        let a = Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 0.0, 1.0]);
        let eta = PowerIter::default().eta_svd(&a);
        assert!((eta - 0.25).abs() < 1e-4);
    }
}
