//! Numerical linear algebra for the SALR model-surgery path: QR
//! factorization, power iteration (the `σ_max(X)` estimate behind the
//! Theorem-4 residual learning rate), one-sided Jacobi SVD, and the
//! randomized truncated SVD that turns pruning residuals into rank-r
//! adapters (Theorem 3).
//!
//! Built from scratch: the offline vendor set has no LAPACK binding, and
//! `jnp.linalg.svd` lowers to a LAPACK custom-call the PJRT interchange
//! cannot carry — so the coordinator owns its own SVD.

mod qr;
mod svd;

pub use qr::{orthogonality_error, qr_thin, PowerIter};
pub use svd::{jacobi_svd, truncated_svd, Svd};
