//! TCP front-end: newline-delimited JSON over a socket.
//!
//! Request:  `{"prompt": "...", "max_tokens": 8}\n`
//! Response: `{"text": "...", "queue_ms": .., "compute_ms": .., "tokens": ..}\n`
//! `{"cmd": "metrics"}` returns aggregate serving metrics;
//! `{"cmd": "shutdown"}` stops the server.

use super::batcher::{BatchPolicy, Batcher, Request};
use crate::infer::Engine;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Serve `engine` on `addr` until a shutdown command arrives. Connections
/// are handled on their own threads; generation requests funnel through
/// the shared dynamic batcher. If `ready` is provided, the bound address
/// is sent once listening (use port 0 for tests/examples).
pub fn serve(
    engine: Engine,
    addr: &str,
    policy: BatchPolicy,
    ready: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let mut engine = engine;
    if policy.num_threads > 0 {
        engine.set_threads(policy.num_threads);
    }
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    log::info!("serving on {local} ({} GEMM worker threads)", engine.num_threads());
    if let Some(tx) = ready {
        let _ = tx.send(local);
    }
    let batcher = Batcher::new(policy);
    let b_worker = batcher.clone();
    let worker = std::thread::spawn(move || b_worker.worker_loop(&engine));
    let next_id = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        let batcher = batcher.clone();
        let next_id = next_id.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            match handle_conn(stream, &batcher, &next_id) {
                Ok(true) => {
                    // Shutdown requested: set the flag and poke the
                    // listener so accept() returns.
                    stop.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(local);
                }
                Ok(false) => {}
                Err(e) => log::warn!("connection error: {e:#}"),
            }
        });
    }
    batcher.shutdown();
    worker.join().unwrap();
    Ok(())
}

/// Handle one connection; returns Ok(true) if a shutdown was requested.
fn handle_conn(stream: TcpStream, batcher: &Batcher, next_id: &AtomicU64) -> Result<bool> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(false); // client closed
        }
        let msg = match Json::parse(line.trim()) {
            Ok(m) => m,
            Err(e) => {
                let err = Json::obj().set("error", format!("bad json: {e}"));
                writeln!(stream, "{}", err.to_string_compact())?;
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                writeln!(stream, "{}", Json::obj().set("ok", true).to_string_compact())?;
                return Ok(true);
            }
            Some("metrics") => {
                let (p50, p90, p99) = batcher.metrics.latency_percentiles();
                let reply = Json::obj()
                    .set("requests", batcher.metrics.requests.load(Ordering::Relaxed))
                    .set("tokens_out", batcher.metrics.tokens_out.load(Ordering::Relaxed))
                    .set("tokens_per_sec", batcher.metrics.tokens_per_sec())
                    .set("mean_batch_size", batcher.metrics.mean_batch_size())
                    .set("latency_p50_ms", p50)
                    .set("latency_p90_ms", p90)
                    .set("latency_p99_ms", p99);
                writeln!(stream, "{}", reply.to_string_compact())?;
            }
            _ => {
                let prompt = msg
                    .get("prompt")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(8)
                    .max(1);
                let resp = batcher.submit(Request {
                    id: next_id.fetch_add(1, Ordering::Relaxed),
                    prompt,
                    max_tokens,
                });
                let reply = Json::obj()
                    .set("text", resp.text)
                    .set("queue_ms", resp.queue_ms)
                    .set("compute_ms", resp.compute_ms)
                    .set("tokens", resp.tokens);
                writeln!(stream, "{}", reply.to_string_compact())?;
            }
        }
    }
}

/// A minimal blocking client for the wire protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            stream,
        })
    }

    pub fn call(&mut self, msg: &Json) -> Result<Json> {
        writeln!(self.stream, "{}", msg.to_string_compact())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn generate(&mut self, prompt: &str, max_tokens: usize) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("prompt", prompt)
                .set("max_tokens", max_tokens),
        )
    }

    pub fn metrics(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "metrics"))
    }

    pub fn shutdown(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "shutdown"))
    }
}
