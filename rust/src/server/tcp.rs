//! TCP front-end: newline-delimited JSON over a socket.
//!
//! Request:  `{"prompt": "...", "max_tokens": 8, "id": 7}` + newline
//! Response: `{"id": 7, "text": "...", "queue_ms": .., "compute_ms": ..,
//! "tokens": ..}` + newline. A rejected request (e.g. a prompt longer
//! than the KV slot capacity) gets `{"id": 7, "error": "..."}` instead.
//!
//! **Streaming**: add `"stream": true` to a generation request and the
//! server emits one frame per generated token as the engine produces it —
//! `{"id": 7, "delta": "...", "seq": 0}` — followed by the usual final
//! frame tagged `"done": true` (full text + stats, the authoritative
//! result). Delta frames of concurrent streamed requests interleave on
//! the wire but are routed by `id` like every other reply.
//!
//! A connection may pipeline many generation requests without reading
//! replies in between; with continuous batching, responses come back **in
//! completion order**, not submission order, so clients must match
//! replies to requests by `id` (server-assigned when omitted; like any
//! JSON number in this codec, ids round-trip through f64, so client ids
//! must be non-negative integers ≤ 2^53 — anything else is replaced
//! with a server-assigned id, echoed in the reply). A pipelining client
//! should supply its own id on **every** in-flight request of a
//! connection: server-assigned ids come from a small shared counter and
//! are not guaranteed distinct from ids the client picks itself. All
//! writes to a connection go through a single writer thread, so
//! concurrent completions never interleave bytes on the wire.
//!
//! Control commands: `{"cmd": "metrics"}` returns aggregate serving
//! metrics; `{"cmd": "shutdown"}` stops the server.

use super::batcher::{spawn_engine_workers, BatchPolicy, Batcher, Request, Response};
use crate::infer::Engine;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

/// Serve `engine` on `addr` until a shutdown command arrives.
///
/// `policy.engine_workers` continuous-batching worker loops are spawned
/// over forks of `engine` (weights shared, each fork on a private pool
/// holding an even share of `policy.num_threads` GEMM threads), each
/// interleaving `policy.prefill_chunk`-token prefill bites with its decode
/// steps. Connections are handled on their own threads; generation
/// requests funnel through the shared admission queue (idle workers steal
/// waiting requests when their KV slots free up first) and complete out
/// of order. If `ready` is provided, the bound address is sent once
/// listening (use port 0 for tests/examples).
pub fn serve(
    engine: Engine,
    addr: &str,
    policy: BatchPolicy,
    ready: Option<Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    log::info!(
        "serving on {local} ({} engine workers, {} GEMM threads total, prefill chunk {})",
        policy.engine_workers.max(1),
        if policy.num_threads > 0 {
            policy.num_threads
        } else {
            crate::util::pool::available_threads()
        },
        policy.prefill_chunk,
    );
    if let Some(tx) = ready {
        let _ = tx.send(local);
    }
    let batcher = Batcher::new(policy);
    let workers = spawn_engine_workers(&batcher, engine);
    let next_id = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        let batcher = batcher.clone();
        let next_id = next_id.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            match handle_conn(stream, &batcher, &next_id) {
                Ok(true) => {
                    // Shutdown requested: set the flag and poke the
                    // listener so accept() returns.
                    stop.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(local);
                }
                Ok(false) => {}
                Err(e) => log::warn!("connection error: {e:#}"),
            }
        });
    }
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    // Requests that raced past shutdown() into the queue after the
    // workers' final drain would otherwise pin their reply channels (and
    // with them, connection writer threads) forever.
    let dropped = batcher.drain_abandoned();
    if dropped > 0 {
        log::warn!("dropped {dropped} request(s) queued after shutdown");
    }
    Ok(())
}

/// The final reply frame for a completed (or rejected) request.
/// `done_marker` (streamed requests) tags the frame `"done": true` —
/// error frames included, so a streaming client waiting on the
/// documented terminator never hangs on a rejected request.
fn final_frame(resp: Response, done_marker: bool) -> Json {
    let mut j = Json::obj().set("id", resp.id);
    j = match resp.error {
        Some(err) => j.set("error", err),
        None => j
            .set("text", resp.text)
            .set("queue_ms", resp.queue_ms)
            .set("compute_ms", resp.compute_ms)
            .set("tokens", resp.tokens),
    };
    if done_marker {
        j.set("done", true)
    } else {
        j
    }
}

/// Handle one connection; returns Ok(true) if a shutdown was requested.
///
/// The reader (this thread) parses requests and submits them without
/// blocking; a dedicated writer thread owns the stream's write half and
/// serializes every reply line — delta frames included — in completion
/// order.
fn handle_conn(stream: TcpStream, batcher: &Batcher, next_id: &AtomicU64) -> Result<bool> {
    let mut reader = BufReader::new(stream.try_clone()?);
    // All replies (generation completions + stream deltas + command
    // responses + errors) go through one channel so concurrent writes
    // never interleave.
    let (reply_tx, reply_rx) = std::sync::mpsc::channel::<String>();
    let mut writer = stream;
    let writer_thread = std::thread::spawn(move || {
        for line in reply_rx {
            if writeln!(writer, "{line}").is_err() {
                break; // client went away; drain + drop remaining replies
            }
        }
    });
    let mut line = String::new();
    let shutdown = loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break false; // client closed
        }
        let msg = match Json::parse(line.trim()) {
            Ok(m) => m,
            Err(e) => {
                let err = Json::obj().set("error", format!("bad json: {e}"));
                let _ = reply_tx.send(err.to_string_compact());
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                let _ = reply_tx.send(Json::obj().set("ok", true).to_string_compact());
                break true;
            }
            Some("metrics") => {
                let _ = reply_tx.send(render_metrics(batcher).to_string_compact());
            }
            _ => {
                let prompt = msg
                    .get("prompt")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(8)
                    .max(1);
                let streaming = msg
                    .get("stream")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                // Ids must be non-negative integers ≤ 2^53 (JSON numbers
                // are f64 here); anything else gets a server-assigned id,
                // which the reply echoes.
                let id = msg
                    .get("id")
                    .and_then(Json::as_f64)
                    .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9_007_199_254_740_992.0)
                    .map(|n| n as u64)
                    .unwrap_or_else(|| next_id.fetch_add(1, Ordering::Relaxed));
                let req = Request {
                    id,
                    prompt,
                    max_tokens,
                };
                let tx = reply_tx.clone();
                let reply = Box::new(move |resp: Response| {
                    let _ = tx.send(final_frame(resp, streaming).to_string_compact());
                });
                let accepted = if streaming {
                    let tx = reply_tx.clone();
                    let mut seq = 0u64;
                    batcher.submit_stream_with(
                        req,
                        Box::new(move |delta: &str| {
                            let frame = Json::obj()
                                .set("id", id)
                                .set("delta", delta)
                                .set("seq", seq);
                            seq += 1;
                            let _ = tx.send(frame.to_string_compact());
                        }),
                        reply,
                    )
                } else {
                    batcher.submit_with(req, reply)
                };
                if !accepted {
                    let mut err = Json::obj()
                        .set("id", id)
                        .set("error", "server shutting down");
                    if streaming {
                        // Streamed requests always terminate with a
                        // done-tagged frame, error or not.
                        err = err.set("done", true);
                    }
                    let _ = reply_tx.send(err.to_string_compact());
                }
            }
        }
    };
    // Drop our sender; the writer exits once every in-flight completion
    // has been delivered (their callbacks hold the remaining clones).
    drop(reply_tx);
    let _ = writer_thread.join();
    Ok(shutdown)
}

/// Aggregate metrics as a JSON object (the `{"cmd":"metrics"}` reply).
fn render_metrics(batcher: &Batcher) -> Json {
    let (p50, p90, p99) = batcher.metrics.latency_percentiles();
    let workers = Json::Arr(
        batcher
            .worker_metrics()
            .iter()
            .map(|w| {
                Json::obj()
                    .set("steps", w.steps)
                    .set("tokens", w.tokens)
                    .set("retired", w.retired)
            })
            .collect(),
    );
    Json::obj()
        .set("requests", batcher.metrics.requests.load(Ordering::Relaxed))
        .set("tokens_out", batcher.metrics.tokens_out.load(Ordering::Relaxed))
        .set("tokens_per_sec", batcher.metrics.tokens_per_sec())
        .set("decode_steps", batcher.metrics.decode_steps.load(Ordering::Relaxed))
        .set("mean_batch_occupancy", batcher.metrics.mean_batch_occupancy())
        .set(
            "max_occupancy",
            batcher.metrics.max_occupancy.load(Ordering::Relaxed),
        )
        .set(
            "admitted_midstream",
            batcher.metrics.admitted_midstream.load(Ordering::Relaxed),
        )
        .set(
            "prefill_chunks",
            batcher.metrics.prefill_chunks.load(Ordering::Relaxed),
        )
        .set("stolen", batcher.metrics.stolen.load(Ordering::Relaxed))
        .set("rejected", batcher.metrics.rejected.load(Ordering::Relaxed))
        .set("latency_p50_ms", p50)
        .set("latency_p90_ms", p90)
        .set("latency_p99_ms", p99)
        .set("workers", workers)
}

/// A minimal blocking client for the wire protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    /// Connect to a serving address (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            stream,
        })
    }

    /// Write one request line without waiting for the reply — the
    /// pipelining half; pair with [`Client::recv`] and match replies to
    /// requests by `id`.
    pub fn send(&mut self, msg: &Json) -> Result<()> {
        writeln!(self.stream, "{}", msg.to_string_compact())?;
        Ok(())
    }

    /// Read the next reply line (completion order, not submission order).
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Send one message and wait for one reply (only safe when no other
    /// request is in flight on this connection).
    pub fn call(&mut self, msg: &Json) -> Result<Json> {
        self.send(msg)?;
        self.recv()
    }

    /// Generate `max_tokens` for `prompt`, blocking for the reply.
    pub fn generate(&mut self, prompt: &str, max_tokens: usize) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("prompt", prompt)
                .set("max_tokens", max_tokens),
        )
    }

    /// Generate with **token streaming**: `on_delta` fires with each text
    /// delta frame as the server emits it; returns the final frame (full
    /// text + stats, or `error`). Only safe when no other request is in
    /// flight on this connection — a pipelining client should use
    /// [`Client::send`]/[`Client::recv`] and route frames by `id` itself.
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        mut on_delta: impl FnMut(&str),
    ) -> Result<Json> {
        self.send(
            &Json::obj()
                .set("prompt", prompt)
                .set("max_tokens", max_tokens)
                .set("stream", true),
        )?;
        loop {
            let frame = self.recv()?;
            match frame.get("delta").and_then(Json::as_str) {
                Some(d) => on_delta(d),
                None => return Ok(frame),
            }
        }
    }

    /// Fetch aggregate serving metrics.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "metrics"))
    }

    /// Ask the server to stop (replies `{"ok": true}` first).
    pub fn shutdown(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "shutdown"))
    }
}
