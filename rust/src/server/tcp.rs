//! TCP front-end: newline-delimited JSON over a socket.
//!
//! Request:  `{"prompt": "...", "max_tokens": 8, "id": 7}` + newline
//! Response: `{"id": 7, "text": "...", "queue_ms": .., "compute_ms": ..,
//! "tokens": ..}` + newline. A rejected request (e.g. a prompt longer
//! than the KV slot capacity) gets `{"id": 7, "error": "..."}` instead.
//!
//! **Streaming**: add `"stream": true` to a generation request and the
//! server emits one frame per generated token as the engine produces it —
//! `{"id": 7, "delta": "...", "seq": 0}` — followed by the usual final
//! frame tagged `"done": true` (full text + stats, the authoritative
//! result). Delta frames of concurrent streamed requests interleave on
//! the wire but are routed by `id` like every other reply.
//!
//! A connection may pipeline many generation requests without reading
//! replies in between; with continuous batching, responses come back **in
//! completion order**, not submission order, so clients must match
//! replies to requests by `id` (server-assigned when omitted; like any
//! JSON number in this codec, ids round-trip through f64, so client ids
//! must be non-negative integers ≤ 2^53 — anything else is replaced
//! with a server-assigned id, echoed in the reply). A pipelining client
//! should supply its own id on **every** in-flight request of a
//! connection: server-assigned ids come from a small shared counter and
//! are not guaranteed distinct from ids the client picks itself. All
//! writes to a connection go through a single writer thread, so
//! concurrent completions never interleave bytes on the wire.
//!
//! **Backpressure**: each connection's reply queue is *bounded*
//! ([`BatchPolicy::stream_frame_cap`] frames). Replies and stream deltas
//! are enqueued with a non-blocking send — an engine worker is never
//! stalled by a slow client — and a reader that falls a full queue
//! behind has its connection closed (the remaining frames are dropped),
//! instead of ballooning server memory with an unbounded backlog. This
//! bound is part of the pipelining contract: final replies share the
//! queue, so a client must read concurrently or keep its unread
//! completions (plus in-flight stream frames) under the cap — the
//! alternative, blocking the sender, would let one dead client stall
//! every sequence on an engine worker.
//!
//! **Deadlines + cancellation**: a generation request may carry
//! `"timeout_ms": N` — the batcher retires it with `{"id": …, "error":
//! "timeout"}` if it has not completed `N` ms after submission
//! (`--default-deadline-ms` applies one to every request that doesn't
//! set its own). `{"cmd": "cancel", "id": N}` cancels in-flight request
//! `N` *of this connection* (tokens are connection-scoped; the ack is
//! `{"cmd": "cancel", "ok": bool}`, and the cancelled request still gets
//! its final `error: "cancelled"` frame). A connection that drops — EOF,
//! write error, slow-reader severing — cancels **all** of its in-flight
//! requests automatically, so dead clients stop consuming decode steps
//! and KV blocks. Pipelining clients that reuse an id for two
//! simultaneously in-flight requests forfeit cancellation of the older
//! one (ids should be unique per connection anyway, see above).
//!
//! **Idle timeout**: with `--idle-timeout-ms N`, a connection with no
//! in-flight requests that sends nothing for `N` ms is closed, so
//! half-open sockets don't pin reader/writer threads for the life of
//! the process. Connections with requests still in flight are never
//! idle-closed.
//!
//! Control commands: `{"cmd": "metrics"}` returns aggregate serving
//! metrics; `{"cmd": "cancel", "id": N}` cancels an in-flight request;
//! `{"cmd": "shutdown"}` (alias `{"cmd": "drain"}`) **drains** the
//! server — new submissions are rejected with `error: "shutting down"`,
//! every request already admitted finishes and delivers its reply
//! (streamed frames included), then the process exits. Nothing in
//! flight is aborted; this is the backend half of router-driven drain.
//!
//! **Tracing** (see `util::trace`): when enabled (`SALR_TRACE=1` or
//! `--trace-out`), a generation request may carry `"trace": T` — the
//! router injects this on every forward — and the id is echoed on the
//! final frame. A request arriving without one is assigned a
//! server-minted id (high-bit-tagged so it cannot collide with
//! router-minted ids). `{"cmd": "trace", "id": T}` returns the request's
//! span tree: `{"cmd":"trace","id":T,"count":N,"tree":[...]}`, spans
//! nested by interval containment, each with
//! `kind/lane/proc/t_start_us/dur_us/op/arg/children`. The metrics reply
//! additionally carries log2 latency histograms (`"hist"`), per-stage
//! span totals (`"stages"`) and the ring-overwrite counter
//! (`"trace_dropped"`).

use super::batcher::{
    spawn_engine_workers, BatchPolicy, Batcher, CancelToken, Request, Response,
};
use crate::infer::Engine;
use crate::util::json::Json;
use crate::util::trace;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Bounded, non-blocking sender for one connection's reply/stream
/// frames. The first overflow *poisons* the connection: the socket is
/// shut down (the client sees EOF), the frame is dropped, and every
/// later frame is dropped too — the queue can never hold more than its
/// bound, and the sending engine worker never blocks. Shared with the
/// router front-end (`server::router`), whose client connections carry
/// the same backpressure contract.
#[derive(Clone)]
pub(crate) struct FrameTx {
    tx: SyncSender<String>,
    poisoned: Arc<AtomicBool>,
    /// The connection to sever on overflow (`None` only in unit tests).
    conn: Option<Arc<TcpStream>>,
}

impl FrameTx {
    pub(crate) fn new(tx: SyncSender<String>, conn: Option<Arc<TcpStream>>) -> FrameTx {
        FrameTx {
            tx,
            poisoned: Arc::new(AtomicBool::new(false)),
            conn,
        }
    }

    /// Enqueue one reply line; `false` means the frame was dropped
    /// (overflow, already-poisoned connection, or writer gone).
    pub(crate) fn send(&self, line: String) -> bool {
        if self.poisoned.load(Ordering::Relaxed) {
            return false;
        }
        match self.tx.try_send(line) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                self.poisoned.store(true, Ordering::Relaxed);
                log::warn!("closing connection: reply queue overflow (slow reader)");
                if let Some(c) = &self.conn {
                    let _ = c.shutdown(std::net::Shutdown::Both);
                }
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }
}

/// Serve `engine` on `addr` until a shutdown command arrives.
///
/// `policy.engine_workers` continuous-batching worker loops are spawned
/// over forks of `engine` (weights shared, each fork on a private pool
/// holding an even share of `policy.num_threads` GEMM threads), each
/// interleaving `policy.prefill_chunk`-token prefill bites with its decode
/// steps. Connections are handled on their own threads; generation
/// requests funnel through the shared admission queue (idle workers steal
/// waiting requests when their KV slots free up first) and complete out
/// of order. If `ready` is provided, the bound address is sent once
/// listening (use port 0 for tests/examples).
pub fn serve(
    engine: Engine,
    addr: &str,
    policy: BatchPolicy,
    ready: Option<Sender<std::net::SocketAddr>>,
) -> Result<()> {
    serve_on(engine, addr, Batcher::new(policy), ready)
}

/// [`serve`] over a caller-built [`Batcher`] (engine workers are spawned
/// here either way). This is the injection point for pairing the TCP
/// front-end with [`Batcher::with_fault`] in deterministic fault tests;
/// `serve` itself builds the batcher from the policy (arming `SALR_FAULT`
/// if set).
pub fn serve_on(
    engine: Engine,
    addr: &str,
    batcher: Arc<Batcher>,
    ready: Option<Sender<std::net::SocketAddr>>,
) -> Result<()> {
    trace::init_from_env();
    let policy = *batcher.policy();
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    log::info!(
        "serving on {local} ({} engine workers, {} GEMM threads total, prefill chunk {})",
        policy.engine_workers.max(1),
        if policy.num_threads > 0 {
            policy.num_threads
        } else {
            crate::util::pool::available_threads()
        },
        policy.prefill_chunk,
    );
    if let Some(tx) = ready {
        let _ = tx.send(local);
    }
    let workers = spawn_engine_workers(&batcher, engine);
    let next_id = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        let batcher = batcher.clone();
        let next_id = next_id.clone();
        let stop = stop.clone();
        let frame_cap = policy.stream_frame_cap.max(1);
        std::thread::spawn(move || {
            match handle_conn(stream, &batcher, &next_id, frame_cap) {
                Ok(true) => {
                    // Shutdown requested: set the flag and poke the
                    // listener so accept() returns.
                    stop.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(local);
                }
                Ok(false) => {}
                Err(e) => log::warn!("connection error: {e:#}"),
            }
        });
    }
    batcher.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    // Requests that raced past shutdown() into the queue after the
    // workers' final drain would otherwise pin their reply channels (and
    // with them, connection writer threads) forever.
    let dropped = batcher.drain_abandoned();
    if dropped > 0 {
        log::warn!("dropped {dropped} request(s) queued after shutdown");
    }
    // `--trace-out`: every ring has gone quiet (workers joined), so the
    // Chrome trace dump is a consistent snapshot of the whole run.
    trace::dump_trace_out("serve");
    Ok(())
}

/// The final reply frame for a completed (or rejected) request.
/// `done_marker` (streamed requests) tags the frame `"done": true` —
/// error frames included, so a streaming client waiting on the
/// documented terminator never hangs on a rejected request. A non-zero
/// `trace_id` (tracing enabled at submission) is echoed so the client
/// can fetch the span tree with `{"cmd":"trace","id":T}`.
fn final_frame(resp: Response, done_marker: bool, trace_id: u64) -> Json {
    let mut j = Json::obj().set("id", resp.id);
    j = match resp.error {
        Some(err) => j.set("error", err),
        None => j
            .set("text", resp.text)
            .set("queue_ms", resp.queue_ms)
            .set("compute_ms", resp.compute_ms)
            .set("tokens", resp.tokens),
    };
    if trace_id != 0 {
        j = j.set("trace", trace_id);
    }
    if done_marker {
        j.set("done", true)
    } else {
        j
    }
}

/// Counter behind server-minted trace ids. The high tag bit keeps them
/// disjoint from router-minted ids (small integers from the router's
/// request counter) while staying well under the codec's 2^53 integer
/// ceiling, so a serve-local request and a router-forwarded one can
/// never alias the same span tree.
static TRACE_SEQ: AtomicU64 = AtomicU64::new(1);

/// Trace id tag bit for ids minted by the serve tier itself.
const TRACE_LOCAL_TAG: u64 = 1 << 40;

/// The trace id for a generation request: the wire-supplied `"trace"`
/// field when present and valid (the router always injects one), else a
/// freshly minted local id. Zero — tracing disabled — means "record
/// nothing for this request".
fn assign_trace(msg: &Json) -> u64 {
    if !trace::enabled() {
        return 0;
    }
    msg.get("trace")
        .and_then(Json::as_f64)
        .filter(|n| n.fract() == 0.0 && *n > 0.0 && *n <= 9_007_199_254_740_992.0)
        .map(|n| n as u64)
        .unwrap_or_else(|| TRACE_LOCAL_TAG | TRACE_SEQ.fetch_add(1, Ordering::Relaxed))
}

/// Handle one connection; returns Ok(true) if a shutdown was requested.
///
/// The reader (this thread) parses requests and submits them without
/// blocking; a dedicated writer thread owns the stream's write half and
/// serializes every reply line — delta frames included — in completion
/// order. Every in-flight generation request holds a [`CancelToken`] in
/// this connection's table: the `cancel` command latches one, and *any*
/// exit from the read loop (EOF, error, idle close) latches them all, so
/// a dead connection's requests stop consuming compute at their next
/// scheduler boundary.
fn handle_conn(
    stream: TcpStream,
    batcher: &Batcher,
    next_id: &AtomicU64,
    frame_cap: usize,
) -> Result<bool> {
    let idle_ms = batcher.policy().idle_timeout_ms;
    if idle_ms > 0 {
        stream.set_read_timeout(Some(Duration::from_millis(idle_ms)))?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    // All replies (generation completions + stream deltas + command
    // responses + errors) go through one **bounded** channel so
    // concurrent writes never interleave and a slow reader cannot pile
    // up an unbounded backlog (overflow severs the connection instead).
    let (tx, reply_rx) = std::sync::mpsc::sync_channel::<String>(frame_cap);
    let reply_tx = FrameTx::new(tx, Some(Arc::new(stream.try_clone()?)));
    let mut writer = stream;
    let writer_thread = std::thread::spawn(move || {
        for line in reply_rx {
            if writeln!(writer, "{line}").is_err() {
                break; // client went away; drain + drop remaining replies
            }
        }
    });
    // Cancellation handles for this connection's in-flight generation
    // requests, keyed by request id. Entries are inserted before
    // submission and removed by the completion callback.
    let inflight: Arc<Mutex<HashMap<u64, CancelToken>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut line = String::new();
    let outcome: Result<bool> = loop {
        // NB: `line` is cleared after each *processed* line, not here — an
        // idle-timeout tick can split one line across several read_line
        // calls, which append to the same buffer.
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // Idle tick. Close only a connection with nothing in
                // flight: a client quietly awaiting a long generation is
                // not idle, and its replies keep flowing regardless.
                if inflight.lock().unwrap().is_empty() {
                    log::info!("closing idle connection (silent for {idle_ms} ms)");
                    break Ok(false);
                }
                continue;
            }
            Err(e) => break Err(e.into()),
        };
        if n == 0 {
            break Ok(false); // client closed
        }
        let msg = match Json::parse(line.trim()) {
            Ok(m) => m,
            Err(e) => {
                let err = Json::obj().set("error", format!("bad json: {e}"));
                let _ = reply_tx.send(err.to_string_compact());
                line.clear();
                continue;
            }
        };
        line.clear();
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") | Some("drain") => {
                // Stop admissions *before* the ack goes out, so a client
                // that sees the ack can rely on later submissions being
                // rejected with "shutting down". Everything already
                // admitted (this connection's own requests included)
                // finishes and delivers its reply: shutdown drains, it
                // does not abort — see the `Ok(true)` exit path below.
                batcher.shutdown();
                let _ = reply_tx.send(Json::obj().set("ok", true).to_string_compact());
                break Ok(true);
            }
            Some("metrics") => {
                let _ = reply_tx.send(render_metrics(batcher).to_string_compact());
            }
            Some("trace") => {
                // The span tree of one traced request (`id` = trace id,
                // as echoed on its final frame). Replies carry
                // `"cmd":"trace"` so a pipelining client can tell them
                // apart from generation completions.
                let reply = if !trace::enabled() {
                    Json::obj()
                        .set("cmd", "trace")
                        .set("error", "tracing disabled (set SALR_TRACE=1 or --trace-out)")
                } else {
                    match parse_id(&msg) {
                        Some(tid) => trace::span_tree_json(tid, "serve").set("cmd", "trace"),
                        None => Json::obj().set("cmd", "trace").set("error", "missing id"),
                    }
                };
                let _ = reply_tx.send(reply.to_string_compact());
            }
            Some("cancel") => {
                // Latch the token of one of *this connection's* in-flight
                // requests. `ok: false` = no such request (unknown id,
                // already completed, or another connection's).
                let target = parse_id(&msg);
                let token = target.and_then(|id| inflight.lock().unwrap().get(&id).cloned());
                let hit = token.is_some_and(|t| {
                    t.cancel();
                    true
                });
                let ack = Json::obj().set("cmd", "cancel").set("ok", hit);
                let _ = reply_tx.send(ack.to_string_compact());
            }
            _ => {
                let prompt = msg
                    .get("prompt")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                let max_tokens = msg
                    .get("max_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(8)
                    .max(1);
                let streaming = msg
                    .get("stream")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                let timeout_ms = msg
                    .get("timeout_ms")
                    .and_then(Json::as_f64)
                    .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9_007_199_254_740_992.0)
                    .map(|n| n as u64);
                let id = parse_id(&msg).unwrap_or_else(|| next_id.fetch_add(1, Ordering::Relaxed));
                let trace_id = assign_trace(&msg);
                let token = CancelToken::new();
                inflight.lock().unwrap().insert(id, token.clone());
                let req = Request {
                    id,
                    prompt,
                    max_tokens,
                    timeout_ms,
                    cancel: Some(token),
                    trace: trace_id,
                };
                let tx = reply_tx.clone();
                let inflight_done = inflight.clone();
                let reply = Box::new(move |resp: Response| {
                    inflight_done.lock().unwrap().remove(&resp.id);
                    let _ = tx.send(final_frame(resp, streaming, trace_id).to_string_compact());
                });
                // Rejections (shutdown, queue shedding) fire `reply`
                // themselves — error text, done marker and the inflight
                // removal included — so both branches need no follow-up.
                if streaming {
                    let tx = reply_tx.clone();
                    let mut seq = 0u64;
                    batcher.submit_stream_with(
                        req,
                        Box::new(move |delta: &str| {
                            let frame = Json::obj()
                                .set("id", id)
                                .set("delta", delta)
                                .set("seq", seq);
                            seq += 1;
                            let _ = tx.send(frame.to_string_compact());
                        }),
                        reply,
                    );
                } else {
                    batcher.submit_with(req, reply);
                }
            }
        }
    };
    // How the read loop ended decides what happens to this connection's
    // in-flight requests. A *drain* exit (`Ok(true)`: shutdown/drain
    // command) leaves them running — the whole point of draining is that
    // admitted work finishes and delivers its replies, and this thread
    // blocks on the writer below until the last final frame has gone out.
    // Any other exit — clean EOF, idle close, socket error — cancels them
    // all: nobody is left to read the replies. (This used to cancel
    // unconditionally, which made `shutdown` abort the issuing
    // connection's own generations mid-stream.)
    if !matches!(outcome, Ok(true)) {
        for (_, token) in inflight.lock().unwrap().drain() {
            token.cancel();
        }
    }
    // Drop our sender; the writer exits once every in-flight completion
    // has been delivered (their callbacks hold the remaining clones).
    drop(reply_tx);
    let _ = writer_thread.join();
    outcome
}

/// The request id, when present and valid. Ids must be non-negative
/// integers ≤ 2^53 (JSON numbers are f64 in this codec). Shared with
/// the router tier, which speaks the same frames.
pub(crate) fn parse_id(msg: &Json) -> Option<u64> {
    msg.get("id")
        .and_then(Json::as_f64)
        .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9_007_199_254_740_992.0)
        .map(|n| n as u64)
}

/// Aggregate metrics as a JSON object (the `{"cmd":"metrics"}` reply).
fn render_metrics(batcher: &Batcher) -> Json {
    let (p50, p90, p99) = batcher.metrics.latency_percentiles();
    let worker_metrics = batcher.worker_metrics();
    let cache_blocks_total: u64 = worker_metrics.iter().map(|w| w.cache_blocks_in_use).sum();
    let slots_total: u64 = worker_metrics.iter().map(|w| w.slots_in_use).sum();
    let workers = Json::Arr(
        worker_metrics
            .iter()
            .map(|w| {
                Json::obj()
                    .set("steps", w.steps)
                    .set("tokens", w.tokens)
                    .set("retired", w.retired)
                    .set("prefix_hit_tokens", w.prefix_hit_tokens)
                    .set("cache_blocks_in_use", w.cache_blocks_in_use)
                    .set("slots_in_use", w.slots_in_use)
            })
            .collect(),
    );
    Json::obj()
        .set("requests", batcher.metrics.requests.load(Ordering::Relaxed))
        .set("tokens_out", batcher.metrics.tokens_out.load(Ordering::Relaxed))
        .set("tokens_per_sec", batcher.metrics.tokens_per_sec())
        .set("decode_steps", batcher.metrics.decode_steps.load(Ordering::Relaxed))
        .set("mean_batch_occupancy", batcher.metrics.mean_batch_occupancy())
        .set(
            "max_occupancy",
            batcher.metrics.max_occupancy.load(Ordering::Relaxed),
        )
        .set(
            "admitted_midstream",
            batcher.metrics.admitted_midstream.load(Ordering::Relaxed),
        )
        .set(
            "prefill_chunks",
            batcher.metrics.prefill_chunks.load(Ordering::Relaxed),
        )
        .set(
            "prefill_tokens",
            batcher.metrics.prefill_tokens.load(Ordering::Relaxed),
        )
        .set(
            "prefix_hit_tokens",
            batcher.metrics.prefix_hit_tokens.load(Ordering::Relaxed),
        )
        .set("cache_blocks_in_use", cache_blocks_total)
        // The router tier's load signal: admission backlog plus decode
        // slots currently held, polled on every heartbeat.
        .set("queue_depth", batcher.queue_depth() as u64)
        .set("slots_in_use", slots_total)
        .set("stolen", batcher.metrics.stolen.load(Ordering::Relaxed))
        .set("rejected", batcher.metrics.rejected.load(Ordering::Relaxed))
        .set("shed", batcher.metrics.shed.load(Ordering::Relaxed))
        .set("cancelled", batcher.metrics.cancelled.load(Ordering::Relaxed))
        .set("timeout", batcher.metrics.timed_out.load(Ordering::Relaxed))
        .set(
            "worker_restarts",
            batcher.metrics.worker_restarts.load(Ordering::Relaxed),
        )
        .set(
            "drafted_tokens",
            batcher.metrics.drafted_tokens.load(Ordering::Relaxed),
        )
        .set(
            "accepted_tokens",
            batcher.metrics.accepted_tokens.load(Ordering::Relaxed),
        )
        .set(
            "spec_rollbacks",
            batcher.metrics.spec_rollbacks.load(Ordering::Relaxed),
        )
        .set("latency_p50_ms", p50)
        .set("latency_p90_ms", p90)
        .set("latency_p99_ms", p99)
        // Log2-bucket latency histograms (µs), mergeable across
        // backends by summing per-bucket counts.
        .set(
            "hist",
            Json::obj()
                .set("queue_wait", batcher.metrics.queue_wait.to_json())
                .set("ttft", batcher.metrics.ttft.to_json())
                .set("per_token", batcher.metrics.per_token.to_json())
                .set("e2e", batcher.metrics.e2e.to_json()),
        )
        .set("stages", trace::kind_totals_json())
        .set("trace_dropped", trace::dropped())
        .set("workers", workers)
}

/// A minimal blocking client for the wire protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    /// Connect to a serving address (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            stream,
        })
    }

    /// Write one request line without waiting for the reply — the
    /// pipelining half; pair with [`Client::recv`] and match replies to
    /// requests by `id`.
    pub fn send(&mut self, msg: &Json) -> Result<()> {
        writeln!(self.stream, "{}", msg.to_string_compact())?;
        Ok(())
    }

    /// Read the next reply line (completion order, not submission order).
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Send one message and wait for one reply (only safe when no other
    /// request is in flight on this connection).
    pub fn call(&mut self, msg: &Json) -> Result<Json> {
        self.send(msg)?;
        self.recv()
    }

    /// Generate `max_tokens` for `prompt`, blocking for the reply.
    pub fn generate(&mut self, prompt: &str, max_tokens: usize) -> Result<Json> {
        self.call(
            &Json::obj()
                .set("prompt", prompt)
                .set("max_tokens", max_tokens),
        )
    }

    /// Generate with **token streaming**: `on_delta` fires with each text
    /// delta frame as the server emits it; returns the final frame (full
    /// text + stats, or `error`). Only safe when no other request is in
    /// flight on this connection — a pipelining client should use
    /// [`Client::send`]/[`Client::recv`] and route frames by `id` itself.
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        mut on_delta: impl FnMut(&str),
    ) -> Result<Json> {
        self.send(
            &Json::obj()
                .set("prompt", prompt)
                .set("max_tokens", max_tokens)
                .set("stream", true),
        )?;
        loop {
            let frame = self.recv()?;
            match frame.get("delta").and_then(Json::as_str) {
                Some(d) => on_delta(d),
                None => return Ok(frame),
            }
        }
    }

    /// Ask the server to cancel in-flight request `id` submitted on this
    /// connection. Fire-and-forget: the ack frame
    /// (`{"cmd":"cancel","ok":bool}`) and the cancelled request's final
    /// `error: "cancelled"` frame both arrive via [`Client::recv`] — a
    /// pipelining concern, so no blocking wrapper is offered.
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        self.send(&Json::obj().set("cmd", "cancel").set("id", id))
    }

    /// Fetch aggregate serving metrics.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "metrics"))
    }

    /// Fetch the span tree of a traced request (`trace_id` as echoed in
    /// the request's final frame). Requires tracing enabled server-side.
    pub fn trace(&mut self, trace_id: u64) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "trace").set("id", trace_id))
    }

    /// Ask the server to stop (replies `{"ok": true}` first). Everything
    /// already admitted still finishes — `shutdown` drains, it does not
    /// abort; only *new* submissions are rejected (`"shutting down"`).
    pub fn shutdown(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "shutdown"))
    }

    /// Ask the server to drain and exit: stop admitting, finish every
    /// in-flight sequence, deliver their replies, then stop. Today an
    /// alias for [`Client::shutdown`] (the commands are one drain path);
    /// the separate verb is what a router sends when decommissioning one
    /// backend of many.
    pub fn drain(&mut self) -> Result<Json> {
        self.call(&Json::obj().set("cmd", "drain"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_frame_channel_poisons_and_closes_on_overflow() {
        // A reader that never drains: with no writer thread attached, the
        // queue fills at exactly its bound, the overflowing send returns
        // immediately (no engine-side blocking), the connection is shut
        // down, and every later frame is dropped.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let (tx, rx) = std::sync::mpsc::sync_channel::<String>(2);
        let ftx = FrameTx::new(tx, Some(Arc::new(server)));
        assert!(ftx.send("frame 1".into()));
        assert!(ftx.send("frame 2".into()));
        assert!(!ftx.send("frame 3".into()), "overflow must drop, not block");
        assert!(!ftx.send("frame 4".into()), "poisoned connection drops frames");
        assert_eq!(
            rx.try_iter().count(),
            2,
            "queue never holds more than its bound"
        );
        // The peer observes the severed connection as EOF.
        let mut line = String::new();
        let n = BufReader::new(client).read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "slow-reader connection must be closed");
    }

    #[test]
    fn frame_tx_without_connection_still_bounds_and_poisons() {
        let (tx, _rx) = std::sync::mpsc::sync_channel::<String>(1);
        let ftx = FrameTx::new(tx, None);
        assert!(ftx.send("a".into()));
        assert!(!ftx.send("b".into()));
        assert!(!ftx.send("c".into()), "stays poisoned");
    }
}
