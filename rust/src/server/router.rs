//! Front-end router tier: one TCP process speaking the existing wire
//! protocol, fronting `N` independent engine backends.
//!
//! ```text
//!                        ┌──────────► backend 0 (salr serve)
//!  clients ──► router ───┤   one multiplexed conn per backend,
//!                        │   pump thread routes frames by id
//!                        └──────────► backend N-1 (salr serve)
//! ```
//!
//! The router lifts the single-process failure model of the serving
//! tier (deadlines, cancellation, bounded queues, supervision) across
//! the process boundary:
//!
//! * **health**: every backend is probed with `{"cmd":"metrics"}` on a
//!   heartbeat interval; its reply doubles as the load signal
//!   (`queue_depth` + `slots_in_use`). A backend that misses
//!   `miss_threshold` consecutive beats is marked unhealthy and its
//!   connection torn down; reconnects run under exponential backoff
//!   with deterministic jitter (the circuit breaker), and the backend
//!   reintegrates only after a *probe* succeeds — never on bare TCP
//!   connect.
//! * **cache-aware routing**: requests consistent-hash on their
//!   prompt's leading KV-block-aligned token blocks, so repeat and
//!   shared-prefix traffic lands on the backend whose radix-tree
//!   prefix cache already holds those blocks. When the owner's load
//!   exceeds `spill_depth`, the request spills to the least-loaded
//!   healthy backend instead (counted `spilled` vs `hash_routed`).
//! * **failover**: a request whose backend dies before its first
//!   streamed token is re-sent, once, to another healthy backend.
//!   Greedy decode is deterministic, so the unstarted retry returns
//!   byte-identical output — the client cannot observe the failover.
//!   A request that already streamed (or already retried once) gets a
//!   clean final `{"error": "backend lost"}` instead, and no router
//!   state survives it.
//! * **drain**: `{"cmd":"drain","backend":N}` marks backend `N`
//!   draining (no new routes) and forwards `{"cmd":"drain"}` to it;
//!   the backend finishes its in-flight sequences, their finals flow
//!   back normally, and the ring's hash range redistributes to the
//!   next backends in ring order without a request being dropped. A
//!   submission that races into the draining backend is rejected there
//!   with `"shutting down"` and transparently re-dispatched.
//!
//! Every one of these paths is deterministically testable: the
//! `SALR_FAULT` network kinds (`conn_drop`, `reply_delay`,
//! `backend_down`) key on per-backend counters of the router's two op
//! points — `fwd` (a request forward) and `reply` (a backend data
//! frame) — see [`crate::util::fault`].
//!
//! * **tracing**: with tracing enabled (see [`crate::util::trace`]) the
//!   router mints a trace id per request (its globally unique router
//!   id), injects it as `"trace"` into the re-keyed forwarded line, and
//!   records `admit`/`failover`/`heartbeat` spans under it; the id
//!   survives failover, so both dispatch attempts stitch into one span
//!   tree, answerable at the router via `{"cmd":"trace","id":T}`
//!   (router spans merged with the owning backend's).

use super::backend::{Backend, BackendState, Inflight};
use super::tcp::{parse_id, FrameTx};
use crate::util::fault::{FaultAction, FaultOp, FaultPlan};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::trace::{self, TraceKind};
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tuning knobs for the router tier (all have serviceable defaults;
/// the `router` subcommand exposes each as a flag).
#[derive(Clone, Copy, Debug)]
pub struct RouterPolicy {
    /// Heartbeat interval: how often every backend is probed with
    /// `{"cmd":"metrics"}` and reconnects are attempted.
    pub heartbeat_ms: u64,
    /// Consecutive unanswered probes before a backend is marked
    /// unhealthy and its connection torn down.
    pub miss_threshold: u64,
    /// Load (backend `queue_depth` + `slots_in_use` + router-side
    /// inflight) above which the ring owner is bypassed and the
    /// request spills to the least-loaded healthy backend.
    pub spill_depth: u64,
    /// How many leading KV blocks of the prompt feed the consistent
    /// hash (prompts shorter than one block hash whole).
    pub hash_blocks: usize,
    /// Token positions per KV block — must match the backends'
    /// `--kv-block-size` for the hash to align with their
    /// prefix-sharing granularity.
    pub kv_block_size: usize,
    /// Virtual nodes per backend on the consistent-hash ring.
    pub vnodes: usize,
    /// First reconnect backoff; doubles per consecutive failure.
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_max_ms: u64,
    /// Per-client-connection reply-queue bound (same slow-reader
    /// severing contract as the serving tier).
    pub stream_frame_cap: usize,
    /// TCP connect timeout for backend dials.
    pub connect_timeout_ms: u64,
}

impl Default for RouterPolicy {
    fn default() -> RouterPolicy {
        RouterPolicy {
            heartbeat_ms: 200,
            miss_threshold: 3,
            spill_depth: 8,
            hash_blocks: 2,
            kv_block_size: 16,
            vnodes: 32,
            backoff_base_ms: 50,
            backoff_max_ms: 2000,
            stream_frame_cap: 1024,
            connect_timeout_ms: 1000,
        }
    }
}

/// Aggregate routing counters (per-backend breakdowns live on each
/// [`Backend`]). `routed` counts *forwards*, not requests: a failover
/// forwards the same request again and counts again.
#[derive(Default)]
struct RouterAggregates {
    routed: AtomicU64,
    hash_routed: AtomicU64,
    spilled: AtomicU64,
    failovers: AtomicU64,
}

/// The router: backends, the consistent-hash ring, and the shared
/// counters. Construct with [`Router::new`] (arms `SALR_FAULT`) or
/// [`Router::with_fault`] (tests), then serve with [`serve_router_on`].
pub struct Router {
    backends: Vec<Arc<Backend>>,
    /// `(hash, backend index)`, sorted by hash. Keyed on backend
    /// *index* — not address — so the prompt→backend mapping is a pure
    /// function of the backend list's order, stable across runs and
    /// processes.
    ring: Vec<(u64, usize)>,
    policy: RouterPolicy,
    metrics: RouterAggregates,
    next_rid: AtomicU64,
    next_client_id: AtomicU64,
    fault: Option<FaultPlan>,
    shutdown: AtomicBool,
    heartbeat: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Which backend last owned each traced request (FIFO-bounded), so
    /// `{"cmd":"trace","id":T}` can be answered after the request
    /// completed and left the inflight tables.
    trace_seen: Mutex<TraceSeen>,
}

/// FIFO-bounded trace id → owning backend map (see [`Router::trace_seen`]).
#[derive(Default)]
struct TraceSeen {
    map: HashMap<u64, usize>,
    order: VecDeque<u64>,
}

/// Retention bound for completed traces the router can still stitch.
const TRACE_SEEN_CAP: usize = 1024;

/// FNV-1a, the codebase's standing choice for cheap stable hashing.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Router {
    /// Build a router over `addrs` (one `host:port` per backend),
    /// arming the `SALR_FAULT` environment spec if set. The heartbeat
    /// thread starts immediately; backends begin `unhealthy` and
    /// become routable when their first probe is answered.
    pub fn new(addrs: &[String], policy: RouterPolicy) -> Arc<Router> {
        Router::with_fault(addrs, policy, FaultPlan::from_env())
    }

    /// [`Router::new`] with an explicit (or no) fault plan — the
    /// injection point for deterministic network-fault tests.
    pub fn with_fault(
        addrs: &[String],
        policy: RouterPolicy,
        fault: Option<FaultPlan>,
    ) -> Arc<Router> {
        let backends: Vec<Arc<Backend>> = addrs
            .iter()
            .enumerate()
            .map(|(i, a)| Arc::new(Backend::new(a.clone(), i)))
            .collect();
        let mut ring = Vec::with_capacity(backends.len() * policy.vnodes.max(1));
        for b in 0..backends.len() {
            for v in 0..policy.vnodes.max(1) {
                ring.push((fnv1a(format!("backend-{b}-vnode-{v}").as_bytes()), b));
            }
        }
        ring.sort_unstable();
        let router = Arc::new(Router {
            backends,
            ring,
            policy,
            metrics: RouterAggregates::default(),
            next_rid: AtomicU64::new(1),
            next_client_id: AtomicU64::new(1),
            fault,
            shutdown: AtomicBool::new(false),
            heartbeat: Mutex::new(None),
            trace_seen: Mutex::new(TraceSeen::default()),
        });
        let hb = {
            // A `Weak` breaks the Router → JoinHandle → Arc<Router>
            // cycle: a router dropped without `stop()` ends its
            // heartbeat at the next tick instead of leaking both.
            let weak = Arc::downgrade(&router);
            std::thread::spawn(move || heartbeat_loop(&weak))
        };
        *router.heartbeat.lock().unwrap() = Some(hb);
        router
    }

    /// The consistent-hash key: the prompt's leading
    /// `hash_blocks × kv_block_size` tokens (whole prompt when shorter
    /// than one block), truncated to *full* blocks so two prompts
    /// sharing their cached head hash identically even when their
    /// tails diverge mid-block.
    fn hash_key(&self, prompt: &str) -> u64 {
        let toks = crate::data::tokenizer::tokenize(prompt);
        let block = self.policy.kv_block_size.max(1);
        let full_blocks = (toks.len() / block).min(self.policy.hash_blocks.max(1));
        let take = if full_blocks == 0 {
            toks.len()
        } else {
            full_blocks * block
        };
        let mut bytes = Vec::with_capacity(take * 4);
        for t in &toks[..take] {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        fnv1a(&bytes)
    }

    /// Backend indices in ring order starting at `key`'s position,
    /// deduplicated — the owner first, then the backends its range
    /// redistributes to when it is unavailable.
    fn ring_order(&self, key: u64) -> Vec<usize> {
        let start = self.ring.partition_point(|&(h, _)| h < key);
        let mut seen = vec![false; self.backends.len()];
        let mut order = Vec::with_capacity(self.backends.len());
        for i in 0..self.ring.len() {
            let (_, b) = self.ring[(start + i) % self.ring.len()];
            if !seen[b] {
                seen[b] = true;
                order.push(b);
                if order.len() == self.backends.len() {
                    break;
                }
            }
        }
        order
    }

    /// The ring owner of `prompt`, health ignored — the pure
    /// prompt→backend mapping. Public so tests (and capacity planning)
    /// can craft prompts that land on a chosen backend.
    pub fn owner_of_prompt(&self, prompt: &str) -> usize {
        self.ring_order(self.hash_key(prompt))[0]
    }

    /// Pick the backend for one request and bump the routing counters.
    /// `None` = no healthy backend exists right now.
    fn route(&self, prompt: &str) -> Option<Arc<Backend>> {
        let order = self.ring_order(self.hash_key(prompt));
        let owner = order
            .iter()
            .map(|&i| &self.backends[i])
            .find(|b| b.state() == BackendState::Healthy)?;
        let chosen = if owner.load() > self.policy.spill_depth {
            // Owner overloaded: spill to the least-loaded healthy
            // backend (ties break on index, deterministically). The
            // owner itself stays a candidate — if it is *still* the
            // least loaded, the request stays put.
            self.backends
                .iter()
                .filter(|b| b.state() == BackendState::Healthy)
                .min_by_key(|b| (b.load(), b.index))
                .unwrap_or(owner)
        } else {
            owner
        };
        self.metrics.routed.fetch_add(1, Ordering::Relaxed);
        chosen.counters.routed.fetch_add(1, Ordering::Relaxed);
        if chosen.index == owner.index {
            self.metrics.hash_routed.fetch_add(1, Ordering::Relaxed);
            chosen.counters.hash_routed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.metrics.spilled.fetch_add(1, Ordering::Relaxed);
            chosen.counters.spilled.fetch_add(1, Ordering::Relaxed);
        }
        Some(chosen.clone())
    }

    fn fault_check(&self, op: FaultOp, backend: usize) -> Option<FaultAction> {
        self.fault.as_ref()?.check(op, backend)
    }

    /// Apply a network fault action against `b`. Returns `false` when
    /// the connection was killed — the caller's frame, if any, goes
    /// down with it (a dead link loses in-transit frames).
    fn apply_network_action(&self, b: &Backend, action: FaultAction) -> bool {
        match action {
            FaultAction::Delay(d) => {
                std::thread::sleep(d);
                true
            }
            FaultAction::DropConn => {
                log::warn!("injected fault: dropping connection to backend {}", b.index);
                b.shut_socket();
                false
            }
            FaultAction::BackendDown => {
                log::warn!("injected fault: backend {} down permanently", b.index);
                b.set_state(BackendState::Down);
                b.shut_socket();
                false
            }
            // Parse-time class validation keeps engine actions off
            // network ops; tolerate rather than poison the pump.
            FaultAction::Panic(msg) => {
                log::error!("ignoring engine fault action on a network op: {msg}");
                true
            }
        }
    }

    /// Forward one generation request. `msg` is the client's parsed
    /// request line; the router substitutes its own globally unique id
    /// before the line goes on a multiplexed backend connection.
    fn submit(
        self: &Arc<Router>,
        msg: Json,
        tx: &FrameTx,
        conn_map: &Arc<Mutex<HashMap<u64, (usize, u64)>>>,
    ) {
        let client_id = parse_id(&msg)
            .unwrap_or_else(|| self.next_client_id.fetch_add(1, Ordering::Relaxed));
        let stream = msg.get("stream").and_then(Json::as_bool).unwrap_or(false);
        let prompt = msg.get("prompt").and_then(Json::as_str).unwrap_or("").to_string();
        let rid = self.next_rid.fetch_add(1, Ordering::Relaxed);
        // The router is the first tier that sees the request, so it
        // mints the trace id (= its globally unique router id) and
        // injects it into the re-keyed line; the backend honors it and
        // echoes it on the final frame, which flows back unchanged.
        let trace_id = if trace::enabled() { rid } else { 0 };
        let mut fwd = msg.set("id", rid);
        if trace_id != 0 {
            fwd = fwd.set("trace", trace_id);
        }
        let line = fwd.to_string_compact();
        let Some(b) = self.route(&prompt) else {
            let mut j = Json::obj().set("id", client_id).set("error", "no healthy backend");
            if stream {
                j = j.set("done", true);
            }
            let _ = tx.send(j.to_string_compact());
            return;
        };
        if trace_id != 0 {
            let t = trace::now_us();
            trace::record_span_at(TraceKind::Admit, trace_id, t, t, b.index as u64);
            self.note_trace(trace_id, b.index);
        }
        let entry = Inflight {
            line: line.clone(),
            client_id,
            stream,
            started: false,
            retried: false,
            trace: trace_id,
            tx: tx.clone(),
            conn_map: conn_map.clone(),
        };
        conn_map.lock().unwrap().insert(client_id, (b.index, rid));
        b.inflight.lock().unwrap().insert(rid, entry);
        if let Some(a) = self.fault_check(FaultOp::RouterFwd, b.index) {
            self.apply_network_action(&b, a);
        }
        if !b.send_line(&line) {
            // Whoever removes the entry owns its disposal — the pump
            // (on the dead connection) and this path race for it.
            let removed = b.inflight.lock().unwrap().remove(&rid);
            if let Some(e) = removed {
                self.redispatch(rid, e, b.index);
            }
        }
    }

    /// Pre-first-token failover: re-send `e` (retried once, ever) on
    /// the least-loaded healthy backend other than `from`.
    fn redispatch(self: &Arc<Router>, rid: u64, mut e: Inflight, from: usize) {
        debug_assert!(!e.started, "started requests are never redispatched");
        if e.retried {
            self.fail(e, "backend lost");
            return;
        }
        e.retried = true;
        self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
        self.backends[from].counters.failovers.fetch_add(1, Ordering::Relaxed);
        let target = self
            .backends
            .iter()
            .filter(|b| b.index != from && b.state() == BackendState::Healthy)
            .min_by_key(|b| (b.load(), b.index))
            .cloned();
        let Some(t) = target else {
            self.fail(e, "backend lost");
            return;
        };
        log::info!(
            "failing request {rid} over from backend {from} to backend {}",
            t.index
        );
        if e.trace != 0 {
            // Same trace id on both attempts: the span tree shows the
            // first admit, this failover marker, and the retry's spans
            // as one request.
            let now = trace::now_us();
            trace::record_span_at(TraceKind::Failover, e.trace, now, now, t.index as u64);
            self.note_trace(e.trace, t.index);
        }
        self.metrics.routed.fetch_add(1, Ordering::Relaxed);
        t.counters.routed.fetch_add(1, Ordering::Relaxed);
        let line = e.line.clone();
        e.conn_map.lock().unwrap().insert(e.client_id, (t.index, rid));
        t.inflight.lock().unwrap().insert(rid, e);
        if let Some(a) = self.fault_check(FaultOp::RouterFwd, t.index) {
            self.apply_network_action(&t, a);
        }
        if !t.send_line(&line) {
            let removed = t.inflight.lock().unwrap().remove(&rid);
            if let Some(e) = removed {
                // Already retried: a second loss is terminal.
                self.fail(e, "backend lost");
            }
        }
    }

    /// Deliver a request's final frame to its client, id substituted
    /// back, and unregister it from its connection's map.
    fn deliver_final(&self, e: Inflight, frame: Json) {
        e.conn_map.lock().unwrap().remove(&e.client_id);
        let _ = e.tx.send(frame.set("id", e.client_id).to_string_compact());
    }

    /// Synthesize an error final for a request the router could not
    /// complete. Streamed requests get the `"done"` terminator so a
    /// client waiting on the documented marker never hangs.
    fn fail(&self, e: Inflight, error: &str) {
        e.conn_map.lock().unwrap().remove(&e.client_id);
        let mut j = Json::obj().set("id", e.client_id).set("error", error);
        if e.stream {
            j = j.set("done", true);
        }
        let _ = e.tx.send(j.to_string_compact());
    }

    /// The single disposal path for a lost backend connection: sever
    /// (epoch-guarded — exactly one caller wins), transition state,
    /// then fail over or error out everything that was in flight.
    fn on_conn_lost(self: &Arc<Router>, b: &Arc<Backend>, epoch: u64) {
        if !b.sever(Some(epoch)) {
            return; // a newer connection owns this backend now
        }
        match b.state() {
            // A draining backend that closed its connection has
            // finished: everything it admitted was delivered.
            BackendState::Draining => b.set_state(BackendState::Down),
            BackendState::Down => {}
            _ => {
                log::warn!("lost connection to backend {} ({})", b.index, b.addr);
                b.set_state(BackendState::Unhealthy);
            }
        }
        let entries: Vec<(u64, Inflight)> = {
            let mut inflight = b.inflight.lock().unwrap();
            inflight.drain().collect()
        };
        for (rid, e) in entries {
            if e.started || e.retried {
                // Mid-stream (or second) loss: a retry would replay
                // delivered tokens, so the contract is a clean error.
                self.fail(e, "backend lost");
            } else {
                self.redispatch(rid, e, b.index);
            }
        }
    }

    /// Handle a frame that carries no request id: a heartbeat
    /// (metrics-shaped) reply, or a command ack — acks are dropped.
    fn on_control_frame(&self, b: &Backend, frame: &Json) {
        let (Some(depth), Some(slots)) = (
            frame.get("queue_depth").and_then(Json::as_f64),
            frame.get("slots_in_use").and_then(Json::as_f64),
        ) else {
            return; // an ok/cancel ack
        };
        b.queue_depth.store(depth as u64, Ordering::Relaxed);
        b.slots_in_use.store(slots as u64, Ordering::Relaxed);
        if let Some(blocks) = frame.get("cache_blocks_in_use").and_then(Json::as_f64) {
            b.cache_blocks_in_use.store(blocks as u64, Ordering::Relaxed);
        }
        b.missed.store(0, Ordering::Relaxed);
        b.probe_outstanding.store(false, Ordering::SeqCst);
        if b.state() == BackendState::Unhealthy {
            // Reintegration: a *probe* succeeded over the live
            // connection — not merely a TCP connect.
            log::info!("backend {} ({}) reintegrated", b.index, b.addr);
            b.consec_fails.store(0, Ordering::Relaxed);
            b.set_state_unless_down(BackendState::Healthy);
        }
    }

    /// Remember which backend owns traced request `trace_id` (bounded,
    /// FIFO eviction) so its span tree can be stitched after completion.
    fn note_trace(&self, trace_id: u64, backend: usize) {
        if trace_id == 0 {
            return;
        }
        let mut seen = self.trace_seen.lock().unwrap();
        if seen.map.insert(trace_id, backend).is_none() {
            seen.order.push_back(trace_id);
            if seen.order.len() > TRACE_SEEN_CAP {
                if let Some(old) = seen.order.pop_front() {
                    seen.map.remove(&old);
                }
            }
        }
    }

    /// Answer `{"cmd":"trace","id":T}` at the router: the router's own
    /// spans for `T` merged with the owning backend's (fetched over a
    /// fresh short-lived connection, so the reply never rides the
    /// multiplexed pump where it would be misrouted by id). Roots are
    /// deduplicated by value — when router and backend share a process
    /// (in-process tests), both snapshots see the same rings.
    pub fn trace_json(&self, tid: Option<u64>) -> Json {
        if !trace::enabled() {
            return Json::obj()
                .set("cmd", "trace")
                .set("error", "tracing disabled (set SALR_TRACE=1 or --trace-out)");
        }
        let Some(tid) = tid else {
            return Json::obj().set("cmd", "trace").set("error", "missing id");
        };
        let local = trace::span_tree_json(tid, "router");
        let owner = self.trace_seen.lock().unwrap().map.get(&tid).copied();
        let remote = owner.and_then(|i| self.fetch_backend_trace(i, tid));
        let mut roots: Vec<Json> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for tree in std::iter::once(local).chain(remote) {
            if let Some(arr) = tree.get("tree").and_then(Json::as_arr) {
                for n in arr {
                    if seen.insert(n.to_string_compact()) {
                        roots.push(n.clone());
                    }
                }
            }
        }
        roots.sort_by(|a, b| {
            let t = |n: &Json| n.get("t_start_us").and_then(Json::as_f64).unwrap_or(0.0);
            t(a).partial_cmp(&t(b)).unwrap_or(std::cmp::Ordering::Equal)
        });
        fn nodes(n: &Json) -> usize {
            1 + n
                .get("children")
                .and_then(Json::as_arr)
                .map_or(0, |kids| kids.iter().map(nodes).sum())
        }
        let count: usize = roots.iter().map(nodes).sum();
        Json::obj()
            .set("cmd", "trace")
            .set("id", tid)
            .set("count", count as f64)
            .set("tree", Json::Arr(roots))
    }

    /// One-shot `{"cmd":"trace"}` query against backend `index` over its
    /// own connection (timeout-bounded; `None` on any failure).
    fn fetch_backend_trace(&self, index: usize, tid: u64) -> Option<Json> {
        use std::io::Write;
        let addr = &self.backends.get(index)?.addr;
        let timeout = Duration::from_millis(self.policy.connect_timeout_ms.max(1));
        let sa = addr.to_socket_addrs().ok()?.next()?;
        let stream = TcpStream::connect_timeout(&sa, timeout).ok()?;
        stream.set_read_timeout(Some(timeout)).ok()?;
        let mut w = stream.try_clone().ok()?;
        let req = Json::obj().set("cmd", "trace").set("id", tid);
        writeln!(w, "{}", req.to_string_compact()).ok()?;
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).ok()?;
        Json::parse(line.trim()).ok()
    }

    /// Begin draining backend `index`: stop routing new requests to it
    /// and forward `{"cmd":"drain"}` so it finishes in-flight work and
    /// exits. Returns `false` for an unknown index or a backend
    /// already down.
    pub fn drain_backend(&self, index: usize) -> bool {
        let Some(b) = self.backends.get(index) else {
            return false;
        };
        if b.state() == BackendState::Down {
            return false;
        }
        log::info!("draining backend {index} ({})", b.addr);
        // Order matters: no new routes *before* the backend stops
        // admitting, so nothing slips in behind the drain.
        b.set_state(BackendState::Draining);
        b.send_line(r#"{"cmd":"drain"}"#);
        true
    }

    /// The router's `{"cmd":"metrics"}` reply: aggregate counters plus
    /// one object per backend (state, load gauges, routing counters).
    pub fn metrics_json(&self) -> Json {
        let mut inflight_total = 0u64;
        let backends = Json::Arr(
            self.backends
                .iter()
                .map(|b| {
                    let inflight = b.inflight.lock().unwrap().len() as u64;
                    inflight_total += inflight;
                    Json::obj()
                        .set("addr", b.addr.as_str())
                        .set("backend_state", b.state().as_str())
                        .set("queue_depth", b.queue_depth.load(Ordering::Relaxed))
                        .set("slots_in_use", b.slots_in_use.load(Ordering::Relaxed))
                        .set(
                            "cache_blocks_in_use",
                            b.cache_blocks_in_use.load(Ordering::Relaxed),
                        )
                        .set("inflight", inflight)
                        .set("routed", b.counters.routed.load(Ordering::Relaxed))
                        .set("hash_routed", b.counters.hash_routed.load(Ordering::Relaxed))
                        .set("spilled", b.counters.spilled.load(Ordering::Relaxed))
                        .set("failovers", b.counters.failovers.load(Ordering::Relaxed))
                        .set(
                            "missed_heartbeats",
                            b.counters.missed_heartbeats.load(Ordering::Relaxed),
                        )
                })
                .collect(),
        );
        Json::obj()
            .set("routed", self.metrics.routed.load(Ordering::Relaxed))
            .set("hash_routed", self.metrics.hash_routed.load(Ordering::Relaxed))
            .set("spilled", self.metrics.spilled.load(Ordering::Relaxed))
            .set("failovers", self.metrics.failovers.load(Ordering::Relaxed))
            .set("inflight", inflight_total)
            .set("stages", trace::kind_totals_json())
            .set("trace_dropped", trace::dropped())
            .set("backends", backends)
    }

    /// Stop the router: end the heartbeat thread, take every backend
    /// down and dispose whatever was still in flight (clients get
    /// `backend lost`; their connections are closing anyway).
    pub fn stop(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        for b in &self.backends {
            b.set_state(BackendState::Down);
        }
        for b in &self.backends {
            b.sever(None);
            let entries: Vec<Inflight> = {
                let mut inflight = b.inflight.lock().unwrap();
                inflight.drain().map(|(_, e)| e).collect()
            };
            for e in entries {
                self.fail(e, "backend lost");
            }
        }
        if let Some(h) = self.heartbeat.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        // The heartbeat thread holds only a Weak and exits at its next
        // tick once this flag is set (or its upgrade fails); setting it
        // here covers routers dropped without an explicit `stop()`.
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// The reader ("pump") thread of one backend connection: routes every
/// incoming frame — stream deltas and finals by router id back to
/// their clients, id-less control frames to the heartbeat handler —
/// and, when the connection dies, runs the disposal path exactly once.
fn pump_loop(router: &Arc<Router>, b: &Arc<Backend>, stream: TcpStream, epoch: u64) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(frame) = Json::parse(trimmed) else {
            log::warn!("backend {} sent unparseable frame", b.index);
            continue;
        };
        let Some(rid) = parse_id(&frame) else {
            router.on_control_frame(b, &frame);
            continue;
        };
        // Fault point: one data frame about to be delivered. A
        // connection-killing action loses this frame with the link —
        // exactly what a real mid-stream death does.
        if let Some(a) = router.fault_check(FaultOp::RouterReply, b.index) {
            if !router.apply_network_action(b, a) {
                break;
            }
        }
        if frame.get("delta").is_some() {
            let routed = {
                let mut inflight = b.inflight.lock().unwrap();
                inflight.get_mut(&rid).map(|e| {
                    e.started = true;
                    (e.client_id, e.tx.clone())
                })
            };
            if let Some((client_id, tx)) = routed {
                let _ = tx.send(frame.set("id", client_id).to_string_compact());
            }
        } else {
            let entry = b.inflight.lock().unwrap().remove(&rid);
            if let Some(e) = entry {
                let shed_by_drain = !e.started
                    && !e.retried
                    && frame.get("error").and_then(Json::as_str) == Some("shutting down");
                if shed_by_drain {
                    // The forward raced the backend's drain: it was
                    // never admitted, so re-dispatching it elsewhere is
                    // exact — this is how a drain drops zero requests.
                    router.redispatch(rid, e, b.index);
                } else {
                    router.deliver_final(e, frame);
                }
            }
        }
    }
    router.on_conn_lost(b, epoch);
}

/// The heartbeat thread: one ticker for all backends — probes live
/// connections, counts misses, tears down silent backends, dials
/// disconnected ones under exponential backoff + jitter, and completes
/// drains whose inflight tables have emptied.
fn heartbeat_loop(weak: &std::sync::Weak<Router>) {
    let mut rngs: Vec<Rng> = Vec::new();
    loop {
        // Upgrade per tick and drop before sleeping: the thread keeps
        // the router alive only while actually inspecting it.
        let Some(router) = weak.upgrade() else {
            return;
        };
        if router.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let interval = router.policy.heartbeat_ms.max(1);
        if rngs.is_empty() {
            // Deterministic per-backend jitter streams: reconnect
            // storms decorrelate, runs stay reproducible.
            rngs = (0..router.backends.len())
                .map(|i| Rng::new(0x51a1_0b00 + i as u64))
                .collect();
        }
        heartbeat_tick(&router, &mut rngs);
        drop(router);
        // Sleep in short slices so `stop()` joins promptly even under
        // a long heartbeat interval.
        let mut slept = 0u64;
        while slept < interval {
            let step = (interval - slept).min(20);
            std::thread::sleep(Duration::from_millis(step));
            slept += step;
            match weak.upgrade() {
                Some(r) if !r.shutdown.load(Ordering::SeqCst) => {}
                _ => return,
            }
        }
    }
}

/// One heartbeat pass over every backend (see [`heartbeat_loop`]).
fn heartbeat_tick(router: &Arc<Router>, rngs: &mut [Rng]) {
    let policy = router.policy;
    let t0 = trace::now_us();
    {
        for b in &router.backends {
            match b.state() {
                BackendState::Down => continue,
                BackendState::Draining => {
                    if b.inflight.lock().unwrap().is_empty() {
                        // In-process backends never close the router's
                        // connection when they exit their accept loop,
                        // so drain completion is detected here, not
                        // only at EOF.
                        b.set_state(BackendState::Down);
                        b.sever(None);
                        log::info!("backend {} drained", b.index);
                    }
                    continue;
                }
                BackendState::Healthy | BackendState::Unhealthy => {}
            }
            if b.connected() {
                if b.probe_outstanding.load(Ordering::SeqCst) {
                    let missed = b.missed.fetch_add(1, Ordering::Relaxed) + 1;
                    b.counters.missed_heartbeats.fetch_add(1, Ordering::Relaxed);
                    if missed >= policy.miss_threshold {
                        log::warn!(
                            "backend {} missed {missed} heartbeats: marking unhealthy",
                            b.index
                        );
                        // State first, socket second: no new routes
                        // land between the two, and the pump thread
                        // does the actual disposal.
                        b.set_state_unless_down(BackendState::Unhealthy);
                        b.shut_socket();
                    }
                } else {
                    b.probe_outstanding.store(true, Ordering::SeqCst);
                    b.send_line(r#"{"cmd":"metrics"}"#);
                }
            } else if *b.next_attempt.lock().unwrap() <= Instant::now() {
                match dial(&b.addr, policy.connect_timeout_ms) {
                    Ok(stream) => {
                        let reader = match stream.try_clone() {
                            Ok(r) => r,
                            Err(_) => continue, // treat as a failed dial next tick
                        };
                        let epoch = b.install_conn(Arc::new(stream));
                        let (router, b2) = (router.clone(), b.clone());
                        std::thread::spawn(move || pump_loop(&router, &b2, reader, epoch));
                        // Probe immediately: reintegration happens when
                        // (and only when) this probe is answered.
                        b.missed.store(0, Ordering::Relaxed);
                        b.probe_outstanding.store(true, Ordering::SeqCst);
                        b.send_line(r#"{"cmd":"metrics"}"#);
                    }
                    Err(_) => {
                        let fails = b.consec_fails.fetch_add(1, Ordering::Relaxed) + 1;
                        let backoff = policy
                            .backoff_base_ms
                            .saturating_mul(1u64 << (fails - 1).min(16))
                            .min(policy.backoff_max_ms.max(policy.backoff_base_ms));
                        let jitter =
                            rngs[b.index].below((backoff / 4 + 1) as usize) as u64;
                        *b.next_attempt.lock().unwrap() =
                            Instant::now() + Duration::from_millis(backoff + jitter);
                        log::info!(
                            "backend {} unreachable (attempt {fails}); next dial in ~{backoff} ms",
                            b.index
                        );
                    }
                }
            }
        }
    }
    if trace::enabled() {
        let healthy = router
            .backends
            .iter()
            .filter(|b| b.state() == BackendState::Healthy)
            .count() as u64;
        trace::record_span(TraceKind::Heartbeat, 0, t0, healthy);
    }
}

fn dial(addr: &str, timeout_ms: u64) -> std::io::Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::NotFound, "no address"))?;
    TcpStream::connect_timeout(&sa, Duration::from_millis(timeout_ms.max(1)))
}

/// Serve the router tier on `addr` over `backend_addrs`, until a
/// `{"cmd":"shutdown"}` arrives. Arms `SALR_FAULT` if set. If `ready`
/// is provided, the bound address is sent once listening.
pub fn serve_router(
    backend_addrs: &[String],
    addr: &str,
    policy: RouterPolicy,
    ready: Option<Sender<std::net::SocketAddr>>,
) -> Result<()> {
    serve_router_on(Router::new(backend_addrs, policy), addr, ready)
}

/// [`serve_router`] over a caller-built [`Router`] — the injection
/// point for [`Router::with_fault`] in deterministic network-fault
/// tests.
pub fn serve_router_on(
    router: Arc<Router>,
    addr: &str,
    ready: Option<Sender<std::net::SocketAddr>>,
) -> Result<()> {
    trace::init_from_env();
    let listener = TcpListener::bind(addr).with_context(|| format!("binding router {addr}"))?;
    let local = listener.local_addr()?;
    log::info!(
        "router on {local} fronting {} backend(s): {}",
        router.backends.len(),
        router
            .backends
            .iter()
            .map(|b| b.addr.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );
    if let Some(tx) = ready {
        let _ = tx.send(local);
    }
    let stop = Arc::new(AtomicBool::new(false));
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        let router = router.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            match handle_client(&router, stream) {
                Ok(true) => {
                    stop.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(local);
                }
                Ok(false) => {}
                Err(e) => log::warn!("router connection error: {e:#}"),
            }
        });
    }
    router.stop();
    trace::dump_trace_out("router");
    Ok(())
}

/// One client connection on the router: same wire protocol as the
/// serving tier, same bounded-reply-queue backpressure. Returns
/// `Ok(true)` if this connection requested router shutdown.
fn handle_client(router: &Arc<Router>, stream: TcpStream) -> Result<bool> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let (tx, reply_rx) =
        std::sync::mpsc::sync_channel::<String>(router.policy.stream_frame_cap.max(1));
    let reply_tx = FrameTx::new(tx, Some(Arc::new(stream.try_clone()?)));
    let mut writer = stream;
    let writer_thread = std::thread::spawn(move || {
        use std::io::Write;
        for line in reply_rx {
            if writeln!(writer, "{line}").is_err() {
                break;
            }
        }
    });
    // This connection's live requests: client id → (backend index,
    // router id). Shared with every Inflight entry so whichever thread
    // disposes a request also unregisters it here.
    let conn_map: Arc<Mutex<HashMap<u64, (usize, u64)>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut line = String::new();
    let outcome: Result<bool> = loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) => break Err(e.into()),
        };
        if n == 0 {
            break Ok(false);
        }
        let msg = match Json::parse(line.trim()) {
            Ok(m) => m,
            Err(e) => {
                let err = Json::obj().set("error", format!("bad json: {e}"));
                let _ = reply_tx.send(err.to_string_compact());
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                let _ = reply_tx.send(Json::obj().set("ok", true).to_string_compact());
                break Ok(true);
            }
            Some("metrics") => {
                let _ = reply_tx.send(router.metrics_json().to_string_compact());
            }
            Some("trace") => {
                let reply = router.trace_json(parse_id(&msg));
                let _ = reply_tx.send(reply.to_string_compact());
            }
            Some("drain") => {
                // `{"cmd":"drain","backend":N}`: decommission one
                // backend without dropping a request.
                let ok = msg
                    .get("backend")
                    .and_then(Json::as_usize)
                    .is_some_and(|i| router.drain_backend(i));
                let _ = reply_tx.send(Json::obj().set("ok", ok).to_string_compact());
            }
            Some("cancel") => {
                // Translate the client's id to the router id and relay
                // to whichever backend holds the request. Best-effort
                // across failover; the cancelled request's final
                // `error: "cancelled"` frame flows back normally.
                let target = parse_id(&msg)
                    .and_then(|cid| conn_map.lock().unwrap().get(&cid).copied());
                let hit = target.is_some_and(|(bidx, rid)| {
                    router.backends[bidx].send_line(
                        &Json::obj().set("cmd", "cancel").set("id", rid).to_string_compact(),
                    )
                });
                let ack = Json::obj().set("cmd", "cancel").set("ok", hit);
                let _ = reply_tx.send(ack.to_string_compact());
            }
            _ => router.submit(msg, &reply_tx, &conn_map),
        }
    };
    // The client is gone (or asked us to stop): cancel whatever it
    // still has in flight on the backends. The finals those cancels
    // produce are dropped at this connection's dead FrameTx; the pump
    // removing them is what keeps the router's tables empty.
    let live: Vec<(usize, u64)> = conn_map.lock().unwrap().drain().map(|(_, v)| v).collect();
    for (bidx, rid) in live {
        router.backends[bidx]
            .send_line(&Json::obj().set("cmd", "cancel").set("id", rid).to_string_compact());
    }
    drop(reply_tx);
    let _ = writer_thread.join();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(n: usize) -> Arc<Router> {
        let addrs: Vec<String> = (0..n).map(|i| format!("127.0.0.1:{}", 20000 + i)).collect();
        // Long heartbeat + far-future dial time keep the heartbeat
        // thread inert for these pure routing-math tests.
        let policy = RouterPolicy {
            heartbeat_ms: 5_000,
            ..RouterPolicy::default()
        };
        let r = Router::with_fault(&addrs, policy, None);
        for b in &r.backends {
            *b.next_attempt.lock().unwrap() = Instant::now() + Duration::from_secs(3600);
        }
        r
    }

    #[test]
    fn ring_is_deterministic_and_covers_every_backend() {
        let a = router(3);
        let b = router(3);
        assert_eq!(a.ring, b.ring, "ring must be a pure function of n and vnodes");
        let mut seen = [false; 3];
        for &(_, idx) in &a.ring {
            seen[idx] = true;
        }
        assert!(seen.iter().all(|&s| s), "every backend owns ring range");
        a.stop();
        b.stop();
    }

    #[test]
    fn hash_key_is_block_aligned() {
        let r = router(2);
        let block = r.policy.kv_block_size; // 16 bytes with the byte tokenizer
        let head = "x".repeat(block * r.policy.hash_blocks);
        // Same leading blocks, different tails: same owner.
        let a = format!("{head}-tail-one");
        let b = format!("{head}-a-completely-different-tail");
        assert_eq!(r.hash_key(&a), r.hash_key(&b));
        assert_eq!(r.owner_of_prompt(&a), r.owner_of_prompt(&b));
        // A mid-block divergence *past* the hashed blocks must not
        // change the key; one *inside* the first block must.
        let c = format!("y{}", &head[1..]);
        assert_ne!(r.hash_key(&head), r.hash_key(&c));
        // Prompts shorter than one block hash whole: distinct shorts
        // get distinct keys.
        assert_ne!(r.hash_key("ab"), r.hash_key("cd"));
        assert_eq!(r.hash_key("ab"), r.hash_key("ab"));
        r.stop();
    }

    #[test]
    fn ring_order_redistributes_without_reshuffling() {
        // Consistent hashing's point: removing one backend only moves
        // the keys it owned; everyone else's owner is unchanged.
        let r = router(3);
        let prompts: Vec<String> = (0..64).map(|i| format!("prompt number {i:03}")).collect();
        for p in &prompts {
            let order = r.ring_order(r.hash_key(p));
            assert_eq!(order.len(), 3);
            let owner = order[0];
            // The fallback owner (first in ring order after the owner)
            // is what the range redistributes to on owner loss.
            assert_ne!(order[1], owner);
        }
        // All three backends own a non-trivial share of 64 prompts.
        let mut share = [0usize; 3];
        for p in &prompts {
            share[r.owner_of_prompt(p)] += 1;
        }
        assert!(share.iter().all(|&s| s > 0), "share: {share:?}");
        r.stop();
    }

    #[test]
    fn route_skips_unhealthy_and_spills_on_load() {
        let r = router(2);
        // No healthy backend: no route.
        assert!(r.route("hello").is_none());
        r.backends[0].set_state(BackendState::Healthy);
        r.backends[1].set_state(BackendState::Healthy);
        let p = "a prompt that hashes somewhere".to_string();
        let owner = r.owner_of_prompt(&p);
        let other = 1 - owner;
        let b = r.route(&p).unwrap();
        assert_eq!(b.index, owner, "healthy owner takes its hash range");
        assert_eq!(r.backends[owner].counters.hash_routed.load(Ordering::Relaxed), 1);
        // Owner over the spill depth: least-loaded healthy wins.
        r.backends[owner]
            .queue_depth
            .store(r.policy.spill_depth + 5, Ordering::Relaxed);
        let b = r.route(&p).unwrap();
        assert_eq!(b.index, other, "overloaded owner spills");
        assert_eq!(r.backends[other].counters.spilled.load(Ordering::Relaxed), 1);
        // Owner unhealthy: its range redistributes in ring order.
        r.backends[owner].queue_depth.store(0, Ordering::Relaxed);
        r.backends[owner].set_state(BackendState::Unhealthy);
        let b = r.route(&p).unwrap();
        assert_eq!(b.index, other);
        assert_eq!(
            r.backends[other].counters.hash_routed.load(Ordering::Relaxed),
            1,
            "redistributed range is hash routing, not spill"
        );
        r.stop();
    }
}
