//! Continuous batching: a shared admission queue feeding `W` engine
//! worker loops.
//!
//! Each worker owns an [`Engine`] fork (weights Arc-shared), a
//! fixed-size [`KvSlotPool`](crate::infer::KvSlotPool) of `max_batch`
//! sequence slots, and runs an
//! **iteration-level scheduling loop**: after every decode step it
//! retires finished sequences, admits waiting requests into the freed
//! slots (prefilling them into reused KV rows), and keeps stepping — so
//! batch occupancy stays near `max_batch` under load instead of draining
//! to zero between static batches.
//!
//! Responses complete **out of order** (a short request admitted late can
//! finish before a long request admitted early); each request carries its
//! own reply callback, and the TCP front-end routes replies by request id.
//!
//! Determinism: greedy decode is order-independent per sequence — every
//! engine computes a sequence's next token from that sequence's row and
//! KV cache alone — so per-request output is byte-identical whether it is
//! served alone, in a static batch, or continuously batched across any
//! number of engine workers. `rust/tests/integration_serve.rs` asserts
//! this end to end.

use crate::data::{detokenize, tokenize};
use crate::infer::Engine;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed in the [`Response`] (the out-of-order
    /// completion key).
    pub id: u64,
    /// Prompt text (tokenized by the worker on admission).
    pub prompt: String,
    /// Upper bound on generated tokens (clamped to the model context).
    pub max_tokens: usize,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echo of [`Request::id`].
    pub id: u64,
    /// Generated text.
    pub text: String,
    /// Time from enqueue to admission into a decode batch (milliseconds).
    pub queue_ms: f64,
    /// Time from admission to completion (milliseconds).
    pub compute_ms: f64,
    /// Generated token count.
    pub tokens: usize,
}

/// Scheduling policy for the serving layer.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Decode-batch slots per engine worker (KV slots are preallocated
    /// for exactly this many concurrent sequences per worker).
    pub max_batch: usize,
    /// How long an idle worker sleeps between admission checks. With
    /// continuous batching there is no batch-forming window — requests
    /// are admitted the moment a slot is free — so this only bounds
    /// shutdown latency; submissions wake idle workers immediately.
    pub max_wait: Duration,
    /// Worker threads for the engines' GEMM/pipeline stages, split evenly
    /// across engine workers (0 = all cores).
    pub num_threads: usize,
    /// Number of engine worker loops pulling from the shared queue.
    pub engine_workers: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            num_threads: 0,
            engine_workers: 1,
        }
    }
}

/// Aggregate serving metrics (lock-free counters; latencies under a lock).
#[derive(Default)]
pub struct ServerMetrics {
    /// Completed requests.
    pub requests: AtomicU64,
    /// Generated tokens across all requests.
    pub tokens_out: AtomicU64,
    /// Decode iterations executed across all engine workers.
    pub decode_steps: AtomicU64,
    /// Sum of batch occupancy over all decode steps (mean occupancy =
    /// `step_slots / decode_steps`).
    pub step_slots: AtomicU64,
    /// Requests admitted into a worker's batch.
    pub admitted: AtomicU64,
    /// Requests admitted while their worker already had live sequences
    /// decoding — i.e. they joined a running batch mid-stream instead of
    /// waiting for it to drain. Static batching keeps this at 0.
    pub admitted_midstream: AtomicU64,
    /// Highest batch occupancy any worker reached.
    pub max_occupancy: AtomicU64,
    /// Per-request end-to-end latencies (µs), for percentile queries.
    pub latencies_us: Mutex<Vec<u64>>,
    started: Mutex<Option<Instant>>,
}

impl ServerMetrics {
    /// Record a completed request.
    pub fn record(&self, resp: &Response) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(resp.tokens as u64, Ordering::Relaxed);
        let total_us = ((resp.queue_ms + resp.compute_ms) * 1000.0) as u64;
        self.latencies_us.lock().unwrap().push(total_us);
    }

    /// Record one decode iteration over `occupancy` live sequences.
    pub fn record_step(&self, occupancy: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.step_slots.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.max_occupancy.fetch_max(occupancy as u64, Ordering::Relaxed);
    }

    fn mark_started(&self) {
        let mut st = self.started.lock().unwrap();
        if st.is_none() {
            *st = Some(Instant::now());
        }
    }

    /// Generated tokens per second since the first admission.
    pub fn tokens_per_sec(&self) -> f64 {
        let st = self.started.lock().unwrap();
        match *st {
            Some(t0) => {
                self.tokens_out.load(Ordering::Relaxed) as f64
                    / t0.elapsed().as_secs_f64().max(1e-9)
            }
            None => 0.0,
        }
    }

    /// End-to-end latency percentiles in milliseconds: (p50, p90, p99).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        v.sort_unstable();
        let pick = |p: f64| v[((v.len() - 1) as f64 * p) as usize] as f64 / 1000.0;
        (pick(0.5), pick(0.9), pick(0.99))
    }

    /// Mean decode-batch occupancy: live sequences per decode step,
    /// averaged over every step any worker ran.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed).max(1);
        self.step_slots.load(Ordering::Relaxed) as f64 / steps as f64
    }
}

/// Per-worker counters, exposed through [`Batcher::worker_metrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerMetrics {
    /// Decode iterations this worker executed.
    pub steps: u64,
    /// Tokens this worker generated.
    pub tokens: u64,
    /// Requests this worker completed.
    pub retired: u64,
}

/// Reply callback: invoked exactly once with the finished [`Response`].
/// Boxed so the TCP front-end, blocking callers and benches can each
/// route completions their own way.
pub type ReplyFn = Box<dyn FnOnce(Response) + Send>;

struct Pending {
    req: Request,
    enqueued: Instant,
    reply: ReplyFn,
}

/// A sequence occupying a KV slot in one worker's decode batch.
struct LiveSeq {
    slot: usize,
    id: u64,
    reply: ReplyFn,
    enqueued: Instant,
    admitted: Instant,
    current: i32,
    out: Vec<i32>,
    budget: usize,
}

/// The admission queue plus the shared serving state; engine workers are
/// spawned on top with [`spawn_engine_workers`] (or run inline via
/// [`Batcher::worker_loop`]).
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    policy: BatchPolicy,
    /// Aggregate metrics across all engine workers.
    pub metrics: ServerMetrics,
    worker_metrics: Mutex<Vec<WorkerMetrics>>,
    shutdown: AtomicBool,
}

impl Batcher {
    /// A batcher with no workers yet (see [`spawn_engine_workers`]).
    pub fn new(policy: BatchPolicy) -> Arc<Batcher> {
        Arc::new(Batcher {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            policy,
            metrics: ServerMetrics::default(),
            worker_metrics: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
        })
    }

    /// The policy this batcher schedules under.
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Submit a request; blocks the calling thread until its response
    /// arrives (other requests keep flowing meanwhile). Panics if the
    /// batcher has already been shut down.
    pub fn submit(&self, req: Request) -> Response {
        let (tx, rx) = std::sync::mpsc::channel();
        let accepted = self.submit_with(
            req,
            Box::new(move |resp| {
                let _ = tx.send(resp);
            }),
        );
        assert!(accepted, "submit after batcher shutdown");
        rx.recv().expect("batcher dropped reply channel")
    }

    /// Submit a request with an explicit completion callback — the
    /// non-blocking form the TCP front-end uses so one connection can
    /// have many requests in flight (responses return out of order).
    /// Returns `false` (dropping `reply` un-fired) if shutdown has
    /// already been requested: no worker would ever serve the request.
    pub fn submit_with(&self, req: Request, reply: ReplyFn) -> bool {
        {
            // The flag is checked under the queue lock — the same lock
            // under which workers make their final empty-queue exit
            // decision — so a request can never slip in between the
            // workers' last drain and their exit.
            let mut q = self.queue.lock().unwrap();
            if self.shutdown.load(Ordering::SeqCst) {
                return false;
            }
            q.push_back(Pending {
                req,
                enqueued: Instant::now(),
                reply,
            });
        }
        self.cv.notify_all();
        true
    }

    /// Ask every worker loop to exit. Workers first drain what is already
    /// queued (every accepted request's reply callback still fires) and
    /// finish their live sequences; *new* submissions are rejected from
    /// this point on (see [`Batcher::submit_with`]).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Drop any requests still queued — call only after the worker
    /// threads have exited, to release the reply callbacks (and whatever
    /// channels they hold) of requests that raced past
    /// [`Batcher::shutdown`] into the queue. Returns how many were
    /// dropped.
    pub fn drain_abandoned(&self) -> usize {
        let mut q = self.queue.lock().unwrap();
        let n = q.len();
        q.clear();
        n
    }

    /// Snapshot of per-worker counters, indexed by worker id.
    pub fn worker_metrics(&self) -> Vec<WorkerMetrics> {
        self.worker_metrics.lock().unwrap().clone()
    }

    /// Pop up to `room` waiting requests. When the worker is fully idle
    /// (`have_live == false`) this blocks until a request arrives or
    /// shutdown; when sequences are mid-decode it never waits — the
    /// decode loop must keep stepping.
    fn admit_up_to(&self, room: usize, have_live: bool) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                // Let the caller finish its live sequences, then exit.
                return if have_live { Some(Vec::new()) } else { None };
            }
            if !q.is_empty() || have_live {
                let n = q.len().min(room);
                return Some(q.drain(..n).collect());
            }
            let wait = self.policy.max_wait.max(Duration::from_millis(1));
            q = self.cv.wait_timeout(q, wait).unwrap().0;
        }
    }

    /// The continuous-batching engine worker loop. Runs until shutdown;
    /// `worker` is this loop's id for per-worker metrics. Call on a
    /// dedicated thread with this worker's engine fork (or use
    /// [`spawn_engine_workers`]).
    pub fn worker_loop(&self, engine: &Engine, worker: usize) {
        {
            let mut wm = self.worker_metrics.lock().unwrap();
            if wm.len() <= worker {
                wm.resize(worker + 1, WorkerMetrics::default());
            }
        }
        let max_ctx = engine.weights.cfg.max_seq_len;
        let nslots = self.policy.max_batch.max(1);
        let mut kv = engine.new_slot_pool(nslots);
        let mut live: Vec<LiveSeq> = Vec::new();
        let mut local = WorkerMetrics::default();

        loop {
            // --- admit into free slots ---
            let room = nslots - live.len();
            let admitted = match self.admit_up_to(room, !live.is_empty()) {
                Some(batch) => batch,
                None => break, // shutdown while idle
            };
            // Mid-stream means joining a batch that was already decoding
            // before this admission round — co-admissions into an idle
            // worker's fresh batch don't count.
            let was_live = !live.is_empty();
            for p in admitted {
                self.metrics.mark_started();
                self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                if was_live {
                    self.metrics.admitted_midstream.fetch_add(1, Ordering::Relaxed);
                }
                let admitted_at = Instant::now();
                let (toks, budget) = prepare_prompt(&p.req, max_ctx);
                let slot = kv.alloc().expect("admission respects free slots");
                let first = engine.prefill(&toks, slot, &mut kv);
                live.push(LiveSeq {
                    slot,
                    id: p.req.id,
                    reply: p.reply,
                    enqueued: p.enqueued,
                    admitted: admitted_at,
                    current: first,
                    out: vec![first],
                    budget,
                });
            }
            // Retire admissions that are already at budget (single-token
            // requests complete on their prefill alone).
            self.retire_finished(&mut live, &mut kv, &mut local);
            if live.is_empty() {
                // Loop back to admission: on shutdown `admit_up_to` keeps
                // draining queued requests (their reply callbacks must
                // fire) and only returns `None` once the queue is empty.
                continue;
            }
            // --- one decode iteration over the current batch ---
            let current: Vec<i32> = live.iter().map(|s| s.current).collect();
            let slots: Vec<usize> = live.iter().map(|s| s.slot).collect();
            self.metrics.record_step(live.len());
            local.steps += 1;
            let next = engine.decode_step(&current, &slots, &mut kv);
            for (seq, tok) in live.iter_mut().zip(next) {
                seq.current = tok;
                seq.out.push(tok);
            }
            // Retire immediately after the step, so a finished request's
            // reply fires before (and its latency never absorbs) the next
            // admission round's prefills — and so the freed slots count
            // toward that round's room.
            self.retire_finished(&mut live, &mut kv, &mut local);
            // Publish per-worker counters (cheap: one short lock per
            // decode iteration, far below the forward-pass cost).
            self.worker_metrics.lock().unwrap()[worker] = local;
        }
        self.worker_metrics.lock().unwrap()[worker] = local;
    }

    /// Retire every live sequence that has reached its token budget:
    /// free its KV slot, record metrics, detokenize and fire its reply.
    fn retire_finished(
        &self,
        live: &mut Vec<LiveSeq>,
        kv: &mut crate::infer::KvSlotPool,
        local: &mut WorkerMetrics,
    ) {
        let mut i = 0;
        while i < live.len() {
            if live[i].out.len() >= live[i].budget {
                let seq = live.swap_remove(i);
                kv.free(seq.slot);
                local.retired += 1;
                local.tokens += seq.out.len() as u64;
                let resp = Response {
                    id: seq.id,
                    text: detokenize(&seq.out),
                    queue_ms: (seq.admitted - seq.enqueued).as_secs_f64() * 1000.0,
                    compute_ms: seq.admitted.elapsed().as_secs_f64() * 1000.0,
                    tokens: seq.out.len(),
                };
                self.metrics.record(&resp);
                (seq.reply)(resp);
            } else {
                i += 1;
            }
        }
    }
}

/// Tokenize a request's prompt, clamp its generation budget to the model
/// context, and truncate the prompt head so `prompt + budget` fits.
/// Returns `(tokens, budget)` with `tokens` non-empty and `budget >= 1`.
fn prepare_prompt(req: &Request, max_ctx: usize) -> (Vec<i32>, usize) {
    let mut toks = tokenize(&req.prompt);
    let budget = req.max_tokens.clamp(1, max_ctx.saturating_sub(2).max(1));
    if toks.len() + budget > max_ctx {
        let cut = toks.len() + budget - max_ctx;
        toks.drain(..cut.min(toks.len().saturating_sub(1)));
    }
    if toks.is_empty() {
        toks.push(b' ' as i32);
    }
    (toks, budget)
}

/// Spawn `engine_workers` (per the batcher's policy) engine worker
/// threads over forks of `engine`, giving each fork a **private** worker
/// pool holding an even share of `num_threads` (0 = all cores) GEMM
/// threads. Returns the join handles; call [`Batcher::shutdown`] then
/// join to stop.
pub fn spawn_engine_workers(
    batcher: &Arc<Batcher>,
    engine: Engine,
) -> Vec<std::thread::JoinHandle<()>> {
    use crate::util::pool::{available_threads, WorkerPool};
    let policy = *batcher.policy();
    let workers = policy.engine_workers.max(1);
    let total = if policy.num_threads > 0 {
        policy.num_threads
    } else {
        available_threads()
    };
    let per_worker = (total / workers).max(1);
    let mut handles = Vec::with_capacity(workers);
    for w in 0..workers {
        let mut eng = engine.fork();
        // Private pools (not the global size registry) so each worker's
        // dense linears and small-m decode GEMMs own disjoint threads.
        // Caveat: the pipelined backend's large-m *prefill* path still
        // resolves a per-size registry pool from PipelineConfig's thread
        // knob, so concurrent prefills share that one (see
        // SalrLayer::forward and the ROADMAP pool-threading item).
        eng.set_pool(Arc::new(WorkerPool::new(per_worker)));
        let b = batcher.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("salr-engine-{w}"))
                .spawn(move || b.worker_loop(&eng, w))
                .expect("spawn engine worker"),
        );
    }
    handles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{Backend, Engine, EngineWeights};
    use crate::model::ParamStore;
    use crate::runtime::ModelCfg;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 96,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 4,
            batch_size: 2,
            ctx_keep: 0.5,
        };
        let mut rng = Rng::new(500);
        let base = ParamStore::init_base(&cfg, &mut rng);
        Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
    }

    #[test]
    fn batcher_serves_concurrent_requests() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            ..Default::default()
        });
        let handles_srv = spawn_engine_workers(&batcher, eng);
        let mut handles = Vec::new();
        for i in 0..6 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                b.submit(Request {
                    id: i,
                    prompt: format!("Q: {i}+1=? A: "),
                    max_tokens: 3,
                })
            }));
        }
        let mut responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens, 3);
        }
        assert_eq!(batcher.metrics.requests.load(Ordering::Relaxed), 6);
        assert!(batcher.metrics.mean_batch_occupancy() >= 1.0);
        batcher.shutdown();
        for h in handles_srv {
            h.join().unwrap();
        }
    }

    #[test]
    fn deterministic_across_submissions() {
        let eng = engine();
        // Same prompt must yield the same text whenever it is submitted.
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 2,
            ..Default::default()
        });
        let handles = spawn_engine_workers(&batcher, eng);
        let r1 = batcher.submit(Request {
            id: 1,
            prompt: "Q: 2+2=? A: ".into(),
            max_tokens: 4,
        });
        let r2 = batcher.submit(Request {
            id: 2,
            prompt: "Q: 2+2=? A: ".into(),
            max_tokens: 4,
        });
        assert_eq!(r1.text, r2.text);
        batcher.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn midstream_admission_joins_a_live_batch() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            ..Default::default()
        });
        let handles = spawn_engine_workers(&batcher, eng);
        // A long request keeps the single worker's batch live…
        let b1 = batcher.clone();
        let long = std::thread::spawn(move || {
            b1.submit(Request {
                id: 1,
                prompt: "Q: 10+20=? A: ".into(),
                max_tokens: 80,
            })
        });
        // …wait until it is actually decoding, then admit a second one.
        let t0 = Instant::now();
        while batcher.metrics.decode_steps.load(Ordering::Relaxed) < 1 {
            assert!(t0.elapsed() < Duration::from_secs(20), "worker never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        let short = batcher.submit(Request {
            id: 2,
            prompt: "Q: 1+1=? A: ".into(),
            max_tokens: 2,
        });
        assert_eq!(short.tokens, 2);
        let long_resp = long.join().unwrap();
        assert_eq!(long_resp.tokens, 80);
        assert!(
            batcher.metrics.admitted_midstream.load(Ordering::Relaxed) >= 1,
            "second request must join the live batch, not wait for a drain"
        );
        assert!(
            batcher.metrics.max_occupancy.load(Ordering::Relaxed) >= 2,
            "occupancy must grow without the batch draining"
        );
        // Out-of-order completion: the short request finished first.
        assert!(batcher.metrics.requests.load(Ordering::Relaxed) == 2);
        batcher.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let batcher = Batcher::new(BatchPolicy::default());
        batcher.shutdown();
        let ok = batcher.submit_with(
            Request {
                id: 1,
                prompt: "x".into(),
                max_tokens: 1,
            },
            Box::new(|_| panic!("reply must not fire for a rejected request")),
        );
        assert!(!ok, "post-shutdown submissions must be rejected");
        assert_eq!(batcher.drain_abandoned(), 0, "nothing may have been queued");
    }

    #[test]
    fn prepare_prompt_clamps_to_context() {
        let req = Request {
            id: 0,
            prompt: "x".repeat(500),
            max_tokens: 1000,
        };
        let (toks, budget) = prepare_prompt(&req, 96);
        assert!(budget >= 1 && budget <= 94);
        assert!(!toks.is_empty());
        assert!(toks.len() + budget <= 96);
    }
}
