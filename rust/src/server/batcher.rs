//! Dynamic batcher: requests queue until either `max_batch` are waiting or
//! the oldest has waited `max_wait`; the formed batch decodes together so
//! every adapted linear sees an m-row GEMM (the utilization the paper's
//! adapter concatenation is designed for).

use crate::data::{detokenize, tokenize};
use crate::infer::Engine;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_tokens: usize,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub queue_ms: f64,
    pub compute_ms: f64,
    pub tokens: usize,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Worker threads for the engine's GEMM/pipeline stages
    /// (0 = keep the engine's own setting / all cores).
    pub num_threads: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            num_threads: 0,
        }
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_requests: AtomicU64,
    pub latencies_us: Mutex<Vec<u64>>,
    started: Mutex<Option<Instant>>,
}

impl ServerMetrics {
    pub fn record(&self, resp: &Response, batch_size: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(resp.tokens as u64, Ordering::Relaxed);
        self.batched_requests.fetch_add(1, Ordering::Relaxed);
        let _ = batch_size;
        let total_us = ((resp.queue_ms + resp.compute_ms) * 1000.0) as u64;
        self.latencies_us.lock().unwrap().push(total_us);
        let mut st = self.started.lock().unwrap();
        if st.is_none() {
            *st = Some(Instant::now());
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let st = self.started.lock().unwrap();
        match *st {
            Some(t0) => {
                self.tokens_out.load(Ordering::Relaxed) as f64
                    / t0.elapsed().as_secs_f64().max(1e-9)
            }
            None => 0.0,
        }
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        v.sort_unstable();
        let pick = |p: f64| v[((v.len() - 1) as f64 * p) as usize] as f64 / 1000.0;
        (pick(0.5), pick(0.9), pick(0.99))
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

struct Pending {
    req: Request,
    enqueued: Instant,
    reply: std::sync::mpsc::Sender<Response>,
}

/// The dynamic batcher: owns the queue and the engine worker loop.
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    policy: BatchPolicy,
    pub metrics: ServerMetrics,
    shutdown: AtomicBool,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Arc<Batcher> {
        Arc::new(Batcher {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            policy,
            metrics: ServerMetrics::default(),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Submit a request; blocks until the response arrives.
    pub fn submit(&self, req: Request) -> Response {
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.queue.lock().unwrap();
            q.push_back(Pending {
                req,
                enqueued: Instant::now(),
                reply: tx,
            });
        }
        self.cv.notify_one();
        rx.recv().expect("batcher dropped reply channel")
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// The worker loop: form batches per policy, decode, reply. Run on a
    /// dedicated thread with the engine.
    pub fn worker_loop(&self, engine: &Engine) {
        loop {
            let batch = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    if q.is_empty() {
                        q = self.cv.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
                        continue;
                    }
                    let oldest_wait = q.front().unwrap().enqueued.elapsed();
                    if q.len() >= self.policy.max_batch || oldest_wait >= self.policy.max_wait {
                        let n = q.len().min(self.policy.max_batch);
                        break q.drain(..n).collect::<Vec<_>>();
                    }
                    // Wait out the remainder of the batching window.
                    let remaining = self.policy.max_wait - oldest_wait;
                    q = self.cv.wait_timeout(q, remaining).unwrap().0;
                }
            };
            self.metrics.batches.fetch_add(1, Ordering::Relaxed);
            self.run_batch(engine, batch);
        }
    }

    fn run_batch(&self, engine: &Engine, batch: Vec<Pending>) {
        let max_ctx = engine.weights.cfg.max_seq_len;
        let t0 = Instant::now();
        let mut prompts = Vec::with_capacity(batch.len());
        let mut max_new = 0usize;
        for p in &batch {
            let mut toks = tokenize(&p.req.prompt);
            let budget = p.req.max_tokens.min(max_ctx.saturating_sub(2));
            if toks.len() + budget > max_ctx {
                let cut = toks.len() + budget - max_ctx;
                toks.drain(..cut.min(toks.len().saturating_sub(1)));
            }
            if toks.is_empty() {
                toks.push(b' ' as i32);
            }
            max_new = max_new.max(budget.max(1));
            prompts.push(toks);
        }
        let outputs = engine.generate_batch(&prompts, max_new);
        let compute_ms = t0.elapsed().as_secs_f64() * 1000.0;
        let bsz = batch.len();
        for (p, out) in batch.into_iter().zip(outputs) {
            let n = p.req.max_tokens.min(out.len());
            let text = detokenize(&out[..n]);
            let resp = Response {
                id: p.req.id,
                text,
                queue_ms: (t0 - p.enqueued).as_secs_f64() * 1000.0,
                compute_ms,
                tokens: n,
            };
            self.metrics.record(&resp, bsz);
            let _ = p.reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{Backend, Engine, EngineWeights};
    use crate::model::ParamStore;
    use crate::runtime::ModelCfg;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 32,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 4,
            batch_size: 2,
            ctx_keep: 0.5,
        };
        let mut rng = Rng::new(500);
        let base = ParamStore::init_base(&cfg, &mut rng);
        Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
    }

    #[test]
    fn batcher_serves_concurrent_requests() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(3),
            ..Default::default()
        });
        let b2 = batcher.clone();
        let worker = std::thread::spawn(move || b2.worker_loop(&eng));
        let mut handles = Vec::new();
        for i in 0..6 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                b.submit(Request {
                    id: i,
                    prompt: format!("Q: {i}+1=? A: "),
                    max_tokens: 3,
                })
            }));
        }
        let mut responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens, 3);
        }
        assert!(batcher.metrics.requests.load(Ordering::Relaxed) == 6);
        assert!(batcher.metrics.mean_batch_size() > 1.0, "batching must kick in");
        batcher.shutdown();
        worker.join().unwrap();
    }

    #[test]
    fn deterministic_across_batch_compositions() {
        let eng = engine();
        // Same prompt must yield the same text whether batched or alone.
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let b2 = batcher.clone();
        let worker = std::thread::spawn(move || b2.worker_loop(&eng));
        let r1 = batcher.submit(Request {
            id: 1,
            prompt: "Q: 2+2=? A: ".into(),
            max_tokens: 4,
        });
        let r2 = batcher.submit(Request {
            id: 2,
            prompt: "Q: 2+2=? A: ".into(),
            max_tokens: 4,
        });
        assert_eq!(r1.text, r2.text);
        batcher.shutdown();
        worker.join().unwrap();
    }
}
