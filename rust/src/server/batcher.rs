//! Continuous batching: a shared admission queue feeding `W` engine
//! worker loops, with **chunked prefill**, **token streaming** and
//! **cross-worker work stealing**.
//!
//! Each worker owns an [`Engine`] fork (weights Arc-shared), a
//! fixed-size [`KvSlotPool`](crate::infer::KvSlotPool) of `max_batch`
//! sequence slots, and runs an **iteration-level scheduling loop**. One
//! scheduler iteration is:
//!
//! 1. *admit*: pop waiting requests from the shared queue into this
//!    worker's claim board (bounded by free capacity); if the queue is
//!    empty but another worker is hoarding unstarted claims, **steal**
//!    from the back of the longest board instead. Admission probes the
//!    worker's **radix-tree prefix cache** (when enabled): the cached
//!    head of the prompt is attached as shared KV blocks and its prefill
//!    forwards are skipped entirely (`prefix_hit_tokens` counts them);
//! 2. *prefill one chunk*: feed at most [`BatchPolicy::prefill_chunk`]
//!    prompt tokens of the oldest unfinished prefill through
//!    [`Engine::prefill_chunk`] — a long prompt therefore spreads over
//!    many iterations instead of freezing the batch. A finished prompt
//!    registers its full blocks in the prefix cache for later requests;
//! 3. *decode*: one [`Engine::decode_step`] over every fully-prefilled
//!    sequence, so running requests keep producing tokens **between**
//!    another request's prefill chunks. With speculation enabled
//!    ([`BatchPolicy::spec_decode`], `--spec-decode {off,radix,self}`)
//!    this becomes draft + verify per sequence: a
//!    [`Drafter`](crate::infer::Drafter) proposes up to
//!    [`BatchPolicy::spec_k`] tokens and one batched
//!    [`Engine::decode_verify`] forward accepts the longest greedy-exact
//!    prefix, emitting `accepted + 1` tokens per iteration instead of 1
//!    (`drafted_tokens` / `accepted_tokens` / `spec_rollbacks` count it);
//! 4. *retire*: finished sequences free their KV slots, fire their reply
//!    callbacks and (counted) make room for the next admissions.
//!
//! Responses complete **out of order** (a short request admitted late can
//! finish before a long request admitted early); each request carries its
//! own reply callback, and the TCP front-end routes replies by request id.
//! A request submitted with a stream callback additionally gets every
//! generated token's text delta as it is produced.
//!
//! Determinism: greedy decode is order-independent per sequence — every
//! engine computes a sequence's next token from that sequence's row and
//! KV cache alone, and chunked prefill splits the same per-row math over
//! several forwards — so per-request output is byte-identical whether it
//! is served alone, in a static batch, continuously batched across any
//! number of engine workers, or prefilled in chunks of any size. The
//! prefix cache preserves this bit for bit: a hit replays K/V rows a
//! cold prefill of the same head would have produced (same kernels,
//! same positions, immutable shared blocks), changing which GEMMs run
//! but never an output byte. `rust/tests/integration_serve.rs` asserts
//! both end to end.
//!
//! **Failure model** (see DESIGN.md "Failure model"): every request can
//! carry a [`CancelToken`] and a deadline ([`Request::timeout_ms`], or
//! [`BatchPolicy::default_deadline_ms`] for all requests); both are
//! checked at admission and at every scheduler-iteration boundary, and a
//! tripped request retires with `error: "cancelled"` / `"timeout"`, its
//! KV chain freed exactly like a normal retirement. A bounded admission
//! queue ([`BatchPolicy::max_queue_depth`]) sheds overflow with an
//! immediate `error: "overloaded"` reply instead of growing without
//! bound. Workers spawned by [`spawn_engine_workers`] run under a panic
//! **supervisor** ([`Batcher::supervised_worker_loop`]): a panicking
//! worker fails its in-flight sequences with error replies (their KV
//! blocks freed, never leaked), is replaced by a fresh [`Engine::fork`]
//! on the same queue and KV pool, and bumps
//! [`ServerMetrics::worker_restarts`] — siblings and the listener never
//! notice. Failure paths are exercised deterministically by the
//! op-counter-keyed [`FaultPlan`](crate::util::fault::FaultPlan)
//! injection harness (`SALR_FAULT`), in `rust/tests/integration_fault.rs`.

use crate::data::{detokenize, token_byte, tokenize};
use crate::infer::{Engine, KvCacheConfig, KvSlotPool, SpecMode};
use crate::util::fault::{FaultAction, FaultOp, FaultPlan};
use crate::util::hist::Hist;
use crate::util::trace::{self, TraceKind};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A shared cancellation latch for one request. Keep a clone, pass the
/// other via [`Request::cancel`]; [`CancelToken::cancel`] is a one-way
/// trip observed by the serving worker at its next scheduler-iteration
/// boundary (admission time if the request has not started), which
/// retires the request with `error: "cancelled"` and frees its KV chain
/// exactly. The TCP front-end wires the `{"cmd":"cancel","id":…}` frame
/// and client disconnects to these tokens.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Latch the token. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Has [`CancelToken::cancel`] been called (by anyone)?
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One generation request.
#[derive(Clone, Debug, Default)]
pub struct Request {
    /// Caller-chosen id, echoed in the [`Response`] (the out-of-order
    /// completion key).
    pub id: u64,
    /// Prompt text (tokenized by the worker on admission).
    pub prompt: String,
    /// Upper bound on generated tokens (clamped to the model context).
    pub max_tokens: usize,
    /// Deadline in milliseconds, measured from submission: a request
    /// still unfinished when it expires retires at the next scheduler
    /// boundary with `error: "timeout"` (partial output discarded, KV
    /// chain freed). `None` inherits
    /// [`BatchPolicy::default_deadline_ms`]; `Some(0)` expires
    /// immediately (useful to test the admission-time check).
    pub timeout_ms: Option<u64>,
    /// Cooperative cancellation: keep a [`CancelToken`] clone and
    /// `cancel()` it to retire the request at its next scheduler
    /// boundary with `error: "cancelled"`.
    pub cancel: Option<CancelToken>,
    /// End-to-end trace id (see [`crate::util::trace`]): every span this
    /// request produces — batcher scheduling, engine forwards, kernel
    /// pack/GEMM work — carries this id, so the spans stitch across tiers
    /// (and across the router process, which mints the id and forwards it
    /// on the wire). `0` = untraced.
    pub trace: u64,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct Response {
    /// Echo of [`Request::id`].
    pub id: u64,
    /// Generated text (empty when `error` is set).
    pub text: String,
    /// Why the request failed, if it did (e.g. a prompt longer than the
    /// KV slot capacity is rejected instead of served truncated).
    pub error: Option<String>,
    /// Time from enqueue to the start of prefill (milliseconds).
    pub queue_ms: f64,
    /// Time from prefill start to completion (milliseconds).
    pub compute_ms: f64,
    /// Generated token count.
    pub tokens: usize,
}

/// Scheduling policy for the serving layer.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Decode-batch slots per engine worker (KV slots are preallocated
    /// for exactly this many concurrent sequences per worker).
    pub max_batch: usize,
    /// How long an idle worker sleeps between admission checks. With
    /// continuous batching there is no batch-forming window — requests
    /// are admitted the moment a slot is free — so this only bounds
    /// shutdown latency and work-stealing latency; submissions wake idle
    /// workers immediately.
    pub max_wait: Duration,
    /// Worker threads for the engines' GEMM/pipeline stages, split evenly
    /// across engine workers (0 = all cores).
    pub num_threads: usize,
    /// Number of engine worker loops pulling from the shared queue.
    pub engine_workers: usize,
    /// Maximum prompt tokens prefilled per scheduler iteration (the chunk
    /// size of [`Engine::prefill_chunk`]). `0` disables chunking: whole
    /// prompts prefill in one forward, so one long prompt stalls that
    /// worker's decode batch for the duration — the pre-chunking behavior.
    pub prefill_chunk: usize,
    /// Token positions per KV block in each worker's paged slot pool
    /// (the `--kv-block-size` flag; also the prefix-sharing granularity).
    pub kv_block_size: usize,
    /// Enable the per-worker radix-tree prefix cache: requests sharing a
    /// prompt head attach the cached head's blocks on admission instead
    /// of re-running prefill over identical tokens (`--prefix-cache`).
    /// Off is bitwise identical to the pre-cache serving behavior.
    pub prefix_cache: bool,
    /// Bound on each TCP connection's queued reply/stream frames. A
    /// reader too slow to keep up has its connection closed once the
    /// queue fills, instead of ballooning server memory or blocking an
    /// engine worker (see `server::tcp`).
    pub stream_frame_cap: usize,
    /// Deadline applied to every request that does not set its own
    /// [`Request::timeout_ms`] (the `--default-deadline-ms` flag).
    /// `0` disables the default: such requests may run indefinitely.
    pub default_deadline_ms: u64,
    /// Bound on the shared admission queue (the `--max-queue-depth`
    /// flag). A submission arriving at a full queue is **shed**: its
    /// reply fires immediately with `error: "overloaded"` (counted by
    /// [`ServerMetrics::shed`]) instead of the queue growing without
    /// bound. `0` leaves the queue unbounded.
    pub max_queue_depth: usize,
    /// Per-connection idle read timeout for the TCP front-end (the
    /// `--idle-timeout-ms` flag): a connection with no in-flight
    /// requests that stays silent this long is closed, so half-open
    /// sockets stop pinning reader/writer threads. `0` disables it.
    pub idle_timeout_ms: u64,
    /// Speculative decoding mode (the `--spec-decode` flag; defaults to
    /// the `SALR_SPEC` env override so the CI matrix can exercise
    /// speculation suite-wide). Verification is greedy-exact, so every
    /// mode produces byte-identical output — the choice is a throughput
    /// knob, never a correctness one.
    pub spec_decode: SpecMode,
    /// Maximum tokens drafted per sequence per scheduler iteration (the
    /// `--spec-k` flag; clamped per sequence to its remaining budget and
    /// KV headroom). Ignored when [`BatchPolicy::spec_decode`] is `Off`.
    pub spec_k: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // Cache knobs inherit the SALR_PREFIX_CACHE / SALR_KV_BLOCK env
        // overrides, so the CI matrix can force the prefix cache on or
        // off across the whole suite without touching call sites.
        let cache = KvCacheConfig::env_default();
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            num_threads: 0,
            engine_workers: 1,
            prefill_chunk: 64,
            kv_block_size: cache.block_size,
            prefix_cache: cache.prefix_cache,
            stream_frame_cap: 1024,
            default_deadline_ms: 0,
            max_queue_depth: 0,
            idle_timeout_ms: 0,
            spec_decode: SpecMode::env_default(),
            spec_k: 4,
        }
    }
}

/// Aggregate serving metrics. Everything here is **lock-free**: counters
/// and gauges are relaxed atomics, latencies go into fixed-bucket log2
/// [`Hist`]ograms (allocation-free at record time, mergeable). The
/// heartbeat thread probes `{"cmd":"metrics"}` every `--heartbeat-ms`,
/// so a metrics snapshot must never contend with the serving hot path —
/// the old `Mutex<Vec<u64>>` latency log (cloned and sorted per probe)
/// is exactly what this replaces.
#[derive(Default)]
pub struct ServerMetrics {
    /// Completed requests.
    pub requests: AtomicU64,
    /// Generated tokens across all requests.
    pub tokens_out: AtomicU64,
    /// Decode iterations executed across all engine workers.
    pub decode_steps: AtomicU64,
    /// Sum of batch occupancy over all decode steps (mean occupancy =
    /// `step_slots / decode_steps`).
    pub step_slots: AtomicU64,
    /// Requests admitted into a worker's batch (prefill started).
    pub admitted: AtomicU64,
    /// Requests whose prefill started while their worker already had
    /// fully-prefilled sequences decoding — i.e. they joined a running
    /// batch mid-stream instead of waiting for it to drain. Static
    /// batching keeps this at 0.
    pub admitted_midstream: AtomicU64,
    /// Prefill chunks executed (multiple per request once a prompt is
    /// longer than [`BatchPolicy::prefill_chunk`]).
    pub prefill_chunks: AtomicU64,
    /// Prompt tokens actually run through prefill forwards. With the
    /// prefix cache on, `prefill_tokens + prefix_hit_tokens` equals the
    /// total admitted prompt tokens — the gap is GEMM work skipped.
    pub prefill_tokens: AtomicU64,
    /// Prompt tokens served straight from the radix-tree prefix cache on
    /// admission (their prefill forwards never ran). This admission-time
    /// atomic is the **authoritative aggregate**; the per-worker
    /// [`WorkerMetrics::prefix_hit_tokens`] gauges are advisory snapshots
    /// published once per scheduler iteration and may transiently lag it.
    pub prefix_hit_tokens: AtomicU64,
    /// Waiting requests moved from one worker's claim board to another's
    /// (the work-stealing counter).
    pub stolen: AtomicU64,
    /// Requests rejected with an error reply (over-long prompt, prefill
    /// failure, worker panic) — their KV slots are freed, never leaked.
    pub rejected: AtomicU64,
    /// Requests shed at admission because the queue was at
    /// [`BatchPolicy::max_queue_depth`] (`error: "overloaded"`).
    pub shed: AtomicU64,
    /// Requests retired by a latched [`CancelToken`]
    /// (`error: "cancelled"`).
    pub cancelled: AtomicU64,
    /// Requests retired by an expired deadline (`error: "timeout"`).
    pub timed_out: AtomicU64,
    /// Panicked engine workers replaced by the supervisor (see
    /// [`Batcher::supervised_worker_loop`]).
    pub worker_restarts: AtomicU64,
    /// Tokens proposed by the speculative drafter across all sequences
    /// (0 with `--spec-decode off`). Always `>= accepted_tokens`.
    pub drafted_tokens: AtomicU64,
    /// Drafted tokens that survived exact verification and were emitted.
    /// The per-iteration bonus/correction token is **not** counted here —
    /// `accepted_tokens / drafted_tokens` is the pure draft hit rate.
    pub accepted_tokens: AtomicU64,
    /// Verify steps in which at least one drafted token was rejected
    /// (the KV chain rolled back past speculative rows).
    pub spec_rollbacks: AtomicU64,
    /// Highest batch occupancy any worker reached.
    pub max_occupancy: AtomicU64,
    /// Queue wait per completed request (enqueue → prefill start), µs.
    pub queue_wait: Hist,
    /// Time to first token per request (enqueue → first emitted token), µs.
    pub ttft: Hist,
    /// Inter-token latency: gap between consecutive emitted tokens of one
    /// sequence, µs. Speculative decode emits accepted runs back-to-back,
    /// which shows up here as a bimodal shape — that is the point.
    pub per_token: Hist,
    /// End-to-end latency per completed request (enqueue → reply), µs.
    pub e2e: Hist,
    /// First-admission stamp on the [`trace::now_us`] clock (0 = never
    /// started) — the lock-free replacement for the old
    /// `Mutex<Option<Instant>>`.
    started_us: AtomicU64,
}

impl ServerMetrics {
    /// Record a completed request.
    pub fn record(&self, resp: &Response) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(resp.tokens as u64, Ordering::Relaxed);
        self.queue_wait.record((resp.queue_ms * 1000.0) as u64);
        self.e2e
            .record(((resp.queue_ms + resp.compute_ms) * 1000.0) as u64);
    }

    /// Record one decode iteration over `occupancy` live sequences.
    pub fn record_step(&self, occupancy: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.step_slots.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.max_occupancy.fetch_max(occupancy as u64, Ordering::Relaxed);
    }

    fn mark_started(&self) {
        // CAS from the 0 sentinel; `.max(1)` keeps a first admission in
        // the epoch's first microsecond from reading as "never started".
        let _ = self.started_us.compare_exchange(
            0,
            trace::now_us().max(1),
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// Generated tokens per second since the first admission.
    pub fn tokens_per_sec(&self) -> f64 {
        let t0 = self.started_us.load(Ordering::Relaxed);
        if t0 == 0 {
            return 0.0;
        }
        let elapsed_s = trace::now_us().saturating_sub(t0) as f64 / 1e6;
        self.tokens_out.load(Ordering::Relaxed) as f64 / elapsed_s.max(1e-9)
    }

    /// End-to-end latency percentiles in milliseconds: (p50, p90, p99).
    /// Read from the log2 histogram, so each value is the upper bound of
    /// the bucket the true percentile falls in (≤ 2x; see [`Hist`]).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        (
            self.e2e.percentile(0.5) / 1000.0,
            self.e2e.percentile(0.9) / 1000.0,
            self.e2e.percentile(0.99) / 1000.0,
        )
    }

    /// Mean decode-batch occupancy: live sequences per decode step,
    /// averaged over every step any worker ran.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed).max(1);
        self.step_slots.load(Ordering::Relaxed) as f64 / steps as f64
    }
}

/// Per-worker counters, exposed through [`Batcher::worker_metrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerMetrics {
    /// Decode iterations this worker executed.
    pub steps: u64,
    /// Tokens this worker generated.
    pub tokens: u64,
    /// Requests this worker completed.
    pub retired: u64,
    /// Prompt tokens this worker served from its prefix cache.
    pub prefix_hit_tokens: u64,
    /// KV blocks currently referenced in this worker's pool (live chains
    /// plus retained cache chains) — a gauge, sampled every iteration.
    pub cache_blocks_in_use: u64,
    /// KV slots currently occupied by live sequences — a gauge, sampled
    /// every iteration; returns to 0 whenever the worker drains, however
    /// its sequences exited (retired, cancelled, timed out, panic-failed).
    pub slots_in_use: u64,
}

/// Atomic backing store for one worker's [`WorkerMetrics`]: the worker
/// publishes with relaxed stores once per scheduler iteration, the
/// heartbeat path reads with relaxed loads — no lock on either side
/// (the old storage was a `Mutex<Vec<WorkerMetrics>>` locked per probe
/// *and* per iteration). Fields transiently disagree mid-publish; each
/// is individually coherent, which is all a gauge snapshot promises.
#[derive(Default)]
struct WorkerGauges {
    steps: AtomicU64,
    tokens: AtomicU64,
    retired: AtomicU64,
    prefix_hit_tokens: AtomicU64,
    cache_blocks_in_use: AtomicU64,
    slots_in_use: AtomicU64,
}

impl WorkerGauges {
    fn store(&self, m: &WorkerMetrics) {
        self.steps.store(m.steps, Ordering::Relaxed);
        self.tokens.store(m.tokens, Ordering::Relaxed);
        self.retired.store(m.retired, Ordering::Relaxed);
        self.prefix_hit_tokens
            .store(m.prefix_hit_tokens, Ordering::Relaxed);
        self.cache_blocks_in_use
            .store(m.cache_blocks_in_use, Ordering::Relaxed);
        self.slots_in_use.store(m.slots_in_use, Ordering::Relaxed);
    }

    fn load(&self) -> WorkerMetrics {
        WorkerMetrics {
            steps: self.steps.load(Ordering::Relaxed),
            tokens: self.tokens.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
            prefix_hit_tokens: self.prefix_hit_tokens.load(Ordering::Relaxed),
            cache_blocks_in_use: self.cache_blocks_in_use.load(Ordering::Relaxed),
            slots_in_use: self.slots_in_use.load(Ordering::Relaxed),
        }
    }
}

/// Reply callback: invoked exactly once with the finished [`Response`].
/// Boxed so the TCP front-end, blocking callers and benches can each
/// route completions their own way.
pub type ReplyFn = Box<dyn FnOnce(Response) + Send>;

/// Stream callback: invoked with each generated token's text delta, in
/// order, as it is produced. Deltas concatenate **exactly** to the final
/// [`Response::text`]: an incomplete multi-byte UTF-8 sequence is held
/// back until its continuation bytes arrive (or the sequence retires),
/// and invalid sequences are replaced with U+FFFD, mirroring the lossy
/// decode the final text uses.
pub type StreamFn = Box<dyn FnMut(&str) + Send>;

struct Pending {
    req: Request,
    enqueued: Instant,
    /// Absolute deadline resolved at submission (request override or
    /// policy default); `None` = no deadline.
    deadline: Option<Instant>,
    cancel: Option<CancelToken>,
    reply: ReplyFn,
    stream: Option<StreamFn>,
}

impl Pending {
    fn new(
        req: Request,
        reply: ReplyFn,
        stream: Option<StreamFn>,
        policy: &BatchPolicy,
    ) -> Pending {
        let enqueued = Instant::now();
        let timeout_ms = req.timeout_ms.or(if policy.default_deadline_ms > 0 {
            Some(policy.default_deadline_ms)
        } else {
            None
        });
        // checked_add: an absurdly large timeout saturates to "no
        // deadline" instead of panicking on Instant overflow.
        let deadline = timeout_ms.and_then(|ms| enqueued.checked_add(Duration::from_millis(ms)));
        let cancel = req.cancel.clone();
        Pending {
            req,
            enqueued,
            deadline,
            cancel,
            reply,
            stream,
        }
    }

    /// `Some("cancelled" | "timeout")` if this waiting request must not
    /// start (checked when a worker pops it off a claim board).
    fn failed(&self, now: Instant) -> Option<&'static str> {
        failure_kind(&self.cancel, self.deadline, now)
    }
}

/// The shared cancel-before-deadline precedence used at both check
/// points (admission and live-sequence reaping).
fn failure_kind(
    cancel: &Option<CancelToken>,
    deadline: Option<Instant>,
    now: Instant,
) -> Option<&'static str> {
    if cancel.as_ref().is_some_and(CancelToken::is_cancelled) {
        Some("cancelled")
    } else if deadline.is_some_and(|d| now >= d) {
        Some("timeout")
    } else {
        None
    }
}

/// A sequence occupying a KV slot in one worker's decode batch.
struct LiveSeq {
    slot: usize,
    id: u64,
    /// The request's end-to-end trace id ([`Request::trace`]).
    trace: u64,
    reply: ReplyFn,
    stream: Option<StreamFn>,
    enqueued: Instant,
    admitted: Instant,
    /// When this sequence last emitted a token (= `admitted` until the
    /// first one); the inter-token histogram measures gaps against it.
    last_token: Instant,
    deadline: Option<Instant>,
    cancel: Option<CancelToken>,
    /// Tokenized prompt; `prefilled` counts how many of these are already
    /// in the KV cache. The sequence decodes once `prefilled == len`.
    prompt: Vec<i32>,
    prefilled: usize,
    current: i32,
    out: Vec<i32>,
    /// Output bytes not yet emitted as stream deltas (at most one
    /// incomplete UTF-8 sequence, ≤ 3 bytes, between emissions).
    pending: Vec<u8>,
    budget: usize,
}

impl LiveSeq {
    fn prefill_done(&self) -> bool {
        self.prefilled >= self.prompt.len()
    }

    /// Record a generated token: time-to-first-token or inter-token gap
    /// into the latency histograms (always on — two relaxed `fetch_add`s,
    /// no lock, no allocation), then append and stream it.
    fn emit_token(&mut self, tok: i32, metrics: &ServerMetrics) {
        let now = Instant::now();
        if self.out.is_empty() {
            metrics
                .ttft
                .record(now.saturating_duration_since(self.enqueued).as_micros() as u64);
        } else {
            metrics
                .per_token
                .record(now.saturating_duration_since(self.last_token).as_micros() as u64);
        }
        self.last_token = now;
        self.out.push(tok);
        self.stream_token(tok);
    }

    /// Record a newly generated token and stream its text delta, if this
    /// sequence has a stream callback. O(1) amortized per token: only the
    /// new token's byte joins `pending`, and `pending` drains as soon as
    /// it is decodable.
    fn stream_token(&mut self, tok: i32) {
        if self.stream.is_none() {
            return;
        }
        if let Some(b) = token_byte(tok) {
            self.pending.push(b);
        }
        self.drain_pending(false);
    }

    /// Flush the held-back tail on retirement so the concatenated deltas
    /// equal the final lossy-decoded text exactly (a truncated multi-byte
    /// sequence becomes one U+FFFD, just as `detokenize` renders it).
    fn finish_stream(&mut self) {
        if self.stream.is_some() {
            self.drain_pending(true);
        }
    }

    /// Incremental `from_utf8_lossy`: emit every decodable prefix of
    /// `pending`, replace invalid sequences with U+FFFD, and (unless
    /// `flush`) hold back an incomplete trailing sequence until its
    /// continuation bytes arrive.
    fn drain_pending(&mut self, flush: bool) {
        let Some(cb) = self.stream.as_mut() else {
            return;
        };
        loop {
            if self.pending.is_empty() {
                return;
            }
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    cb(s);
                    self.pending.clear();
                    return;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    if valid > 0 {
                        // SAFETY-free: the prefix is valid per valid_up_to.
                        cb(std::str::from_utf8(&self.pending[..valid]).unwrap());
                    }
                    match e.error_len() {
                        Some(bad) => {
                            // A maximal invalid subpart: replace it, keep
                            // decoding what follows (same substitution
                            // from_utf8_lossy applies).
                            cb("\u{FFFD}");
                            self.pending.drain(..valid + bad);
                        }
                        None => {
                            // Incomplete trailing sequence: wait for its
                            // continuation — or, on the final flush,
                            // render it as the one U+FFFD the lossy final
                            // decode will show.
                            self.pending.drain(..valid);
                            if flush {
                                cb("\u{FFFD}");
                                self.pending.clear();
                            }
                            return;
                        }
                    }
                }
            }
        }
    }
}

/// One engine worker's owned serving state: its private KV pool, its
/// live decode batch and its local counters. Owned by the supervisor
/// frame, **outside** the `catch_unwind` boundary, so a panicking worker
/// loop leaves it reachable for cleanup and the pool (with its retained
/// prefix-cache chains) survives the respawn.
struct WorkerState {
    kv: KvSlotPool,
    live: Vec<LiveSeq>,
    local: WorkerMetrics,
}

/// Best-effort text of a caught panic payload (panics raised by `panic!`
/// carry `&str` or `String`; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The admission queue plus the shared serving state; engine workers are
/// spawned on top with [`spawn_engine_workers`] (or run inline via
/// [`Batcher::worker_loop`]).
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    policy: BatchPolicy,
    /// Per-worker claim boards: requests popped from the queue but whose
    /// prefill has not started. No KV state yet, so an idle worker can
    /// steal from the back of another worker's board at zero cost.
    boards: Mutex<Vec<VecDeque<Pending>>>,
    /// Aggregate metrics across all engine workers.
    pub metrics: ServerMetrics,
    /// One atomic gauge block per worker id, preallocated for the
    /// policy's worker count so publish/read never locks. A worker id
    /// past the preallocation (only reachable by driving
    /// [`Batcher::worker_loop`] by hand with an out-of-range id) is
    /// served but not gauge-tracked.
    worker_gauges: Vec<WorkerGauges>,
    shutdown: AtomicBool,
    /// Armed fault-injection plan (`SALR_FAULT`, or explicit in tests);
    /// `None` in production — the checks cost one branch per op.
    fault: Option<FaultPlan>,
}

impl Batcher {
    /// A batcher with no workers yet (see [`spawn_engine_workers`]).
    /// Arms the fault-injection plan from `SALR_FAULT` when that env var
    /// is set (CI's fault leg); see [`Batcher::with_fault`].
    pub fn new(policy: BatchPolicy) -> Arc<Batcher> {
        Batcher::with_fault(policy, FaultPlan::from_env())
    }

    /// [`Batcher::new`] with an explicit fault-injection plan — the
    /// deterministic-test entry point (env vars race across parallel
    /// tests; an explicit plan cannot). Pass `None` to disable injection
    /// regardless of `SALR_FAULT`.
    pub fn with_fault(policy: BatchPolicy, fault: Option<FaultPlan>) -> Arc<Batcher> {
        let workers = policy.engine_workers.max(1);
        Arc::new(Batcher {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            policy,
            boards: Mutex::new((0..workers).map(|_| VecDeque::new()).collect()),
            metrics: ServerMetrics::default(),
            worker_gauges: (0..workers).map(|_| WorkerGauges::default()).collect(),
            shutdown: AtomicBool::new(false),
            fault,
        })
    }

    /// Execute the armed fault plan's action if `op` on `worker` is its
    /// trigger point: `panic` faults unwind this worker thread (the
    /// supervisor catches it), `delay` faults stall it in place.
    fn fault_point(&self, op: FaultOp, worker: usize) {
        let Some(plan) = &self.fault else { return };
        match plan.check(op, worker) {
            Some(FaultAction::Panic(msg)) => panic!("{msg}"),
            Some(FaultAction::Delay(d)) => {
                log::warn!("injected fault: stalling worker {worker} for {d:?}");
                std::thread::sleep(d);
            }
            None => {}
        }
    }

    /// The policy this batcher schedules under.
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Submit a request; blocks the calling thread until its response
    /// arrives (other requests keep flowing meanwhile). Every failure —
    /// shutdown, shedding, deadline expiry, cancellation, a worker panic
    /// — comes back as [`Response::error`], never as a panic in the
    /// caller.
    pub fn submit(&self, req: Request) -> Response {
        let id = req.id;
        let enqueued = Instant::now();
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit_with(
            req,
            Box::new(move |resp| {
                let _ = tx.send(resp);
            }),
        );
        // Every path fires the reply exactly once (accepted, shed, shut
        // down, failed). The recv-error arm is pure defense: it can only
        // trigger if a queued reply callback is dropped un-fired, e.g.
        // by `drain_abandoned` racing a shutdown.
        rx.recv().unwrap_or_else(|_| {
            error_response(id, enqueued, "request dropped without a reply".into())
        })
    }

    /// Submit a request with an explicit completion callback — the
    /// non-blocking form the TCP front-end uses so one connection can
    /// have many requests in flight (responses return out of order).
    /// `reply` fires **exactly once** on every path; if the request is
    /// not accepted (shutdown already requested, or the bounded queue
    /// shed it) the reply fires immediately with the error and this
    /// returns `false`.
    pub fn submit_with(&self, req: Request, reply: ReplyFn) -> bool {
        self.enqueue(req, reply, None)
    }

    /// [`Batcher::submit_with`] plus a per-token stream callback: `stream`
    /// fires with each generated token's text delta as the engine produces
    /// it, then `reply` fires once with the complete [`Response`].
    pub fn submit_stream_with(&self, req: Request, stream: StreamFn, reply: ReplyFn) -> bool {
        self.enqueue(req, reply, Some(stream))
    }

    fn enqueue(&self, req: Request, reply: ReplyFn, stream: Option<StreamFn>) -> bool {
        let pend = Pending::new(req, reply, stream, &self.policy);
        {
            // The flag is checked under the queue lock — the same lock
            // under which workers make their final empty-queue exit
            // decision — so a request can never slip in between the
            // workers' last drain and their exit. Rejection replies fire
            // outside the lock: a reply callback may itself re-enter the
            // batcher.
            let mut q = self.queue.lock().unwrap();
            if self.shutdown.load(Ordering::SeqCst) {
                drop(q);
                // "shutting down" is wire-visible contract: the router
                // retries a request shed with exactly this error on
                // another backend when it has not streamed yet.
                (pend.reply)(error_response(
                    pend.req.id,
                    pend.enqueued,
                    "shutting down".into(),
                ));
                return false;
            }
            let depth = self.policy.max_queue_depth;
            if depth > 0 && q.len() >= depth {
                drop(q);
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                (pend.reply)(error_response(pend.req.id, pend.enqueued, "overloaded".into()));
                return false;
            }
            q.push_back(pend);
        }
        self.cv.notify_all();
        true
    }

    /// Bump the counter matching a `"cancelled"` / `"timeout"` failure.
    fn count_failure(&self, kind: &str) {
        let counter = if kind == "cancelled" {
            &self.metrics.cancelled
        } else {
            &self.metrics.timed_out
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Ask every worker loop to exit. Workers first drain what is already
    /// queued or claimed (every accepted request's reply callback still
    /// fires) and finish their live sequences; *new* submissions are
    /// rejected from this point on (see [`Batcher::submit_with`]).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Drop any requests still queued or still on a claim board — call
    /// only after the worker threads have exited, to release the reply
    /// callbacks (and whatever channels they hold) of requests that raced
    /// past [`Batcher::shutdown`] into the queue. Returns how many were
    /// dropped.
    pub fn drain_abandoned(&self) -> usize {
        let mut n = {
            let mut q = self.queue.lock().unwrap();
            let n = q.len();
            q.clear();
            n
        };
        let mut boards = self.boards.lock().unwrap();
        for b in boards.iter_mut() {
            n += b.len();
            b.clear();
        }
        n
    }

    /// Snapshot of per-worker counters, indexed by worker id. Lock-free:
    /// each gauge is a relaxed atomic load (this runs on every heartbeat
    /// probe, concurrent with the serving hot path).
    pub fn worker_metrics(&self) -> Vec<WorkerMetrics> {
        self.worker_gauges.iter().map(WorkerGauges::load).collect()
    }

    /// Requests admitted but not yet scheduled: the shared queue plus
    /// every worker's claim board. This is the admission-depth half of
    /// the load signal the router tier balances on (the other half is
    /// the `slots_in_use` gauge), reported as `queue_depth` in the
    /// wire metrics reply.
    pub fn queue_depth(&self) -> usize {
        let queued = self.queue.lock().unwrap().len();
        let boarded: usize = self.boards.lock().unwrap().iter().map(|b| b.len()).sum();
        queued + boarded
    }

    /// Pop up to `room` waiting requests off the shared queue; if the
    /// queue is empty and `may_steal` (the worker could start a prefill
    /// right now), try to **steal** unstarted claims from another
    /// worker's board. When the worker has nothing at all to do
    /// (`have_work == false`) this blocks until a request arrives or
    /// shutdown; when sequences are mid-decode or mid-prefill it never
    /// waits — the iteration loop must keep stepping.
    fn admit_up_to(
        &self,
        room: usize,
        have_work: bool,
        may_steal: bool,
        me: usize,
    ) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                // Let the caller finish its live sequences and drain its
                // own board, then exit.
                return if have_work { Some(Vec::new()) } else { None };
            }
            if !q.is_empty() {
                let n = q.len().min(room);
                return Some(q.drain(..n).collect());
            }
            if may_steal && room > 0 {
                let stolen = self.steal(me, room);
                if !stolen.is_empty() {
                    self.metrics
                        .stolen
                        .fetch_add(stolen.len() as u64, Ordering::Relaxed);
                    return Some(stolen);
                }
            }
            if have_work {
                return Some(Vec::new());
            }
            let wait = self.policy.max_wait.max(Duration::from_millis(1));
            q = self.cv.wait_timeout(q, wait).unwrap().0;
        }
    }

    /// Steal up to `room` unstarted claims from the back of the longest
    /// other board (lock order: queue → boards, matching `admit_up_to`).
    fn steal(&self, me: usize, room: usize) -> Vec<Pending> {
        let mut boards = self.boards.lock().unwrap();
        let victim = boards
            .iter()
            .enumerate()
            .filter(|(w, b)| *w != me && !b.is_empty())
            .max_by_key(|(_, b)| b.len())
            .map(|(w, _)| w);
        let Some(v) = victim else {
            return Vec::new();
        };
        let take = boards[v].len().min(room);
        let at = boards[v].len() - take;
        boards[v].split_off(at).into()
    }

    fn board_len(&self, worker: usize) -> usize {
        self.boards
            .lock()
            .unwrap()
            .get(worker)
            .map(|b| b.len())
            .unwrap_or(0)
    }

    fn push_board(&self, worker: usize, items: Vec<Pending>) {
        if items.is_empty() {
            return;
        }
        {
            let mut boards = self.boards.lock().unwrap();
            if boards.len() <= worker {
                boards.resize_with(worker + 1, VecDeque::new);
            }
            boards[worker].extend(items);
        }
        // Idle peers wake to steal if we can't start these soon.
        self.cv.notify_all();
    }

    fn pop_board(&self, worker: usize) -> Option<Pending> {
        self.boards.lock().unwrap().get_mut(worker)?.pop_front()
    }

    /// Make `worker`'s claim-board slot exist (gauges are preallocated).
    fn register_worker(&self, worker: usize) {
        let mut boards = self.boards.lock().unwrap();
        if boards.len() <= worker {
            boards.resize_with(worker + 1, VecDeque::new);
        }
    }

    /// One worker's owned serving state. Held **outside**
    /// [`Batcher::worker_loop_inner`] so the supervisor can clean up
    /// in-flight sequences (and keep the KV pool, with its retained
    /// prefix-cache chains, alive) across a panic and respawn.
    fn new_worker_state(&self, engine: &Engine) -> WorkerState {
        let nslots = self.policy.max_batch.max(1);
        // Each worker owns a private paged pool (and prefix cache): KV
        // rows are written per token per layer, far too hot to share
        // across workers under a lock. Requests sharing a head therefore
        // reuse blocks when they land on the same worker.
        let kv = engine.new_slot_pool_with(
            nslots,
            KvCacheConfig {
                block_size: self.policy.kv_block_size.max(1),
                prefix_cache: self.policy.prefix_cache,
                // Retention headroom stays an env knob (SALR_KV_EXTRA).
                ..KvCacheConfig::env_default()
            },
        );
        WorkerState {
            kv,
            live: Vec::new(),
            local: WorkerMetrics::default(),
        }
    }

    /// Publish a worker's per-iteration gauges and counters (lock-free
    /// relaxed stores into the worker's preallocated gauge block).
    fn publish_worker_metrics(&self, worker: usize, state: &WorkerState) {
        let mut local = state.local;
        local.prefix_hit_tokens = state.kv.prefix_hit_tokens();
        local.cache_blocks_in_use = state.kv.blocks_in_use() as u64;
        local.slots_in_use = state.live.len() as u64;
        if let Some(g) = self.worker_gauges.get(worker) {
            g.store(&local);
        }
    }

    /// The continuous-batching engine worker loop, **unsupervised**: a
    /// panic unwinds the calling thread. Runs until shutdown; `worker` is
    /// this loop's id for per-worker metrics and its claim board. Call on
    /// a dedicated thread with this worker's engine fork — or use
    /// [`spawn_engine_workers`], which runs the supervised form.
    pub fn worker_loop(&self, engine: &Engine, worker: usize) {
        self.register_worker(worker);
        let mut state = self.new_worker_state(engine);
        self.worker_loop_inner(engine, worker, &mut state);
        self.publish_worker_metrics(worker, &state);
    }

    /// [`Batcher::worker_loop`] under a panic supervisor: the loop runs
    /// in `catch_unwind`, and on a panic (an engine bug, or an injected
    /// `SALR_FAULT`) the supervisor (1) fails every in-flight sequence
    /// with an error reply — nothing retires silently — freeing each KV
    /// chain exactly, (2) bumps [`ServerMetrics::worker_restarts`], and
    /// (3) re-enters the loop on a fresh [`Engine::fork`] of `engine`,
    /// same queue, same claim board, same KV pool (retained prefix-cache
    /// chains survive the respawn). One worker's crash never poisons its
    /// siblings or the listener. Returns when shutdown drains normally.
    pub fn supervised_worker_loop(&self, engine: &Engine, worker: usize) {
        self.register_worker(worker);
        let mut state = self.new_worker_state(engine);
        loop {
            let eng = engine.fork();
            let run = catch_unwind(AssertUnwindSafe(|| {
                self.worker_loop_inner(&eng, worker, &mut state)
            }));
            match run {
                Ok(()) => break, // clean shutdown drain
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    self.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                    log::error!(
                        "engine worker {worker} panicked ({msg}); failing {} in-flight \
                         request(s) and respawning",
                        state.live.len()
                    );
                    for seq in std::mem::take(&mut state.live) {
                        // A panic can land mid-forward, leaving the slot's
                        // per-layer lengths inconsistent; free() releases
                        // whatever the chain holds, exactly.
                        state.kv.free(seq.slot);
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        (seq.reply)(error_response(
                            seq.id,
                            seq.enqueued,
                            format!("worker panicked mid-request: {msg}"),
                        ));
                    }
                    self.publish_worker_metrics(worker, &state);
                }
            }
        }
        self.publish_worker_metrics(worker, &state);
    }

    fn worker_loop_inner(&self, engine: &Engine, worker: usize, state: &mut WorkerState) {
        let max_ctx = engine.weights.cfg.max_seq_len;
        let nslots = self.policy.max_batch.max(1);
        let chunk = self.policy.prefill_chunk;
        // One drafter instance per loop entry (`None` = non-speculative).
        let drafter = self.policy.spec_decode.drafter();
        let WorkerState { kv, live, local } = state;

        loop {
            // --- 1. admit: claim waiting requests (or steal) ---
            let claimed = self.board_len(worker);
            let room = nslots.saturating_sub(live.len() + claimed);
            let have_work = !live.is_empty() || claimed > 0;
            // Steal only when this worker could start the stolen claim on
            // this very iteration (no local backlog, nothing mid-prefill)
            // — otherwise claims would ping-pong between busy boards.
            let may_steal = claimed == 0
                && live.len() < nslots
                && live.iter().all(LiveSeq::prefill_done);
            let admitted = match self.admit_up_to(room, have_work, may_steal, worker) {
                Some(batch) => batch,
                None => break, // shutdown while idle
            };
            self.push_board(worker, admitted);

            // --- 1b. reap: the step boundary where cancellation and
            // deadline expiry take effect for live sequences ---
            self.reap_expired(live, kv);

            // --- 2. prefill: at most one `chunk`-sized bite this round ---
            self.prefill_one_chunk(engine, worker, live, kv, max_ctx, chunk);
            // Retire sequences already at budget (single-token requests
            // complete on their final prefill chunk alone).
            self.retire_finished(live, kv, local);

            // --- 3. one decode iteration over the fully-prefilled batch ---
            let ready: Vec<usize> = (0..live.len())
                .filter(|&i| live[i].prefill_done())
                .collect();
            if !ready.is_empty() {
                self.fault_point(FaultOp::DecodeStep, worker);
                self.metrics.record_step(ready.len());
                local.steps += 1;
                if let Some(drafter) = &drafter {
                    // Speculative iteration: draft + verify per sequence.
                    // Each verify emits `accepted + 1` tokens, so a good
                    // draft advances a sequence several positions in one
                    // forward; a bad one degenerates to plain decode.
                    for &i in &ready {
                        let seq = &mut live[i];
                        // Clamp so the `k+1`-row verify forward can never
                        // overrun the token budget (emitted ≤ k+1) or the
                        // KV slot (appends ≤ k+1 rows before rollback).
                        // `out.len() < budget` here: budget-reached
                        // sequences retired before this loop.
                        let k = self
                            .policy
                            .spec_k
                            .min(seq.budget.saturating_sub(seq.out.len() + 1))
                            .min(kv.remaining(seq.slot).saturating_sub(1));
                        let (tid, slot, cur) = (seq.trace, seq.slot, seq.current);
                        let draft = if k == 0 {
                            Vec::new()
                        } else {
                            // History = prompt ++ out; `current` (the
                            // token about to be fed) is its last element.
                            let mut hist =
                                Vec::with_capacity(seq.prompt.len() + seq.out.len());
                            hist.extend_from_slice(&seq.prompt);
                            hist.extend_from_slice(&seq.out);
                            // `with_trace`: kernel spans the draft forward
                            // records (self-drafting runs base-only GEMMs)
                            // inherit this sequence's trace id.
                            let t0 = trace::now_us();
                            let mut d =
                                trace::with_trace(tid, || drafter.draft(engine, kv, slot, &hist, k));
                            d.truncate(k); // defensive: the clamp is load-bearing
                            trace::record_span(TraceKind::SpecDraft, tid, t0, d.len() as u64);
                            d
                        };
                        // Fault point between draft and verify: the draft
                        // is computed (self-drafting has appended and
                        // rolled back its base-only KV rows) but nothing
                        // is verified — a panic here is the worst spot
                        // for speculative KV accounting.
                        self.fault_point(FaultOp::VerifyStep, worker);
                        let t0 = trace::now_us();
                        let v =
                            trace::with_trace(tid, || engine.decode_verify(cur, &draft, slot, kv));
                        trace::record_span(TraceKind::SpecVerify, tid, t0, v.accepted as u64);
                        self.metrics
                            .drafted_tokens
                            .fetch_add(draft.len() as u64, Ordering::Relaxed);
                        self.metrics
                            .accepted_tokens
                            .fetch_add(v.accepted as u64, Ordering::Relaxed);
                        if v.accepted < draft.len() {
                            self.metrics.spec_rollbacks.fetch_add(1, Ordering::Relaxed);
                        }
                        for &tok in draft[..v.accepted].iter().chain([v.next].iter()) {
                            seq.emit_token(tok, &self.metrics);
                        }
                        seq.current = v.next;
                    }
                } else {
                    let current: Vec<i32> = ready.iter().map(|&i| live[i].current).collect();
                    let slots: Vec<usize> = ready.iter().map(|&i| live[i].slot).collect();
                    // The batched forward belongs to every ready sequence
                    // at once, so it runs under trace id 0 (kernel spans
                    // attach to the step, not one request) and the step
                    // interval is then recorded once per ready sequence —
                    // each request's tree shows every decode step it was
                    // part of, stamped with the batch occupancy.
                    let t0 = trace::now_us();
                    let next = engine.decode_step(&current, &slots, kv);
                    if trace::enabled() {
                        let t1 = trace::now_us();
                        for &i in &ready {
                            trace::record_span_at(
                                TraceKind::DecodeStep,
                                live[i].trace,
                                t0,
                                t1,
                                ready.len() as u64,
                            );
                        }
                    }
                    for (j, &i) in ready.iter().enumerate() {
                        let seq = &mut live[i];
                        seq.current = next[j];
                        seq.emit_token(next[j], &self.metrics);
                    }
                }
                // Retire immediately after the step, so a finished
                // request's reply fires before (and its latency never
                // absorbs) the next round's prefill chunk — and so the
                // freed slots count toward the next round's room.
                self.retire_finished(live, kv, local);
            }
            // Publish per-worker counters (six relaxed stores — no lock
            // for the heartbeat's reader to contend on).
            local.prefix_hit_tokens = kv.prefix_hit_tokens();
            local.cache_blocks_in_use = kv.blocks_in_use() as u64;
            local.slots_in_use = live.len() as u64;
            if let Some(g) = self.worker_gauges.get(worker) {
                g.store(local);
            }
        }
    }

    /// Retire every live sequence whose [`CancelToken`] has latched or
    /// whose deadline has passed: free its KV chain (exactly — shared
    /// prefix blocks refcount back to baseline), fire its reply with
    /// `error: "cancelled"` / `"timeout"`, and discard partial output.
    /// Called once per scheduler iteration — the "next step boundary"
    /// the [`Request`] docs promise.
    fn reap_expired(&self, live: &mut Vec<LiveSeq>, kv: &mut KvSlotPool) {
        let now = Instant::now();
        let mut i = 0;
        while i < live.len() {
            match failure_kind(&live[i].cancel, live[i].deadline, now) {
                Some(kind) => {
                    let seq = live.swap_remove(i);
                    kv.free(seq.slot);
                    self.count_failure(kind);
                    (seq.reply)(error_response(seq.id, seq.enqueued, kind.into()));
                }
                None => i += 1,
            }
        }
    }

    /// Run one prefill chunk: continue the oldest mid-prefill sequence,
    /// or start the next claim off this worker's board if nothing is
    /// mid-prefill and a KV slot is free. Rejections (over-long prompt,
    /// engine error) free the slot and fire an error reply.
    fn prefill_one_chunk(
        &self,
        engine: &Engine,
        worker: usize,
        live: &mut Vec<LiveSeq>,
        kv: &mut KvSlotPool,
        max_ctx: usize,
        chunk: usize,
    ) {
        let mut target = live.iter().position(|s| !s.prefill_done());
        if target.is_none() && live.len() < kv.capacity() {
            while let Some(p) = self.pop_board(worker) {
                // Admission-time failure check: a request cancelled or
                // expired while it waited never allocates a slot.
                if let Some(kind) = p.failed(Instant::now()) {
                    self.count_failure(kind);
                    (p.reply)(error_response(p.req.id, p.enqueued, kind.into()));
                    continue;
                }
                match prepare_prompt(&p.req, max_ctx) {
                    Err(msg) => {
                        // Rejected before any KV state exists: error reply,
                        // no slot consumed, try the next claim.
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        (p.reply)(error_response(p.req.id, p.enqueued, msg));
                        continue;
                    }
                    Ok((toks, budget)) => {
                        self.metrics.mark_started();
                        self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                        // Mid-stream = joining a batch that already has
                        // sequences decoding (not merely co-prefilling).
                        if live.iter().any(|s| s.prefill_done()) {
                            self.metrics
                                .admitted_midstream
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        let slot = kv.alloc().expect("admission respects free slots");
                        // Prefix-cache admission: attach the cached head
                        // of the prompt (shared blocks, COW at a mid-block
                        // divergence). The attached tokens' prefill
                        // forwards are skipped outright — `prefilled`
                        // starts past them.
                        let hit = kv.attach_prefix(slot, &toks);
                        if hit > 0 {
                            self.metrics
                                .prefix_hit_tokens
                                .fetch_add(hit as u64, Ordering::Relaxed);
                        }
                        if trace::enabled() {
                            let t = trace::now_us();
                            trace::record_span_at(
                                TraceKind::Admit,
                                p.req.trace,
                                t,
                                t,
                                toks.len() as u64,
                            );
                        }
                        let now = Instant::now();
                        live.push(LiveSeq {
                            slot,
                            id: p.req.id,
                            trace: p.req.trace,
                            reply: p.reply,
                            stream: p.stream,
                            enqueued: p.enqueued,
                            admitted: now,
                            last_token: now,
                            deadline: p.deadline,
                            cancel: p.cancel,
                            prompt: toks,
                            prefilled: hit,
                            current: 0,
                            out: Vec::new(),
                            pending: Vec::new(),
                            budget,
                        });
                        target = Some(live.len() - 1);
                        break;
                    }
                }
            }
        }
        let Some(i) = target else {
            return;
        };
        self.fault_point(FaultOp::PrefillChunk, worker);
        let seq = &mut live[i];
        let remaining = seq.prompt.len() - seq.prefilled;
        let take = if chunk == 0 { remaining } else { chunk.min(remaining) };
        let last = seq.prefilled + take == seq.prompt.len();
        // `with_trace`: the chunk's GEMM/pack kernel spans inherit this
        // sequence's trace id on whatever pool thread they run.
        let (tid, slot) = (seq.trace, seq.slot);
        let t0 = trace::now_us();
        let res = trace::with_trace(tid, || {
            engine.prefill_chunk(
                &seq.prompt[seq.prefilled..seq.prefilled + take],
                slot,
                kv,
                last,
            )
        });
        trace::record_span(TraceKind::PrefillChunk, tid, t0, take as u64);
        self.metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        match res {
            Ok(first) => {
                // Counted only on success, so `prefill_tokens +
                // prefix_hit_tokens == admitted prompt tokens` holds even
                // if a chunk is ever rejected mid-prefill.
                self.metrics
                    .prefill_tokens
                    .fetch_add(take as u64, Ordering::Relaxed);
                seq.prefilled += take;
                if let Some(tok) = first {
                    seq.current = tok;
                    seq.emit_token(tok, &self.metrics);
                }
                // The whole prompt is cached now: publish its full blocks
                // to this worker's prefix cache so later requests sharing
                // the head skip these forwards.
                if seq.prefill_done() {
                    kv.register_prefix(seq.slot, &seq.prompt);
                }
            }
            Err(e) => {
                // Defensive: `prepare_prompt` sizes prompts to fit, so
                // this only fires on internal inconsistencies — free the
                // slot (never leak it) and reply with the error.
                let seq = live.swap_remove(i);
                kv.free(seq.slot);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                (seq.reply)(error_response(seq.id, seq.enqueued, format!("{e:#}")));
            }
        }
    }

    /// Retire every live sequence that has reached its token budget:
    /// free its KV slot, record metrics, detokenize and fire its reply.
    fn retire_finished(
        &self,
        live: &mut Vec<LiveSeq>,
        kv: &mut KvSlotPool,
        local: &mut WorkerMetrics,
    ) {
        let mut i = 0;
        while i < live.len() {
            if live[i].prefill_done() && live[i].out.len() >= live[i].budget {
                let mut seq = live.swap_remove(i);
                seq.finish_stream();
                // Radix drafting feeds on generated continuations: at
                // retirement, register the sequence's *whole* chain —
                // prompt plus generated tokens — not just the prompt the
                // prefill path registered. A repeat of the prompt then
                // both attaches the cached head AND drafts the previous
                // completion from the tree's edge labels (greedy decode
                // is deterministic, so those drafts verify fully). Every
                // registered row is a verified full-model row — rejected
                // speculative rows were truncated before ever being
                // registrable. Keyed on mode: other modes keep the exact
                // pre-speculation cache contents.
                if self.policy.spec_decode == SpecMode::Radix && self.policy.prefix_cache {
                    let mut hist =
                        Vec::with_capacity(seq.prompt.len() + seq.out.len() - 1);
                    hist.extend_from_slice(&seq.prompt);
                    hist.extend_from_slice(&seq.out[..seq.out.len() - 1]);
                    kv.register_prefix(seq.slot, &hist);
                }
                kv.free(seq.slot);
                local.retired += 1;
                local.tokens += seq.out.len() as u64;
                if trace::enabled() {
                    let t = trace::now_us();
                    trace::record_span_at(
                        TraceKind::Retire,
                        seq.trace,
                        t,
                        t,
                        seq.out.len() as u64,
                    );
                }
                let resp = Response {
                    id: seq.id,
                    text: detokenize(&seq.out),
                    error: None,
                    queue_ms: (seq.admitted - seq.enqueued).as_secs_f64() * 1000.0,
                    compute_ms: seq.admitted.elapsed().as_secs_f64() * 1000.0,
                    tokens: seq.out.len(),
                };
                self.metrics.record(&resp);
                (seq.reply)(resp);
            } else {
                i += 1;
            }
        }
    }
}

fn error_response(id: u64, enqueued: Instant, msg: String) -> Response {
    Response {
        id,
        text: String::new(),
        error: Some(msg),
        queue_ms: enqueued.elapsed().as_secs_f64() * 1000.0,
        compute_ms: 0.0,
        tokens: 0,
    }
}

/// Tokenize a request's prompt and clamp its generation budget to the
/// model context. A prompt that cannot fit a KV slot alongside its budget
/// is **rejected** (`Err(reason)`) rather than silently truncated or
/// panicking a worker. Returns `(tokens, budget)` with `tokens` non-empty
/// and `budget >= 1`.
fn prepare_prompt(req: &Request, max_ctx: usize) -> Result<(Vec<i32>, usize), String> {
    let mut toks = tokenize(&req.prompt);
    if toks.is_empty() {
        toks.push(b' ' as i32);
    }
    if toks.len() >= max_ctx {
        return Err(format!(
            "prompt too long: {} tokens leaves no room to generate in a {max_ctx}-token context",
            toks.len()
        ));
    }
    let budget = req.max_tokens.clamp(1, max_ctx - toks.len());
    Ok((toks, budget))
}

/// Spawn `engine_workers` (per the batcher's policy) engine worker
/// threads over forks of `engine`, giving each fork a **private** worker
/// pool holding an even share of `num_threads` (0 = all cores) GEMM
/// threads. Each thread runs [`Batcher::supervised_worker_loop`], so a
/// panicking worker fails its in-flight requests with error replies and
/// is respawned in place — the returned join handles complete normally
/// even across worker panics. Call [`Batcher::shutdown`] then join to
/// stop.
pub fn spawn_engine_workers(
    batcher: &Arc<Batcher>,
    engine: Engine,
) -> Vec<std::thread::JoinHandle<()>> {
    use crate::util::pool::{available_threads, WorkerPool};
    let policy = *batcher.policy();
    let workers = policy.engine_workers.max(1);
    let total = if policy.num_threads > 0 {
        policy.num_threads
    } else {
        available_threads()
    };
    let per_worker = (total / workers).max(1);
    let mut handles = Vec::with_capacity(workers);
    for w in 0..workers {
        let mut eng = engine.fork();
        // Private pools (not the global size registry) so each worker's
        // linears — dense, small-m direct sparse *and* the pipelined
        // prefill stages — own disjoint threads end to end
        // (`SalrLayer::forward` threads the pool through every path).
        eng.set_pool(Arc::new(WorkerPool::new(per_worker)));
        let b = batcher.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("salr-engine-{w}"))
                .spawn(move || b.supervised_worker_loop(&eng, w))
                .expect("spawn engine worker"),
        );
    }
    handles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{Backend, Engine, EngineWeights};
    use crate::model::ParamStore;
    use crate::runtime::ModelCfg;
    use crate::util::rng::Rng;

    fn engine() -> Engine {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 96,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 4,
            batch_size: 2,
            ctx_keep: 0.5,
        };
        let mut rng = Rng::new(500);
        let base = ParamStore::init_base(&cfg, &mut rng);
        Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
    }

    #[test]
    fn batcher_serves_concurrent_requests() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            ..Default::default()
        });
        let handles_srv = spawn_engine_workers(&batcher, eng);
        let mut handles = Vec::new();
        for i in 0..6 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                b.submit(Request {
                    id: i,
                    prompt: format!("Q: {i}+1=? A: "),
                    max_tokens: 3,
                    ..Default::default()
                })
            }));
        }
        let mut responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert!(r.error.is_none());
            assert_eq!(r.tokens, 3);
        }
        assert_eq!(batcher.metrics.requests.load(Ordering::Relaxed), 6);
        assert!(batcher.metrics.mean_batch_occupancy() >= 1.0);
        batcher.shutdown();
        for h in handles_srv {
            h.join().unwrap();
        }
    }

    #[test]
    fn deterministic_across_submissions_and_chunk_sizes() {
        // Same prompt must yield the same text whenever it is submitted —
        // and whatever the prefill chunk size, including unchunked.
        let eng = engine();
        let mut texts = Vec::new();
        for chunk in [0usize, 1, 3, 64] {
            let batcher = Batcher::new(BatchPolicy {
                max_batch: 2,
                prefill_chunk: chunk,
                ..Default::default()
            });
            let handles = spawn_engine_workers(&batcher, eng.fork());
            let r1 = batcher.submit(Request {
                id: 1,
                prompt: "Q: 2+2=? A: ".into(),
                max_tokens: 4,
                ..Default::default()
            });
            let r2 = batcher.submit(Request {
                id: 2,
                prompt: "Q: 2+2=? A: ".into(),
                max_tokens: 4,
                ..Default::default()
            });
            assert_eq!(r1.text, r2.text, "chunk={chunk}");
            texts.push(r1.text);
            batcher.shutdown();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert!(
            texts.windows(2).all(|w| w[0] == w[1]),
            "prefill chunk size changed the output bytes: {texts:?}"
        );
    }

    #[test]
    fn midstream_admission_joins_a_live_batch() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            engine_workers: 1,
            prefill_chunk: 4,
            ..Default::default()
        });
        let handles = spawn_engine_workers(&batcher, eng);
        // A long request keeps the single worker's batch live…
        let b1 = batcher.clone();
        let long = std::thread::spawn(move || {
            b1.submit(Request {
                id: 1,
                prompt: "Q: 10+20=? A: ".into(),
                max_tokens: 80,
                ..Default::default()
            })
        });
        // …wait until it is actually decoding, then admit a second one
        // (which prefills in chunks while the first keeps decoding).
        let t0 = Instant::now();
        while batcher.metrics.decode_steps.load(Ordering::Relaxed) < 1 {
            assert!(t0.elapsed() < Duration::from_secs(20), "worker never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        let short = batcher.submit(Request {
            id: 2,
            prompt: "Q: 1+1=? A: ".into(),
            max_tokens: 2,
            ..Default::default()
        });
        assert_eq!(short.tokens, 2);
        let long_resp = long.join().unwrap();
        assert_eq!(long_resp.tokens, 80);
        assert!(
            batcher.metrics.admitted_midstream.load(Ordering::Relaxed) >= 1,
            "second request must join the live batch, not wait for a drain"
        );
        assert!(
            batcher.metrics.max_occupancy.load(Ordering::Relaxed) >= 2,
            "occupancy must grow without the batch draining"
        );
        // Out-of-order completion: the short request finished first.
        assert!(batcher.metrics.requests.load(Ordering::Relaxed) == 2);
        batcher.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stream_deltas_concatenate_to_the_response_text() {
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 2,
            prefill_chunk: 3,
            ..Default::default()
        });
        let handles = spawn_engine_workers(&batcher, eng);
        let deltas = Arc::new(Mutex::new(String::new()));
        let d = deltas.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let accepted = batcher.submit_stream_with(
            Request {
                id: 9,
                prompt: "Q: 3+4=? A: ".into(),
                max_tokens: 6,
                ..Default::default()
            },
            Box::new(move |delta| d.lock().unwrap().push_str(delta)),
            Box::new(move |resp| {
                let _ = tx.send(resp);
            }),
        );
        assert!(accepted);
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, 6);
        assert_eq!(
            *deltas.lock().unwrap(),
            resp.text,
            "streamed deltas must concatenate to the final text"
        );
        // And match a plain (un-streamed) submission byte for byte.
        let plain = batcher.submit(Request {
            id: 10,
            prompt: "Q: 3+4=? A: ".into(),
            max_tokens: 6,
            ..Default::default()
        });
        assert_eq!(plain.text, resp.text);
        batcher.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn overlong_prompt_gets_error_reply_and_slots_survive() {
        let eng = engine(); // max_seq_len = 96
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 2,
            ..Default::default()
        });
        let handles = spawn_engine_workers(&batcher, eng);
        let bad = batcher.submit(Request {
            id: 1,
            prompt: "x".repeat(200),
            max_tokens: 4,
            ..Default::default()
        });
        assert!(bad.error.is_some(), "over-long prompt must be rejected");
        assert_eq!(bad.tokens, 0);
        assert_eq!(batcher.metrics.rejected.load(Ordering::Relaxed), 1);
        // Every KV slot is still available: max_batch sequences can run
        // concurrently right after the rejection.
        let mut joins = Vec::new();
        for i in 0..2 {
            let b = batcher.clone();
            joins.push(std::thread::spawn(move || {
                b.submit(Request {
                    id: 10 + i,
                    prompt: format!("Q: {i}+2=? A: "),
                    max_tokens: 3,
                    ..Default::default()
                })
            }));
        }
        for j in joins {
            let r = j.join().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.tokens, 3);
        }
        batcher.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn idle_worker_steals_from_a_hoarding_board() {
        // Deterministic steal: stuff worker 0's claim board directly (no
        // worker-0 thread exists), then run only worker 1 — it must pull
        // the waiting requests across and serve them.
        let eng = engine();
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 4,
            engine_workers: 2,
            prefill_chunk: 4,
            ..Default::default()
        });
        let (tx, rx) = std::sync::mpsc::channel();
        let items: Vec<Pending> = (0..3)
            .map(|i| {
                let tx = tx.clone();
                Pending {
                    req: Request {
                        id: i,
                        prompt: format!("Q: {i}+5=? A: "),
                        max_tokens: 3,
                        ..Default::default()
                    },
                    enqueued: Instant::now(),
                    deadline: None,
                    cancel: None,
                    reply: Box::new(move |resp| {
                        let _ = tx.send(resp);
                    }),
                    stream: None,
                }
            })
            .collect();
        batcher.boards.lock().unwrap()[0].extend(items);
        let b = batcher.clone();
        let worker1 = std::thread::spawn(move || b.worker_loop(&eng, 1));
        let mut got = 0;
        while got < 3 {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("stolen request served");
            assert!(r.error.is_none());
            assert_eq!(r.tokens, 3);
            got += 1;
        }
        assert_eq!(
            batcher.metrics.stolen.load(Ordering::Relaxed),
            3,
            "all three waiting claims must have been stolen"
        );
        batcher.shutdown();
        worker1.join().unwrap();
    }

    #[test]
    fn prefix_cache_hits_shared_heads_without_changing_text() {
        // Requests sharing a prompt head, submitted sequentially to one
        // worker: with the prefix cache on, later admissions must hit the
        // registered head (prefill forwards skipped — the counters prove
        // it) and every response must be byte-identical to cache-off.
        let eng = engine();
        let shared = "Q: what is 12+34? A: ";
        let prompts: Vec<String> = (0..4).map(|i| format!("{shared}guess {i}")).collect();
        let mut texts_by_mode = Vec::new();
        let mut prefill_by_mode = Vec::new();
        for prefix_cache in [false, true] {
            let batcher = Batcher::new(BatchPolicy {
                max_batch: 2,
                engine_workers: 1,
                prefill_chunk: 4,
                kv_block_size: 4,
                prefix_cache,
                ..Default::default()
            });
            let handles = spawn_engine_workers(&batcher, eng.fork());
            let texts: Vec<String> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let r = batcher.submit(Request {
                        id: i as u64,
                        prompt: p.clone(),
                        max_tokens: 3,
                        ..Default::default()
                    });
                    assert!(r.error.is_none());
                    r.text
                })
                .collect();
            let hits = batcher.metrics.prefix_hit_tokens.load(Ordering::Relaxed);
            let prefilled = batcher.metrics.prefill_tokens.load(Ordering::Relaxed);
            let admitted_tokens: u64 =
                prompts.iter().map(|p| p.len() as u64).sum();
            if prefix_cache {
                assert!(hits > 0, "shared heads must be served from the cache");
                assert_eq!(
                    prefilled + hits,
                    admitted_tokens,
                    "every admitted prompt token is either prefilled or a cache hit"
                );
                let wm = batcher.worker_metrics();
                assert_eq!(wm[0].prefix_hit_tokens, hits);
                assert!(wm[0].cache_blocks_in_use > 0, "retired chains retained");
            } else {
                assert_eq!(hits, 0);
                assert_eq!(prefilled, admitted_tokens);
            }
            texts_by_mode.push(texts);
            prefill_by_mode.push(prefilled);
            batcher.shutdown();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert_eq!(
            texts_by_mode[0], texts_by_mode[1],
            "prefix cache changed response bytes"
        );
        assert!(
            prefill_by_mode[1] < prefill_by_mode[0],
            "cache-on must run strictly fewer prefill tokens"
        );
    }

    #[test]
    fn speculative_modes_serve_identical_bytes_and_count_drafts() {
        // All three spec modes over the same traffic: byte-identical
        // responses (exact verification), drafted >= accepted, and the
        // drafters actually engage — self-drafting from the first decode,
        // radix drafting once a completed continuation is registered.
        let eng = engine();
        let prompts: Vec<String> =
            (0..3).map(|_| "Q: what is 6*7? A: ".to_string()).collect();
        let mut texts_by_mode = Vec::new();
        for mode in [SpecMode::Off, SpecMode::Radix, SpecMode::SelfDraft] {
            let batcher = Batcher::new(BatchPolicy {
                max_batch: 2,
                engine_workers: 1,
                prefill_chunk: 4,
                kv_block_size: 4,
                prefix_cache: true,
                spec_decode: mode,
                spec_k: 4,
                ..Default::default()
            });
            let handles = spawn_engine_workers(&batcher, eng.fork());
            let texts: Vec<String> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let r = batcher.submit(Request {
                        id: i as u64,
                        prompt: p.clone(),
                        max_tokens: 8,
                        ..Default::default()
                    });
                    assert!(r.error.is_none(), "mode={}: {:?}", mode.name(), r.error);
                    assert_eq!(r.tokens, 8, "mode={}", mode.name());
                    r.text
                })
                .collect();
            let drafted = batcher.metrics.drafted_tokens.load(Ordering::Relaxed);
            let accepted = batcher.metrics.accepted_tokens.load(Ordering::Relaxed);
            assert!(accepted <= drafted, "mode={}", mode.name());
            match mode {
                SpecMode::Off => assert_eq!(drafted, 0, "off must never draft"),
                // Sequential identical prompts: request 2+ draft request
                // 1's registered continuation, and greedy determinism
                // makes those drafts verify in full.
                SpecMode::Radix => {
                    assert!(drafted > 0, "radix never engaged");
                    assert_eq!(accepted, drafted, "cached continuations must verify");
                }
                // Dense test engine: the "base" is the full model, so
                // every self-draft is correct.
                SpecMode::SelfDraft => {
                    assert!(drafted > 0, "self-drafting never engaged");
                    assert_eq!(accepted, drafted);
                    assert_eq!(
                        batcher.metrics.spec_rollbacks.load(Ordering::Relaxed),
                        0
                    );
                }
            }
            texts_by_mode.push(texts);
            batcher.shutdown();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert_eq!(texts_by_mode[0], texts_by_mode[1], "radix changed bytes");
        assert_eq!(texts_by_mode[0], texts_by_mode[2], "self-draft changed bytes");
    }

    #[test]
    fn submit_after_shutdown_gets_error_reply_not_silence() {
        let batcher = Batcher::new(BatchPolicy::default());
        batcher.shutdown();
        let (tx, rx) = std::sync::mpsc::channel();
        let ok = batcher.submit_with(
            Request {
                id: 1,
                prompt: "x".into(),
                max_tokens: 1,
                ..Default::default()
            },
            Box::new(move |resp| {
                let _ = tx.send(resp);
            }),
        );
        assert!(!ok, "post-shutdown submissions must not be queued");
        let resp = rx.recv().expect("a rejected submission still gets its reply");
        assert_eq!(resp.error.as_deref(), Some("shutting down"));
        assert_eq!(batcher.drain_abandoned(), 0, "nothing may have been queued");
        // The blocking form degrades to an error response, not a panic.
        let resp = batcher.submit(Request {
            id: 2,
            prompt: "x".into(),
            max_tokens: 1,
            ..Default::default()
        });
        assert_eq!(resp.error.as_deref(), Some("shutting down"));
    }

    #[test]
    fn bounded_queue_sheds_overflow_immediately() {
        // No workers: the queue cannot drain, so submissions past the
        // depth bound must be shed synchronously with `overloaded`.
        let batcher = Batcher::new(BatchPolicy {
            max_queue_depth: 2,
            ..Default::default()
        });
        let (tx, rx) = std::sync::mpsc::channel();
        let mut accepted = Vec::new();
        for i in 0..4 {
            let tx = tx.clone();
            accepted.push(batcher.submit_with(
                Request {
                    id: i,
                    prompt: "x".into(),
                    max_tokens: 1,
                    ..Default::default()
                },
                Box::new(move |resp| {
                    let _ = tx.send(resp);
                }),
            ));
        }
        assert_eq!(accepted, vec![true, true, false, false]);
        let shed: Vec<Response> = rx.try_iter().collect();
        assert_eq!(shed.len(), 2, "overflow replies fire immediately");
        for resp in &shed {
            assert_eq!(resp.error.as_deref(), Some("overloaded"));
        }
        assert_eq!(batcher.metrics.shed.load(Ordering::Relaxed), 2);
        assert_eq!(batcher.drain_abandoned(), 2, "the bounded queue held only 2");
    }

    #[test]
    fn cancel_token_latches_and_cancel_wins_over_deadline() {
        let token = CancelToken::new();
        assert!(!token.is_cancelled());
        token.cancel();
        token.cancel();
        assert!(token.is_cancelled(), "cancel is a one-way latch");
        let now = Instant::now();
        let expired = now.checked_sub(Duration::from_millis(1));
        assert_eq!(failure_kind(&None, None, now), None);
        assert_eq!(failure_kind(&None, expired, now), Some("timeout"));
        assert_eq!(failure_kind(&Some(token.clone()), None, now), Some("cancelled"));
        assert_eq!(
            failure_kind(&Some(token), expired, now),
            Some("cancelled"),
            "a cancelled-and-expired request reports the caller's action"
        );
    }

    #[test]
    fn prepare_prompt_clamps_budget_and_rejects_overflow() {
        let fits = Request {
            id: 0,
            prompt: "x".repeat(20),
            max_tokens: 1000,
            ..Default::default()
        };
        let (toks, budget) = prepare_prompt(&fits, 96).expect("budget clamps into context");
        assert_eq!(toks.len(), 20);
        assert!(budget >= 1 && toks.len() + budget <= 96);
        let too_long = Request {
            id: 0,
            prompt: "x".repeat(500),
            max_tokens: 4,
            ..Default::default()
        };
        assert!(prepare_prompt(&too_long, 96).is_err(), "over-long prompt rejected");
        let empty = Request {
            id: 0,
            prompt: String::new(),
            max_tokens: 4,
            ..Default::default()
        };
        let (toks, budget) = prepare_prompt(&empty, 96).unwrap();
        assert_eq!(toks.len(), 1);
        assert!(budget >= 1);
    }
}
