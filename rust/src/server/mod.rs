//! Serving: a TCP inference server with dynamic batching over the native
//! engine. The request path is pure rust (no python, no HLO retracing):
//! socket → batcher queue → engine decode → response.

mod batcher;
mod tcp;

pub use batcher::{BatchPolicy, Batcher, Request, Response, ServerMetrics};
pub use tcp::{serve, Client};
