//! Serving: a TCP inference server with **continuous batching** over the
//! native engine. The request path is pure rust (no python, no HLO
//! retracing): socket → shared admission queue → one of `W` engine
//! worker loops (iteration-level scheduling over a **paged** KV-slot
//! pool with an optional radix-tree **prefix cache**, chunked prefill
//! interleaved with decode steps, work stealing between workers) →
//! out-of-order response routed back by request id, with optional
//! per-token streaming frames along the way. Per-connection reply
//! queues are bounded — a slow reader is disconnected, never an
//! unbounded buffer or a blocked engine worker.
//!
//! The tier carries a **failure model** end to end: per-request
//! deadlines and [`CancelToken`]s (wired to disconnects and the `cancel`
//! wire command), bounded admission with `overloaded` shedding, and
//! panic **supervision** of every engine worker (failed-over with error
//! replies, no KV leaks, `worker_restarts` counted) — exercised
//! deterministically by the `SALR_FAULT` op-counter fault-injection
//! harness (`util::fault`).
//!
//! Decode can run **speculatively** (`--spec-decode {radix,self}`,
//! [`crate::infer::SpecMode`]): each iteration drafts up to `--spec-k`
//! tokens per sequence (radix-tree continuations or the sparse-base-only
//! forward) and verifies them in one batched forward with exact greedy
//! acceptance — output stays byte-identical to non-speculative serving,
//! counted by `drafted_tokens` / `accepted_tokens` / `spec_rollbacks`.
//!
//! Above the single process sits the **router tier**
//! ([`serve_router`], the `router` subcommand): a front-end TCP
//! process speaking the same wire protocol over `N` independent
//! engine backends, with heartbeat health checks, consistent-hash
//! cache-aware routing with least-loaded spill, exact pre-first-token
//! failover, and graceful per-backend drain — the same failure-model
//! discipline lifted across the process boundary (see
//! `server::router`).
//!
//! See DESIGN.md "Serving layer", "KV cache subsystem" and "Router
//! tier" for the scheduler, the block/prefix-cache lifecycle, the
//! chunked-prefill/streaming wire protocol, and the determinism
//! argument; `rust/benches/bench_serve.rs` measures tokens/s, batch
//! occupancy and prefix-hit rates at 1/2/4 engine workers.

mod backend;
mod batcher;
mod router;
mod tcp;

pub use backend::BackendState;
pub use batcher::{
    spawn_engine_workers, BatchPolicy, Batcher, CancelToken, ReplyFn, Request, Response,
    ServerMetrics, StreamFn, WorkerMetrics,
};
pub use router::{serve_router, serve_router_on, Router, RouterPolicy};
pub use tcp::{serve, serve_on, Client};
