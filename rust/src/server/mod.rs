//! Serving: a TCP inference server with **continuous batching** over the
//! native engine. The request path is pure rust (no python, no HLO
//! retracing): socket → shared admission queue → one of `W` engine
//! worker loops (iteration-level scheduling over a fixed KV-slot pool) →
//! out-of-order response routed back by request id.
//!
//! See DESIGN.md "Serving layer" for the scheduler, the KV-slot
//! lifecycle, and the determinism argument; `rust/benches/bench_serve.rs`
//! measures tokens/s and batch occupancy at 1/2/4 engine workers.

mod batcher;
mod tcp;

pub use batcher::{
    spawn_engine_workers, BatchPolicy, Batcher, ReplyFn, Request, Response, ServerMetrics,
    WorkerMetrics,
};
pub use tcp::{serve, Client};
