//! Router-side state for one engine backend: the multiplexed
//! connection, the in-flight request table, health/heartbeat gauges and
//! the per-backend routing counters.
//!
//! A [`Backend`] owns exactly one TCP connection to its engine process
//! at a time. Every client request the router forwards there is
//! multiplexed over that connection under a router-assigned id and
//! parked in the backend's `inflight` table until its final frame comes
//! back. The connection lifecycle follows one discipline:
//!
//! * **writers never clean up** — [`Backend::send_line`] and the fault
//!   injector only *shut down* the socket on failure
//!   ([`Backend::shut_socket`]), leaving the connection entry in place;
//! * **the pump thread is the single disposer** — the reader loop in
//!   `server::router` notices the dead socket, calls
//!   [`Backend::sever`] with the epoch it was spawned under, and only
//!   the caller that wins that epoch check drains and re-disposes the
//!   inflight table (failover / `backend lost` errors).
//!
//! The epoch counter makes severing idempotent: a stale pump (one
//! spawned for a connection that has since been replaced) fails the
//! check and exits without touching state that now belongs to the new
//! connection.

use super::tcp::FrameTx;
use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Health state of one backend, as the router sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendState {
    /// Connected and its last heartbeat probe was answered: routable.
    Healthy,
    /// Not routable: disconnected (reconnecting under backoff), or
    /// connected but not yet proven by a heartbeat reply. Reintegration
    /// requires a successful probe, never just a successful `connect` —
    /// a backend that accepts TCP but cannot answer is still down.
    Unhealthy,
    /// Draining: no new requests are routed here; in-flight sequences
    /// finish and deliver. Moves to [`BackendState::Down`] once the
    /// inflight table empties (or the connection is lost).
    Draining,
    /// Permanently out of rotation: a completed drain or an injected
    /// `backend_down` fault. The router never reconnects.
    Down,
}

impl BackendState {
    /// Wire name used in the router's metrics reply.
    pub fn as_str(self) -> &'static str {
        match self {
            BackendState::Healthy => "healthy",
            BackendState::Unhealthy => "unhealthy",
            BackendState::Draining => "draining",
            BackendState::Down => "down",
        }
    }
}

/// Monotonic per-backend routing counters (reported per backend in the
/// router metrics reply; the aggregate view sums them).
#[derive(Default)]
pub(crate) struct BackendCounters {
    /// Requests forwarded to this backend, by any rule.
    pub(crate) routed: AtomicU64,
    /// Requests that landed here because the consistent-hash ring made
    /// this backend the owner of their prompt head.
    pub(crate) hash_routed: AtomicU64,
    /// Requests that landed here by least-loaded spill — their ring
    /// owner was over the spill depth (or unhealthy).
    pub(crate) spilled: AtomicU64,
    /// Requests failed over *away* from this backend after its
    /// connection died before their first streamed token.
    pub(crate) failovers: AtomicU64,
    /// Heartbeat probes this backend failed to answer in time.
    pub(crate) missed_heartbeats: AtomicU64,
}

/// One forwarded request parked in a backend's inflight table. Carries
/// everything the router needs to re-dispatch it on another backend
/// (pre-first-token failover) or synthesize its `backend lost` final.
pub(crate) struct Inflight {
    /// The exact request line forwarded (router id already substituted);
    /// re-sent verbatim on failover, so the retry is the same request.
    pub(crate) line: String,
    /// The id the client used — substituted back into every reply frame.
    pub(crate) client_id: u64,
    /// Whether the client asked for per-token streaming (decides the
    /// `"done"` marker on synthesized error finals).
    pub(crate) stream: bool,
    /// First delta frame already delivered to the client. A started
    /// request is never retried: its retry would replay tokens the
    /// client has already seen. Greedy decode makes the *unstarted*
    /// retry exact — same prompt, same bytes.
    pub(crate) started: bool,
    /// Already failed over once; a second loss is a `backend lost`.
    pub(crate) retried: bool,
    /// The request's trace id (0 = tracing disabled at submission).
    /// Survives failover unchanged: both dispatch attempts — and the
    /// `failover` span between them — stitch into one span tree.
    pub(crate) trace: u64,
    /// The owning client connection's bounded reply sender.
    pub(crate) tx: FrameTx,
    /// The owning client connection's id → (backend, router id) map,
    /// shared here so whoever disposes the request can unregister it.
    pub(crate) conn_map: Arc<Mutex<HashMap<u64, (usize, u64)>>>,
}

/// Router-side handle for one engine backend (see the module docs for
/// the connection-lifecycle discipline).
pub(crate) struct Backend {
    /// `host:port` this backend serves on.
    pub(crate) addr: String,
    /// Stable index: the consistent-hash ring, fault specs
    /// (`backend=N`) and the metrics reply all key on it.
    pub(crate) index: usize,
    /// The live connection's write half (`None` while disconnected).
    conn: Mutex<Option<Arc<TcpStream>>>,
    /// Bumped on every sever; a pump thread only disposes state if its
    /// spawn-time epoch still matches.
    epoch: AtomicU64,
    state: Mutex<BackendState>,
    /// Forwarded requests awaiting their final frame, by router id.
    pub(crate) inflight: Mutex<HashMap<u64, Inflight>>,
    /// Last heartbeat's admission backlog (`queue_depth` gauge).
    pub(crate) queue_depth: AtomicU64,
    /// Last heartbeat's occupied decode slots (`slots_in_use` gauge).
    pub(crate) slots_in_use: AtomicU64,
    /// Last heartbeat's `cache_blocks_in_use` gauge (leak checks).
    pub(crate) cache_blocks_in_use: AtomicU64,
    /// Consecutive unanswered heartbeat probes.
    pub(crate) missed: AtomicU64,
    /// A probe is in flight; answered by the pump on a metrics-shaped
    /// reply, counted as a miss by the next tick otherwise.
    pub(crate) probe_outstanding: AtomicBool,
    /// Consecutive failed `connect` attempts — the circuit-breaker
    /// input: backoff doubles per failure up to the policy cap.
    pub(crate) consec_fails: AtomicU64,
    /// Earliest instant the next reconnect attempt may run.
    pub(crate) next_attempt: Mutex<Instant>,
    pub(crate) counters: BackendCounters,
}

impl Backend {
    pub(crate) fn new(addr: String, index: usize) -> Backend {
        Backend {
            addr,
            index,
            conn: Mutex::new(None),
            epoch: AtomicU64::new(0),
            state: Mutex::new(BackendState::Unhealthy),
            inflight: Mutex::new(HashMap::new()),
            queue_depth: AtomicU64::new(0),
            slots_in_use: AtomicU64::new(0),
            cache_blocks_in_use: AtomicU64::new(0),
            missed: AtomicU64::new(0),
            probe_outstanding: AtomicBool::new(false),
            consec_fails: AtomicU64::new(0),
            next_attempt: Mutex::new(Instant::now()),
            counters: BackendCounters::default(),
        }
    }

    pub(crate) fn state(&self) -> BackendState {
        *self.state.lock().unwrap()
    }

    pub(crate) fn set_state(&self, s: BackendState) {
        *self.state.lock().unwrap() = s;
    }

    /// `Down` is terminal: once set, no transition out is ever applied.
    /// Used by state changes that race a `backend_down` fault.
    pub(crate) fn set_state_unless_down(&self, s: BackendState) {
        let mut cur = self.state.lock().unwrap();
        if *cur != BackendState::Down {
            *cur = s;
        }
    }

    /// Install a freshly connected stream and return the epoch the new
    /// pump thread must carry into [`Backend::sever`].
    pub(crate) fn install_conn(&self, stream: Arc<TcpStream>) -> u64 {
        let mut conn = self.conn.lock().unwrap();
        *conn = Some(stream);
        self.epoch.load(Ordering::SeqCst)
    }

    /// Write one line to the backend connection. On any failure the
    /// socket is shut down but the connection entry is kept — the pump
    /// thread observes the dead socket and runs the one true disposal
    /// path. Returns `false` if the line was not delivered.
    pub(crate) fn send_line(&self, line: &str) -> bool {
        let conn = self.conn.lock().unwrap();
        let Some(stream) = conn.as_ref() else {
            return false;
        };
        let mut w = stream.as_ref();
        if writeln!(w, "{line}").and_then(|_| w.flush()).is_err() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return false;
        }
        true
    }

    /// Shut the live socket down without clearing the connection entry:
    /// the pump thread will notice and run disposal. Safe when
    /// disconnected (no-op).
    pub(crate) fn shut_socket(&self) {
        if let Some(stream) = self.conn.lock().unwrap().as_ref() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Tear the connection down, if `epoch` still names the current
    /// connection (`None` = unconditionally). Returns `true` only for
    /// the single caller that actually performed the sever — that
    /// caller (and nobody else) must dispose the inflight table.
    pub(crate) fn sever(&self, epoch: Option<u64>) -> bool {
        let mut conn = self.conn.lock().unwrap();
        if let Some(e) = epoch {
            if e != self.epoch.load(Ordering::SeqCst) {
                return false;
            }
        }
        if let Some(stream) = conn.take() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        } else if epoch.is_none() {
            // Unconditional sever of an already-clear connection: there
            // is nothing left to dispose either.
            return false;
        }
        self.epoch.fetch_add(1, Ordering::SeqCst);
        self.probe_outstanding.store(false, Ordering::SeqCst);
        true
    }

    /// Is a connection currently installed (healthy or not)?
    pub(crate) fn connected(&self) -> bool {
        self.conn.lock().unwrap().is_some()
    }

    /// The load signal routing compares: the backend's last-reported
    /// admission backlog and occupied decode slots, plus what the
    /// router has forwarded there and not yet seen finish (which the
    /// next heartbeat has not observed yet).
    pub(crate) fn load(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
            + self.slots_in_use.load(Ordering::Relaxed)
            + self.inflight.lock().unwrap().len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sever_is_epoch_guarded_and_one_shot() {
        let b = Backend::new("127.0.0.1:1".into(), 0);
        // No connection: an unconditional sever has nothing to dispose.
        assert!(!b.sever(None));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let epoch = b.install_conn(Arc::new(stream));
        assert!(b.connected());
        // A stale epoch (pump of a previous connection) must not win.
        assert!(!b.sever(Some(epoch + 1)));
        assert!(b.connected());
        // The matching epoch wins exactly once.
        assert!(b.sever(Some(epoch)));
        assert!(!b.connected());
        assert!(!b.sever(Some(epoch)), "second disposer must lose");
    }

    #[test]
    fn down_is_terminal() {
        let b = Backend::new("127.0.0.1:1".into(), 0);
        b.set_state(BackendState::Down);
        b.set_state_unless_down(BackendState::Healthy);
        assert_eq!(b.state(), BackendState::Down);
    }

    #[test]
    fn load_counts_router_side_inflight() {
        let b = Backend::new("127.0.0.1:1".into(), 0);
        b.queue_depth.store(3, Ordering::Relaxed);
        b.slots_in_use.store(2, Ordering::Relaxed);
        assert_eq!(b.load(), 5);
    }
}
