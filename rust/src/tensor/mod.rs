//! Dense `f32` tensors and the host-side numerical ops the coordinator
//! needs (pruning, SVD, bitmap codecs, model surgery, the native serving
//! engine). This is intentionally a small, explicit implementation — the
//! heavy training math lives in the AOT-compiled HLO executables; these ops
//! exist so the *request path* and the *model-surgery path* never touch
//! python.

mod ops;
mod tensor;

pub use ops::*;
pub use tensor::Tensor;
