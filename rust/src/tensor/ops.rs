//! Host-side numerical ops over [`Tensor`]: matmul (thin wrapper over the
//! optimized `gemm` module), elementwise arithmetic, reductions, softmax,
//! layer-norm — everything the native inference engine and model surgery
//! need.

use super::Tensor;

/// `C = A @ B` for 2-D tensors.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    assert_eq!(a.cols(), b.rows(), "matmul inner dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Tensor::zeros(&[m, n]);
    crate::gemm::dense::gemm_f32(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C = A @ B + C0` (accumulating variant; `c` is consumed and returned).
pub fn matmul_acc(a: &Tensor, b: &Tensor, mut c: Tensor) -> Tensor {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    crate::gemm::dense::gemm_f32_acc(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Elementwise `a + b`.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::from_vec(a.shape(), data)
}

/// Elementwise `a - b`.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::from_vec(a.shape(), data)
}

/// Elementwise `a * b` (Hadamard).
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x * y).collect();
    Tensor::from_vec(a.shape(), data)
}

/// `a + alpha * b`, in place on `a`.
pub fn axpy(a: &mut Tensor, alpha: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += alpha * y;
    }
}

/// Row-wise softmax of a 2-D tensor.
pub fn softmax_rows(t: &Tensor) -> Tensor {
    let (r, c) = (t.rows(), t.cols());
    let mut out = Tensor::zeros(&[r, c]);
    for i in 0..r {
        let row = t.row(i);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        let orow = out.row_mut(i);
        for j in 0..c {
            let e = (row[j] - m).exp();
            orow[j] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for v in orow {
            *v *= inv;
        }
    }
    out
}

/// Layer norm over the last axis of a 2-D tensor: `g * (x-mu)/sigma + b`.
pub fn layer_norm(t: &Tensor, gamma: &[f32], beta: &[f32], eps: f32) -> Tensor {
    let (r, c) = (t.rows(), t.cols());
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let mut out = Tensor::zeros(&[r, c]);
    for i in 0..r {
        let row = t.row(i);
        let mean: f32 = row.iter().sum::<f32>() / c as f32;
        let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..c {
            orow[j] = gamma[j] * (row[j] - mean) * inv + beta[j];
        }
    }
    out
}

/// RMS norm over the last axis (no mean subtraction), as used by Llama-style
/// blocks: `g * x / rms(x)`.
pub fn rms_norm(t: &Tensor, gamma: &[f32], eps: f32) -> Tensor {
    let (r, c) = (t.rows(), t.cols());
    assert_eq!(gamma.len(), c);
    let mut out = Tensor::zeros(&[r, c]);
    for i in 0..r {
        let row = t.row(i);
        let ms: f32 = row.iter().map(|&x| x * x).sum::<f32>() / c as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..c {
            orow[j] = gamma[j] * row[j] * inv;
        }
    }
    out
}

/// GELU activation (tanh approximation, matches jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Elementwise GELU.
pub fn gelu_t(t: &Tensor) -> Tensor {
    t.map(gelu)
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Argmax over a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean squared difference between two tensors (per entry).
pub fn mse(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let n = a.len().max(1) as f64;
    a.data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / n
}

/// Maximum absolute difference.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape(), b.shape());
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs()))
}

/// Naive triple-loop matmul — the oracle the optimized GEMM is tested against.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(k, b.rows());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.at(i, p);
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c.data_mut()[i * n + j] += av * b.at(p, j);
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 4), (17, 31, 13), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c1 = matmul(&a, &b);
            let c2 = matmul_naive(&a, &b);
            assert!(
                max_abs_diff(&c1, &c2) < 1e-3,
                "({m},{k},{n}) diff={}",
                max_abs_diff(&c1, &c2)
            );
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(4);
        let t = Tensor::randn(&[5, 9], 3.0, &mut rng);
        let s = softmax_rows(&t);
        for i in 0..5 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(i).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(&[4, 64], 2.5, &mut rng);
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let n = layer_norm(&t, &g, &b, 1e-5);
        for i in 0..4 {
            let row = n.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn arith_ops() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(add(&a, &b).data(), &[6.0, 8.0, 10.0, 12.0]);
        assert_eq!(sub(&b, &a).data(), &[4.0, 4.0, 4.0, 4.0]);
        assert_eq!(mul(&a, &b).data(), &[5.0, 12.0, 21.0, 32.0]);
        let mut c = a.clone();
        axpy(&mut c, 2.0, &b);
        assert_eq!(c.data(), &[11.0, 14.0, 17.0, 20.0]);
    }

    #[test]
    fn mse_and_argmax() {
        let a = Tensor::from_vec(&[1, 3], vec![0.0, 0.0, 0.0]);
        let b = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 2.0]);
        assert!((mse(&a, &b) - 3.0).abs() < 1e-12);
        assert_eq!(argmax(b.data()), 1);
    }

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-3);
        assert!((silu(0.0)).abs() < 1e-7);
    }
}
