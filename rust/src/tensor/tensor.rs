//! The `Tensor` type: a reference-counted-free, owned, row-major `f32`
//! n-d array with shape tracking and 2-D conveniences.

use crate::util::rng::Rng;
use std::fmt;

/// Owned row-major `f32` tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// Tensor from existing data (must match the shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// I.i.d. `N(0, sigma^2)` entries.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, sigma);
        t
    }

    /// Identity matrix `n x n`.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() on non-2D tensor");
        self.shape[0]
    }

    /// Columns of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() on non-2D tensor");
        self.shape[1]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2-D element access.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D element assignment.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row slice of a 2-D tensor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place (product must be preserved).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} mismatch",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copies).
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Sum of squares.
    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Count of exact zeros.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Fraction of zero entries.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.len().max(1) as f64
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise scaling.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor{:?} [{}]",
            self.shape,
            self.data
                .iter()
                .take(6)
                .map(|x| format!("{x:.4}"))
                .collect::<Vec<_>>()
                .join(", ")
        )?;
        if self.data.len() > 6 {
            write!(f, " …({} elems)", self.data.len())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(1, 2, 5.0);
        assert_eq!(t.at(1, 2), 5.0);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let tt = t.transpose().transpose();
        assert_eq!(t, tt);
    }

    #[test]
    fn eye_matmul_identity_like() {
        let e = Tensor::eye(4);
        assert_eq!(e.at(2, 2), 1.0);
        assert_eq!(e.at(2, 3), 0.0);
        assert_eq!(e.nnz(), 4);
        assert!((e.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 0.0, 4.0]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn reshape_checks_product() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn randn_stats() {
        let mut rng = Rng::new(2);
        let t = Tensor::randn(&[100, 100], 2.0, &mut rng);
        let mean: f64 = t.data().iter().map(|&x| x as f64).sum::<f64>() / t.len() as f64;
        let var: f64 =
            t.data().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / t.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }
}
