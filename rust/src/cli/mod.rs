//! Command-line argument parsing (the offline vendor set has no `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value` and boolean
//! switches, with typed accessors and automatic usage text.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed arguments: a subcommand, positionals, and flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator (first item must be argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter();
        let _bin = it.next();
        let mut args = Args::default();
        let mut rest: Vec<String> = it.collect();
        if let Some(first) = rest.first() {
            if !first.starts_with('-') {
                args.command = rest.remove(0);
            }
        }
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.flags.insert(flag.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args())
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
            None => Ok(default),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        // Shares the env-var truthy set, so `--prefix-cache on` and
        // `SALR_PREFIX_CACHE=on` can never disagree.
        self.flag(name).is_some_and(crate::util::truthy)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.flag(name) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{name}"),
        }
    }
}

/// Usage text for the `salr` binary.
pub const USAGE: &str = "\
salr — Sparsity-Aware Low-Rank Representation (paper reproduction)

USAGE: salr <command> [flags]

COMMANDS:
  exp <id>        run a paper experiment: theory table1..table7 fig1 fig3 all
  pretrain        pretrain the base model and cache it
  finetune        fine-tune one baseline (--baseline, --task, --sparsity)
  serve           start the inference server (--addr, --backend)
  router          start the front-end router tier over N running
                  `serve` backends (--backends host:port,host:port,...)
  compress        prune+encode a model, print size accounting
  info            print manifest + config summary

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --config NAME     model config (default: tiny)
  --results DIR     results directory (default: results)
  --steps N         override fine-tune steps (also SALR_STEPS)
  --sparsity P      prune ratio (default 0.5)
  --baseline NAME   lora|losa|sparselora|deepsparse|salr|salr-frozen
  --task NAME       math|mcq (default math)
  --addr HOST:PORT  serve address (default 127.0.0.1:7433)
  --backend NAME    dense|bitmap|pipeline (default pipeline)
  --threads N       GEMM + pipeline worker threads (default: all cores)
  --weight-format F resident form of the sparse base weights:
                    f32 (dense copy), bitmap (mask + f32 nonzeros, exact),
                    nf4 (mask + NF4-quantized nonzeros, lossy ~5x smaller)
                    (default bitmap, or SALR_WEIGHT_FORMAT); the GEMM
                    kernels decode compressed formats per tile — no dense
                    copy of the base is ever materialized

SERVE FLAGS:
  --engine-workers W  continuous-batching engine worker loops (default 1);
                      each owns max-batch KV slots and threads/W GEMM threads
  --max-batch N       decode-batch slots per engine worker (default 8)
  --max-wait-ms T     idle-worker admission poll interval (default 5)
  --prefill-chunk N   max prompt tokens prefilled per scheduler iteration,
                      so running sequences keep decoding between the chunks
                      of a long prompt (default 64; 0 = whole-prompt prefill)
  --kv-block-size N   token positions per paged KV block (default 16, or
                      SALR_KV_BLOCK); also the prefix-sharing granularity
  --prefix-cache B    radix-tree prefix cache: requests sharing a prompt
                      head reuse its KV blocks instead of re-running
                      prefill (default off, or SALR_PREFIX_CACHE=1);
                      output bytes are identical either way
  --stream-frame-cap N  per-connection reply-queue bound; a reader that
                      falls N frames behind is disconnected (default 1024)
  --default-deadline-ms T  deadline applied to every request that sets no
                      \"timeout_ms\" of its own; an unfinished request is
                      retired with error \"timeout\" at its next scheduler
                      boundary (default 0 = no deadline)
  --max-queue-depth N bound on the admission queue: submissions past it
                      are shed immediately with error \"overloaded\"
                      (default 0 = unbounded)
  --idle-timeout-ms T close a connection with nothing in flight after T ms
                      of silence, freeing its reader/writer threads
                      (default 0 = never)
  --spec-decode MODE  speculative decoding draft source: off|radix|self
                      (default off, or SALR_SPEC). radix drafts cached
                      continuations from the prefix-cache radix tree;
                      self drafts with the sparse-base-only forward.
                      Verification is exact: output bytes are identical
                      to non-speculative decode in every mode
  --spec-k N          max draft tokens verified per sequence per decode
                      iteration (default 4)

OBSERVABILITY FLAGS (serve + router):
  --trace-out FILE    enable request tracing and dump a Chrome
                      trace_event JSON (load in chrome://tracing or
                      Perfetto) to FILE at drain/shutdown. SALR_TRACE=1
                      enables tracing without the file dump;
                      SALR_TRACE_RING sets the per-thread span ring
                      capacity (default 4096 events, oldest overwritten).
                      Traced requests carry a \"trace\" id on their final
                      frame; {\"cmd\":\"trace\",\"id\":T} returns that
                      request's span tree (admit -> prefill_chunk ->
                      decode_step -> retire, with gemm_call/pack_b kernel
                      spans nested), stitched across router and backend.
                      {\"cmd\":\"metrics\"} additionally reports log2
                      latency histograms (\"hist\"), per-stage span totals
                      (\"stages\") and the overwrite counter
                      (\"trace_dropped\"). Tracing never changes output
                      bytes; disabled sites cost one atomic load.

ROUTER FLAGS:
  --backends LIST     comma-separated backend addresses (required); each
                      is a running `salr serve` process
  --addr HOST:PORT    router listen address (default 127.0.0.1:7400)
  --heartbeat-ms T    health-probe + reconnect tick interval (default 200)
  --miss-threshold M  consecutive unanswered probes before a backend is
                      marked unhealthy and its connection torn down
                      (default 3); it reintegrates after a probe succeeds
  --spill-depth N     backend load (queue_depth + slots_in_use +
                      router-side inflight) above which the hash owner is
                      bypassed for the least-loaded healthy backend
                      (default 8)
  --hash-blocks N     leading KV blocks of the prompt fed to the
                      consistent hash (default 2); prompts shorter than
                      one block hash whole
  --kv-block-size N   must match the backends' --kv-block-size so hash
                      granularity aligns with prefix sharing (default 16)
  --vnodes N          virtual ring nodes per backend (default 32)
  --backoff-base-ms T first reconnect backoff, doubling per consecutive
                      failure (default 50)
  --backoff-max-ms T  reconnect backoff ceiling (default 2000)
  --connect-timeout-ms T  backend dial timeout (default 1000)
  --stream-frame-cap N    per-client reply-queue bound, as in serve

The router speaks the same wire protocol as serve. Extra router
commands: {\"cmd\":\"drain\",\"backend\":N} decommissions backend N without
dropping a request; {\"cmd\":\"metrics\"} reports per-backend
state/load/routing counters. A request whose backend dies before its
first streamed token is retried once on another healthy backend
(byte-identical: greedy decode is deterministic); mid-stream deaths
get a clean {\"error\":\"backend lost\"} final.

Clients add \"stream\": true to a request line to receive one
{\"id\",\"delta\",\"seq\"} frame per generated token before the final reply;
\"timeout_ms\": T puts a deadline on one request, and
{\"cmd\":\"cancel\",\"id\":N} cancels in-flight request N of the same
connection (a dropped connection cancels all of its requests). SALR_FAULT
arms the deterministic fault-injection harness (see util::fault).
";

/// Parse a baseline name.
pub fn parse_baseline(s: &str) -> Result<crate::salr::Baseline> {
    use crate::salr::Baseline::*;
    Ok(match s.to_ascii_lowercase().as_str() {
        "pretrained" => Pretrained,
        "lora" => Lora,
        "losa" => Losa,
        "sparselora" => SparseLora,
        "deepsparse" => DeepSparse,
        "salr" => Salr,
        "salr-frozen" | "salr_frozen" => SalrFrozenResidual,
        other => bail!("unknown baseline {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(
            std::iter::once("salr".to_string()).chain(items.iter().map(|s| s.to_string())),
        )
        .unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["exp", "table2", "--steps", "100", "--config=small", "--fast"]);
        assert_eq!(a.command, "exp");
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.str_or("config", "tiny"), "small");
        assert!(a.bool("fast"));
        assert!(!a.bool("slow"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["serve"]);
        assert_eq!(a.str_or("addr", "127.0.0.1:7433"), "127.0.0.1:7433");
        assert!(a.require("missing").is_err());
        let bad = parse(&["x", "--steps", "abc"]);
        assert!(bad.usize_or("steps", 1).is_err());
    }

    #[test]
    fn baseline_names() {
        assert!(parse_baseline("salr").is_ok());
        assert!(parse_baseline("SALR").is_ok());
        assert!(parse_baseline("nope").is_err());
    }
}
