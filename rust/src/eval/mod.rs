//! The experiment harness: every table and figure of the paper has a
//! driver here (`salr exp <id>`), built on a shared context that
//! pretrains/fine-tunes once per (baseline, task, sparsity) and caches the
//! results under `results/cache/`. See DESIGN.md §Experiment-index.

mod accuracy;
mod context;
mod report;
mod tables;

pub use accuracy::{math_accuracy, mcq_accuracy};
pub use context::{deploy_engine, deploy_engine_with_format, ExpContext, RunKey, Task};
pub use report::Report;
pub use tables::{run_experiment, EXPERIMENTS};
