//! Result rendering: aligned text tables + CSV + JSON files under
//! `results/`, and the EXPERIMENTS.md paper-vs-measured blocks.

use crate::util::json::Json;
use anyhow::Result;
use std::path::Path;

/// A simple column-aligned table with metadata, rendered to stdout, CSV
/// and JSON.
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n=== {} — {} ===\n", self.id, self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .columns
            .iter()
            .map(|c| esc(c))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id.as_str())
            .set("title", self.title.as_str())
            .set(
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
            )
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            )
            .set(
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
            )
    }

    /// Print and persist under `dir` as `<id>.csv` + `<id>.json`.
    pub fn emit(&self, dir: impl AsRef<Path>) -> Result<()> {
        println!("{}", self.render());
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), self.to_csv())?;
        std::fs::write(
            dir.join(format!("{}.json", self.id)),
            self.to_json().to_string_pretty(),
        )?;
        Ok(())
    }
}

/// Format helpers shared by the table drivers.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut r = Report::new("t1", "Test", &["a", "b"]);
        r.row(vec!["x".into(), "1,2".into()]);
        r.note("hello");
        let text = r.render();
        assert!(text.contains("Test") && text.contains("hello"));
        let csv = r.to_csv();
        assert!(csv.contains("\"1,2\""));
        let j = r.to_json();
        assert_eq!(j.get("id").unwrap().as_str(), Some("t1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut r = Report::new("t2", "Test", &["a", "b"]);
        r.row(vec!["only-one".into()]);
    }
}
