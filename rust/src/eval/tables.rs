//! One driver per paper table/figure. Each regenerates the corresponding
//! result on the synthetic testbed and emits a Report under `results/`.
//! Paper numbers are quoted in notes for side-by-side comparison —
//! *shape* (who wins, by roughly what factor) is the reproduction target,
//! not absolute values (see DESIGN.md §Substitutions).

use super::context::{deploy_engine, ExpContext, RunKey, Task};
use super::report::{f2, pct, Report};
use crate::data::MathTask;
use crate::infer::Engine;
use crate::linalg::jacobi_svd;
use crate::model::{save_model, Encoding, ParamStore};
use crate::prune::{theory, NmPattern};
use crate::salr::{Baseline, BaselineSpec};
use crate::tensor::{matmul, sub, Tensor};
use crate::train::{finetune, TrainConfig};
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

/// Registry of experiment ids.
pub const EXPERIMENTS: [&str; 10] = [
    "theory", "table1", "table2", "table3", "table4", "table5", "table6", "table7",
    "fig1", "fig3",
];

/// Run one experiment by id.
pub fn run_experiment(ctx: &ExpContext, id: &str) -> Result<()> {
    match id {
        "theory" => theory_exp(ctx),
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "table5" => table5(ctx),
        "table6" => table6(ctx),
        "table7" => table7(ctx),
        "fig1" => fig1(ctx),
        "fig3" => fig3(ctx),
        "all" => {
            for e in EXPERIMENTS {
                run_experiment(ctx, e)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other} (have {EXPERIMENTS:?} or 'all')"),
    }
}

// ---------------------------------------------------------------------------
// Theorems 1–3 numerics
// ---------------------------------------------------------------------------

fn theory_exp(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "theory",
        "Theorems 1–3: closed forms vs Monte Carlo (σ²=1, τ²=0.25)",
        &["p", "MSE(p)", "E1", "E2", "E3", "E1 MC", "E2 MC", "E3 MC", "Thm3 r=q/4"],
    );
    let (s2, t2) = (1.0, 0.25);
    let mut rng = Rng::new(777);
    for &p in &[0.1, 0.3, 0.5, 0.7, 0.9] {
        let n = 200_000;
        let v = (s2 + t2_f(t2)).sqrt();
        let (mut m1, mut m2, mut m3) = (0.0f64, 0.0, 0.0);
        for _ in 0..n {
            let w0 = rng.normal();
            let u = w0 + rng.normal() * t2.sqrt();
            let e1v = if w0.abs() <= theory::t_p(p) { w0 } else { 0.0 };
            let e2v = if u.abs() <= v * theory::t_p(p) { w0 } else { 0.0 };
            let e3v = if u.abs() <= v * theory::t_p(p) { u } else { 0.0 };
            m1 += e1v * e1v;
            m2 += e2v * e2v;
            m3 += e3v * e3v;
        }
        let nf = n as f64;
        r.row(vec![
            format!("{p:.1}"),
            format!("{:.4}", theory::mse_prune(p, s2)),
            format!("{:.4}", theory::e1(p, s2)),
            format!("{:.4}", theory::e2(p, s2, t2)),
            format!("{:.4}", theory::e3(p, s2, t2)),
            format!("{:.4}", m1 / nf),
            format!("{:.4}", m2 / nf),
            format!("{:.4}", m3 / nf),
            format!("{:.4}", theory::mse_prune_svd_bound(p, s2, 16, 64, 64)),
        ]);
    }
    r.note(format!(
        "paper: MSE(0.5) ≈ 0.072σ²; measured closed form = {:.4}",
        theory::mse_prune(0.5, 1.0)
    ));
    r.note("E1 ≤ E2 and E1 ≤ E3 hold everywhere (the paper's Method-1 claim).");
    r.note(format!(
        "paper's secondary claim E3 ≤ E2 fails for large τ²: e.g. p=0.55, σ²=0.5, τ²=2 → E2−E3 = {:.4} (<0); its Comparison step actually derives E2−E1 (see prune::theory docs)",
        theory::e2_minus_e3(0.55, 0.5, 2.0)
    ));
    r.emit(&ctx.results_dir)
}

fn t2_f(t2: f64) -> f64 {
    t2
}

// ---------------------------------------------------------------------------
// Table 1: qualitative feature matrix
// ---------------------------------------------------------------------------

fn table1(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table1",
        "Feature matrix (paper Table 1)",
        &["Method", "Performance", "Model", "Speedup"],
    );
    for b in [Baseline::Losa, Baseline::SparseLora, Baseline::Salr] {
        let perf = match b {
            Baseline::Losa => "Low",
            _ => "High",
        };
        r.row(vec![
            b.name().to_string(),
            perf.to_string(),
            if b.deploys_sparse() { "Sparse" } else { "Dense" }.to_string(),
            if b.claims_speedup() { "Y" } else { "N" }.to_string(),
        ]);
    }
    r.note("Performance column validated quantitatively by table2.");
    r.emit(&ctx.results_dir)
}

// ---------------------------------------------------------------------------
// Table 2: benchmark accuracy across methods @50% sparsity
// ---------------------------------------------------------------------------

fn table2(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table2",
        "Accuracy @50% sparsity (paper Table 2; MCQ≈MMLU, Math≈GSM8K)",
        &["Method", "MCQ acc", "Math acc", "Sparsity"],
    );
    let baselines = [
        Baseline::Pretrained,
        Baseline::Lora,
        Baseline::Losa,
        Baseline::SparseLora,
        Baseline::DeepSparse,
        Baseline::Salr,
    ];
    for b in baselines {
        let mut accs = Vec::new();
        for task in [Task::Mcq, Task::Math] {
            let key = RunKey {
                baseline: b,
                task,
                sparsity: 0.5,
            };
            let (spec, adapters, _) = ctx.run(&key)?;
            accs.push(ctx.accuracy(&spec, &adapters, task)?);
        }
        let sparsity = if b.deploys_sparse() { "50%" } else { "-" };
        r.row(vec![
            b.name().to_string(),
            pct(accs[0]),
            pct(accs[1]),
            sparsity.to_string(),
        ]);
    }
    r.note("paper (Llama3-8B): LoRA 69.2/79.5, LoSA 64.4/71.4, SparseLoRA 69.0/72.0, DeepSparse 60.4/47.9, SALR 68.2/79.5");
    r.note("expected shape: SALR ≈ LoRA > {LoSA, DeepSparse}; SparseLoRA matches on MCQ, degrades on Math.");
    r.emit(&ctx.results_dir)
}

// ---------------------------------------------------------------------------
// Table 3: fine-tuning memory + throughput + compression
// ---------------------------------------------------------------------------

fn table3(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table3",
        "Fine-tuning footprint (paper Table 3)",
        &["Method", "step ms", "GFLOP/s", "Δ            RSS MB", "# Comp"],
    );
    let base = ctx.base_model()?;
    let steps = 12usize;
    let data = ctx.task_data(Task::Math);
    for b in [Baseline::Lora, Baseline::Losa, Baseline::Salr] {
        let mut spec = BaselineSpec::build(&ctx.cfg, &base, b, 0.5, 41);
        let tc = TrainConfig {
            steps,
            lr: 1e-3,
            seed: 5,
            log_every: 0,
            mask_refresh: 0,
            ..Default::default()
        };
        let rss_before = crate::util::mem::rss_bytes();
        let report = finetune(&ctx.runtime, &ctx.cfg, &mut spec, &data, &tc)?;
        let rss_after = crate::util::mem::rss_bytes();
        let step_ms = report.train_secs / steps as f64 * 1e3;
        let flops = flops_per_step(&ctx.cfg, b);
        let comp = compression_rate(ctx, &spec)?;
        r.row(vec![
            b.name().to_string(),
            f2(step_ms),
            f2(flops / (report.train_secs / steps as f64) / 1e9),
            f2((rss_after.saturating_sub(rss_before)) as f64 / 1e6),
            format!("{comp:.1}x"),
        ]);
    }
    r.note("paper: LoRA 26.7GB/91.9TF, LoSA 27.1GB/74.5TF, SALR 19.2GB/89.2TF, 2.0x comp @50%");
    r.note("expected shape: LoSA slowest (materializes ΔW=AB densely per layer per step); SALR ≈ LoRA throughput; 2x compression.");
    r.emit(&ctx.results_dir)
}

/// Analytic FLOPs per optimization step (adapted linears only — the terms
/// that differ across methods).
fn flops_per_step(cfg: &crate::runtime::ModelCfg, b: Baseline) -> f64 {
    let tokens = (cfg.batch_size * cfg.max_seq_len) as f64;
    let mut fl = 0.0;
    for name in cfg.adapted_layers() {
        let lin = name.split('.').nth(1).unwrap();
        let (d_in, d_out) = cfg.linear_shape(lin);
        let (d_in, d_out) = (d_in as f64, d_out as f64);
        let r = cfg.rank as f64;
        // Frozen base: fwd (2) + input-grad (2) MACs.
        fl += 4.0 * tokens * d_in * d_out;
        // Adapters: fwd + full bwd (weight grads) = 6 on both factors.
        fl += 6.0 * tokens * r * (d_in + d_out);
        match b {
            Baseline::Losa => {
                // ΔW = A·B materialization + mask each step (the paper's
                // charged inefficiency).
                fl += 2.0 * r * d_in * d_out + d_in * d_out;
            }
            Baseline::Salr => {
                let rr = cfg.residual_rank as f64;
                fl += 6.0 * tokens * rr * (d_in + d_out);
            }
            _ => {}
        }
    }
    fl
}

/// Serialized compression of the deployed model vs dense f32.
fn compression_rate(ctx: &ExpContext, spec: &BaselineSpec) -> Result<f64> {
    let dense_bytes = spec.params.dense_bytes() as f64;
    let adapted: std::collections::HashSet<String> =
        ctx.cfg.adapted_layers().into_iter().collect();
    let path = ctx.results_dir.join("cache").join(format!(
        "size_probe_{}.salr",
        spec.baseline.name().replace([' ', '(', ')'], "-")
    ));
    let enc = |name: &str, _t: &Tensor| -> Encoding {
        if adapted.contains(name) && spec.baseline.deploys_sparse() {
            Encoding::Bitmap
        } else {
            Encoding::Dense
        }
    };
    let bytes = save_model(&path, &spec.params, enc)? as f64;
    let _ = std::fs::remove_file(&path);
    Ok(dense_bytes / bytes)
}

// ---------------------------------------------------------------------------
// Table 4: inference accuracy + throughput under 2:4
// ---------------------------------------------------------------------------

fn table4(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table4",
        "Inference under 2:4 sparsity (paper Table 4)",
        &["Method (sparsity)", "Math acc", "tokens/s", "speedup"],
    );
    let test = MathTask::finetune().test_examples(ctx.scale.eval_n.min(32));
    let mut base_tps = 0.0f64;
    for (label, b, nm) in [
        ("LoRA (N/A)", Baseline::Lora, None),
        ("SparseLoRA (N/A)", Baseline::SparseLora, None),
        ("LoSA (2:4)", Baseline::Losa, Some(NmPattern::TWO_FOUR)),
        ("SALR (2:4)", Baseline::Salr, Some(NmPattern::TWO_FOUR)),
    ] {
        let key = RunKey {
            baseline: b,
            task: Task::Math,
            sparsity: 0.5,
        };
        let (spec, mut adapters, _) = ctx.run(&key)?;
        // SALR's deploy-time N:M re-prune *recaptures* the newly pruned
        // mass in the residual adapter (Theorem 3 applied at deployment) —
        // the mechanism LoSA lacks.
        if b == Baseline::Salr && nm.is_some() {
            recapture_nm_residual(ctx, &spec, &mut adapters, NmPattern::TWO_FOUR);
        }
        let engine = deploy_engine(&ctx.cfg, &spec, &adapters, nm)?;
        let (acc, _) = super::math_accuracy(&engine, &test, ctx.cfg.batch_size, 6);
        let tps = measure_decode_tps(&engine, ctx.cfg.batch_size, 24);
        if base_tps == 0.0 {
            base_tps = tps;
        }
        r.row(vec![
            label.to_string(),
            pct(acc),
            f2(tps),
            format!("{:.2}x", tps / base_tps),
        ]);
    }
    r.note("paper (RTX4090): LoRA 79.5/60.1 t/s, SparseLoRA 72/60.1, LoSA 69.4/113.5 (1.9x), SALR 78.9/104.9 (1.7x)");
    r.note("expected shape: sparse deployments faster; SALR holds accuracy via residual recapture, LoSA drops.");
    r.emit(&ctx.results_dir)
}

/// Fold the N:M re-pruning error back into the residual adapter:
/// res' = truncated_svd(res·resᵀ-product + (Ŵ − NM(Ŵ)), r).
fn recapture_nm_residual(
    ctx: &ExpContext,
    spec: &BaselineSpec,
    adapters: &mut ParamStore,
    pat: NmPattern,
) {
    for name in ctx.cfg.adapted_layers() {
        let w_hat = spec.params.get(&name).unwrap();
        let mut w_nm = w_hat.clone();
        crate::prune::prune_nm(&mut w_nm, pat);
        let extra = sub(w_hat, &w_nm);
        let (ra_k, rb_k) = (format!("{name}.res_a"), format!("{name}.res_b"));
        if let (Some(ra), Some(rb)) = (adapters.get(&ra_k), adapters.get(&rb_k)) {
            let old = matmul(ra, rb);
            let target = crate::tensor::add(&old, &extra);
            let svd = crate::linalg::truncated_svd(&target, ctx.cfg.residual_rank, 97);
            let (na, nb) = svd.into_adapter();
            adapters.insert(&ra_k, na);
            adapters.insert(&rb_k, nb);
        }
    }
}

/// Sustained batched decode throughput (tokens/s).
fn measure_decode_tps(engine: &Engine, batch: usize, new_tokens: usize) -> f64 {
    let cfg = &engine.weights.cfg;
    let prompt_len = (cfg.max_seq_len / 2).min(cfg.max_seq_len - new_tokens - 1);
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|i| (0..prompt_len).map(|j| ((i * 31 + j * 7) % 200 + 32) as i32).collect())
        .collect();
    // Warm up once, then measure.
    let _ = engine.generate_batch(&prompts, 4);
    let t0 = Instant::now();
    let _ = engine.generate_batch(&prompts, new_tokens);
    let secs = t0.elapsed().as_secs_f64();
    (batch * new_tokens) as f64 / secs
}

// ---------------------------------------------------------------------------
// Table 5: residual frozen vs trainable
// ---------------------------------------------------------------------------

fn table5(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table5",
        "Residual-update ablation on MCQ (paper Table 5)",
        &["Method", "MCQ acc"],
    );
    for b in [
        Baseline::Lora,
        Baseline::SalrFrozenResidual,
        Baseline::Salr,
    ] {
        let key = RunKey {
            baseline: b,
            task: Task::Mcq,
            sparsity: 0.5,
        };
        let (spec, adapters, _) = ctx.run(&key)?;
        let acc = ctx.accuracy(&spec, &adapters, Task::Mcq)?;
        r.row(vec![b.name().to_string(), pct(acc)]);
    }
    r.note("paper (Llama3-8B MMLU): LoRA 69.2, frozen 66.8 (−2.4), trainable 68.2");
    r.note("expected shape: frozen < trainable ≤ LoRA.");
    r.emit(&ctx.results_dir)
}

// ---------------------------------------------------------------------------
// Table 6: QSALR (20% sparsity + NF4)
// ---------------------------------------------------------------------------

fn table6(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table6",
        "QSALR: 20% sparsity + NF4 (paper Table 6)",
        &["Method", "Math acc", "Model size", "ratio"],
    );
    // LoRA dense reference.
    let key = RunKey {
        baseline: Baseline::Lora,
        task: Task::Math,
        sparsity: 0.0,
    };
    let (spec, adapters, _) = ctx.run(&key)?;
    let acc_lora = ctx.accuracy(&spec, &adapters, Task::Math)?;
    let dense_path = ctx.results_dir.join("lora_dense_model.salr");
    let dense_bytes = save_model(&dense_path, &spec.params, |_, _| Encoding::Dense)?;

    // QSALR: 20% static sparsity + NF4 on the kept values.
    let key_q = RunKey {
        baseline: Baseline::Salr,
        task: Task::Math,
        sparsity: 0.2,
    };
    let (spec_q, adapters_q, _) = ctx.run(&key_q)?;
    let adapted: std::collections::HashSet<String> =
        ctx.cfg.adapted_layers().into_iter().collect();
    let q_path = ctx.results_dir.join("qsalr_model.salr");
    let q_bytes = save_model(&q_path, &spec_q.params, |name, t| {
        if adapted.contains(name) {
            Encoding::SparseNf4
        } else if t.ndim() == 2 {
            Encoding::Nf4
        } else {
            Encoding::Dense
        }
    })?;
    // Accuracy with quantized+sparse weights actually deployed.
    let dequant = crate::model::load_model(&q_path)?;
    let mut spec_deq = spec_q;
    spec_deq.params = dequant;
    let acc_q = ctx.accuracy(&spec_deq, &adapters_q, Task::Math)?;

    r.row(vec![
        "LoRA".into(),
        pct(acc_lora),
        crate::util::human_bytes(dense_bytes),
        "1.0x".into(),
    ]);
    r.row(vec![
        "QSALR (20% + NF4)".into(),
        pct(acc_q),
        crate::util::human_bytes(q_bytes),
        format!("{:.1}x", dense_bytes as f64 / q_bytes as f64),
    ]);
    r.note("paper: DeepSeek-V2 31.8→6.5 GB (−0.6 acc); Mixtral 93.9→19.2 GB (0.0 acc) — ~5x");
    r.emit(&ctx.results_dir)
}

// ---------------------------------------------------------------------------
// Table 7: sparsity sweep
// ---------------------------------------------------------------------------

fn table7(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "table7",
        "Sparsity–accuracy trade-off (paper Table 7)",
        &["Method (sparsity)", "Math acc"],
    );
    let key = RunKey {
        baseline: Baseline::Lora,
        task: Task::Math,
        sparsity: 0.0,
    };
    let (spec, adapters, _) = ctx.run(&key)?;
    r.row(vec!["LoRA (N/A)".into(), pct(ctx.accuracy(&spec, &adapters, Task::Math)?)]);
    for p in [0.1, 0.3, 0.5] {
        let key = RunKey {
            baseline: Baseline::Salr,
            task: Task::Math,
            sparsity: p,
        };
        let (spec, adapters, _) = ctx.run(&key)?;
        let acc = ctx.accuracy(&spec, &adapters, Task::Math)?;
        r.row(vec![format!("SALR ({:.0}%)", p * 100.0), pct(acc)]);
    }
    r.note("paper: LoRA 79.5; SALR 79.5/80.1/79.5 at 10/30/50% — flat up to 50%.");
    r.emit(&ctx.results_dir)
}

// ---------------------------------------------------------------------------
// Fig 1: memory–accuracy trade-off
// ---------------------------------------------------------------------------

fn fig1(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "fig1",
        "Memory–accuracy trade-off @50% (paper Fig. 1)",
        &["Method", "Math acc", "Model bytes", "rel size"],
    );
    let adapted: std::collections::HashSet<String> =
        ctx.cfg.adapted_layers().into_iter().collect();
    let mut dense_bytes = 0u64;
    for (b, p) in [
        (Baseline::Lora, 0.0),
        (Baseline::Losa, 0.5),
        (Baseline::Salr, 0.5),
    ] {
        let key = RunKey {
            baseline: b,
            task: Task::Math,
            sparsity: p,
        };
        let (spec, adapters, _) = ctx.run(&key)?;
        let acc = ctx.accuracy(&spec, &adapters, Task::Math)?;
        // Serialize the deployable model (LoSA: masked merged weights).
        let path = ctx.results_dir.join(format!("fig1_{}.salr", b.name().replace(' ', "-")));
        let store = deploy_store(ctx, &spec, &adapters)?;
        let bytes = save_model(&path, &store, |name, t| {
            if b.deploys_sparse() && adapted.contains(name) && t.ndim() == 2 {
                Encoding::Bitmap
            } else {
                Encoding::Dense
            }
        })?;
        if b == Baseline::Lora {
            dense_bytes = bytes;
        }
        r.row(vec![
            b.name().to_string(),
            pct(acc),
            crate::util::human_bytes(bytes),
            format!("{:.2}", bytes as f64 / dense_bytes as f64),
        ]);
    }
    r.note("paper: LoRA 79.5 @15.5GB; SALR 79.5 @7.98GB; LoSA 71.4 @~8GB");
    r.note("expected shape: SALR keeps LoRA accuracy at ~55% the bytes; LoSA same bytes, lower accuracy.");
    r.emit(&ctx.results_dir)
}

/// The store a baseline actually ships (merged for LoSA, pruned + factored
/// adapters folded separately for SALR — here we fold adapters dense for a
/// conservative size).
fn deploy_store(ctx: &ExpContext, spec: &BaselineSpec, adapters: &ParamStore) -> Result<ParamStore> {
    let mut store = spec.params.clone();
    if spec.baseline == Baseline::Losa {
        let masks = spec.masks.as_ref().unwrap();
        let s = ctx.cfg.lora_scaling();
        for name in ctx.cfg.adapted_layers() {
            let w = store.get_mut(&name).unwrap();
            if let (Some(a), Some(b)) = (
                adapters.get(&format!("{name}.lora_a")),
                adapters.get(&format!("{name}.lora_b")),
            ) {
                let mut ab = matmul(a, b);
                ab.scale(s);
                crate::tensor::axpy(w, 1.0, &ab);
            }
            let masked = crate::tensor::mul(w, masks.get(&format!("{name}.mask")).unwrap());
            *w = masked;
        }
    } else {
        // Ship factored adapters alongside (they are small).
        for (k, v) in adapters.iter() {
            store.insert(k, v.clone());
        }
    }
    Ok(store)
}

// ---------------------------------------------------------------------------
// Fig 3: singular-energy spectra of the residual corrections
// ---------------------------------------------------------------------------

fn fig3(ctx: &ExpContext) -> Result<()> {
    let mut r = Report::new(
        "fig3",
        "Cumulative singular energy of residual corrections (paper Fig. 3)",
        &["rank i", "LoSA cum-energy", "SALR cum-energy"],
    );
    // The *correction matrix* each method uses to compensate pruning, for a
    // representative layer: LoSA has only its LoRA product s·A·B; SALR has
    // the concatenated LoRA + sparsity-preservation residual adapters.
    let layer = "layer0.w_in";
    let s_scale = ctx.cfg.lora_scaling();
    let correction_of = |b: Baseline| -> Result<Tensor> {
        let key = RunKey {
            baseline: b,
            task: Task::Math,
            sparsity: 0.5,
        };
        let (_spec, adapters, _) = ctx.run(&key)?;
        let a = adapters.get(&format!("{layer}.lora_a")).unwrap();
        let bb = adapters.get(&format!("{layer}.lora_b")).unwrap();
        let mut corr = matmul(a, bb);
        corr.scale(s_scale);
        if let (Some(ra), Some(rb)) = (
            adapters.get(&format!("{layer}.res_a")),
            adapters.get(&format!("{layer}.res_b")),
        ) {
            let res = matmul(ra, rb);
            corr = crate::tensor::add(&corr, &res);
        }
        Ok(corr)
    };
    let losa_corr = correction_of(Baseline::Losa)?;
    let salr_corr = correction_of(Baseline::Salr)?;
    let ce_losa = jacobi_svd(&losa_corr).cumulative_energy();
    let ce_salr = jacobi_svd(&salr_corr).cumulative_energy();
    let q = ce_losa.len().min(ce_salr.len());
    let i99 = |ce: &[f64]| ce.iter().position(|&e| e >= 0.99).map(|i| i + 1).unwrap_or(q);
    for i in (0..q.min(48)).step_by(2) {
        r.row(vec![
            format!("{}", i + 1),
            format!("{:.4}", ce_losa[i]),
            format!("{:.4}", ce_salr[i]),
        ]);
    }
    r.note(format!(
        "i_0.99: LoSA = {}, SALR = {} (paper: i99_LoSA ≪ i99_SALR — SALR retains a larger spectrum tail via the rank-r residual, Theorem 3)",
        i99(&ce_losa),
        i99(&ce_salr)
    ));
    r.emit(&ctx.results_dir)
}
