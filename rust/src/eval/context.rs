//! Shared experiment context: pretraining and fine-tuning runs, cached on
//! disk so the table drivers can share models instead of retraining.

use crate::data::{MathTask, McqTask};
use crate::infer::{Backend, Engine, EngineWeights};
use crate::model::{load_model, save_model, Encoding, ParamStore};
use crate::prune::NmPattern;
use crate::runtime::{ModelCfg, Runtime};
use crate::salr::{Baseline, BaselineSpec};
use crate::train::{finetune, pretrain, FinetuneData, TrainConfig};
use anyhow::{Context as _, Result};
use std::path::PathBuf;

/// Identifies one fine-tuning run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunKey {
    pub baseline: Baseline,
    pub task: Task,
    /// Prune ratio (ignored for dense baselines).
    pub sparsity: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Math,
    Mcq,
}

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Math => "math",
            Task::Mcq => "mcq",
        }
    }
}

impl RunKey {
    fn cache_tag(&self) -> String {
        format!(
            "{}_{}_{}",
            self.baseline.name().replace([' ', '(', ')'], "-"),
            self.task.name(),
            (self.sparsity * 100.0) as usize
        )
    }
}

/// Environment-tunable experiment scales.
pub struct ExpScale {
    pub pretrain_steps: usize,
    pub finetune_steps: usize,
    pub eval_n: usize,
    pub lr: f32,
}

impl ExpScale {
    pub fn from_env() -> ExpScale {
        let get = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        ExpScale {
            pretrain_steps: get("SALR_PRETRAIN_STEPS", 2000),
            finetune_steps: get("SALR_STEPS", 500),
            eval_n: get("SALR_EVAL_N", 96),
            lr: std::env::var("SALR_LR")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(2e-3),
        }
    }
}

/// The context every experiment driver runs in.
pub struct ExpContext {
    pub runtime: Runtime,
    pub cfg: ModelCfg,
    pub scale: ExpScale,
    pub results_dir: PathBuf,
    cache_dir: PathBuf,
}

impl ExpContext {
    pub fn new(artifact_dir: &str, config: &str, results_dir: &str) -> Result<ExpContext> {
        let runtime = Runtime::new(artifact_dir)?;
        let cfg = runtime.manifest().config(config)?.clone();
        let results_dir = PathBuf::from(results_dir);
        let cache_dir = results_dir.join("cache");
        std::fs::create_dir_all(&cache_dir)?;
        Ok(ExpContext {
            runtime,
            cfg,
            scale: ExpScale::from_env(),
            results_dir,
            cache_dir,
        })
    }

    fn cache_path(&self, tag: &str) -> PathBuf {
        self.cache_dir.join(format!(
            "{}_{}_s{}.salr",
            self.cfg.name, tag, self.scale.finetune_steps
        ))
    }

    /// The pretrained base model (cached on disk).
    pub fn base_model(&self) -> Result<ParamStore> {
        let path = self.cache_path(&format!("base_p{}", self.scale.pretrain_steps));
        if path.exists() {
            log::info!("loading cached base model {path:?}");
            return load_model(&path);
        }
        log::info!(
            "pretraining base model ({} steps)…",
            self.scale.pretrain_steps
        );
        let tc = TrainConfig {
            steps: self.scale.pretrain_steps,
            lr: self.scale.lr,
            seed: 11,
            log_every: 100,
            ..Default::default()
        };
        let (params, losses) = pretrain(&self.runtime, &self.cfg, &tc)?;
        log::info!(
            "pretrain done: loss {:.3} → {:.3}",
            losses.first().copied().unwrap_or(0.0),
            losses.last().copied().unwrap_or(0.0)
        );
        save_model(&path, &params, |_, _| Encoding::Dense)?;
        Ok(params)
    }

    /// Fine-tune (or load cached) a baseline; returns (spec, adapters,
    /// final losses). The spec carries the pruned/masked frozen state.
    pub fn run(&self, key: &RunKey) -> Result<(BaselineSpec, ParamStore, Vec<f32>)> {
        let base = self.base_model()?;
        let mut spec = BaselineSpec::build(&self.cfg, &base, key.baseline, key.sparsity, 21);
        if key.baseline == Baseline::Pretrained {
            return Ok((spec, ParamStore::new(), Vec::new()));
        }
        let path = self.cache_path(&key.cache_tag());
        if path.exists() {
            log::info!("loading cached run {path:?}");
            let adapters = load_model(&path)?;
            return Ok((spec, adapters, Vec::new()));
        }
        let data = self.task_data(key.task);
        let tc = TrainConfig {
            steps: self.scale.finetune_steps,
            lr: self.scale.lr,
            seed: 31,
            log_every: 100,
            ..Default::default()
        };
        log::info!(
            "fine-tuning {} on {} at p={} ({} steps)…",
            key.baseline.name(),
            key.task.name(),
            key.sparsity,
            tc.steps
        );
        let report = finetune(&self.runtime, &self.cfg, &mut spec, &data, &tc)?;
        log::info!(
            "finetune[{}] done: loss {:.3} → {:.3} (η={:.2e}, {:.1}s)",
            key.baseline.name(),
            report.losses.first().copied().unwrap_or(0.0),
            report.losses.last().copied().unwrap_or(0.0),
            report.eta,
            report.train_secs
        );
        save_model(&path, &report.adapters, |_, _| Encoding::Dense)?;
        Ok((spec, report.adapters, report.losses))
    }

    /// The fine-tuning dataset for a task.
    pub fn task_data(&self, task: Task) -> FinetuneData {
        match task {
            Task::Math => FinetuneData::Math(MathTask::finetune().train_examples(4096)),
            Task::Mcq => FinetuneData::Mcq(McqTask::default_task().train_examples(4096)),
        }
    }

    /// Accuracy of a deployed run on a task's held-out set.
    pub fn accuracy(&self, spec: &BaselineSpec, adapters: &ParamStore, task: Task) -> Result<f64> {
        let engine = deploy_engine(&self.cfg, spec, adapters, None)?;
        Ok(match task {
            Task::Math => {
                let test = MathTask::finetune().test_examples(self.scale.eval_n);
                super::math_accuracy(&engine, &test, self.cfg.batch_size, 6).0
            }
            Task::Mcq => {
                let test = McqTask::default_task().test_examples(self.scale.eval_n);
                super::mcq_accuracy(&engine, &test).0
            }
        })
    }
}

/// Build the deployment engine for a fine-tuned baseline.
/// `nm` re-prunes to an N:M pattern (Table 4's 2:4 protocol).
/// The resident weight format for sparse deployments comes from
/// `SALR_WEIGHT_FORMAT` (default bitmap); the CLI's `--weight-format`
/// flag goes through [`deploy_engine_with_format`].
pub fn deploy_engine(
    cfg: &ModelCfg,
    spec: &BaselineSpec,
    adapters: &ParamStore,
    nm: Option<NmPattern>,
) -> Result<Engine> {
    deploy_engine_with_format(
        cfg,
        spec,
        adapters,
        nm,
        crate::model::WeightFormat::env_default(),
    )
}

/// [`deploy_engine`] with an explicit resident weight format for the
/// sparse deployments (dense baselines ignore it — their weights are
/// merged dense matrices by definition).
pub fn deploy_engine_with_format(
    cfg: &ModelCfg,
    spec: &BaselineSpec,
    adapters: &ParamStore,
    nm: Option<NmPattern>,
    fmt: crate::model::WeightFormat,
) -> Result<Engine> {
    let weights = match spec.baseline {
        Baseline::Pretrained => EngineWeights::dense_merged(cfg, &spec.params, None),
        Baseline::Lora | Baseline::SparseLora => {
            EngineWeights::dense_merged(cfg, &spec.params, Some(adapters))
        }
        Baseline::Losa => {
            // Deploy the masked merged weights sparsely (zero adapters).
            let mut merged = spec.params.clone();
            let masks = spec.masks.as_ref().context("losa spec missing masks")?;
            let s = cfg.lora_scaling();
            for name in cfg.adapted_layers() {
                let w = merged.get_mut(&name).unwrap();
                if let (Some(a), Some(b)) = (
                    adapters.get(&format!("{name}.lora_a")),
                    adapters.get(&format!("{name}.lora_b")),
                ) {
                    let mut ab = crate::tensor::matmul(a, b);
                    ab.scale(s);
                    crate::tensor::axpy(w, 1.0, &ab);
                }
                let m = masks.get(&format!("{name}.mask")).unwrap();
                let masked = crate::tensor::mul(w, m);
                *w = masked;
            }
            let mut zero_adapters = ParamStore::new();
            for name in cfg.adapted_layers() {
                let lin = name.split('.').nth(1).unwrap();
                let (d_in, d_out) = cfg.linear_shape(lin);
                zero_adapters.insert(
                    &format!("{name}.lora_a"),
                    crate::tensor::Tensor::zeros(&[d_in, 1]),
                );
                zero_adapters.insert(
                    &format!("{name}.lora_b"),
                    crate::tensor::Tensor::zeros(&[1, d_out]),
                );
            }
            return Ok(Engine::new(
                EngineWeights::salr_with_format(cfg, &merged, &zero_adapters, nm, fmt),
                Backend::BitmapPipelined(Default::default()),
            ));
        }
        Baseline::DeepSparse | Baseline::Salr | Baseline::SalrFrozenResidual => {
            EngineWeights::salr_with_format(cfg, &spec.params, adapters, nm, fmt)
        }
    };
    let backend = if spec.baseline.deploys_sparse() {
        Backend::BitmapPipelined(Default::default())
    } else {
        Backend::Dense
    };
    Ok(Engine::new(weights, backend))
}
