//! Benchmark scoring: exact-match generation accuracy (the GSM8K protocol)
//! and choice log-likelihood accuracy (the MMLU protocol), over the native
//! engine — the deployment path.

use crate::data::{grade, tokenize, McqExample, MathExample};
use crate::infer::Engine;
use crate::tensor::Tensor;

/// Exact-match accuracy on arithmetic problems: generate greedily, grade
/// the leading number. Returns (accuracy, per-example correctness).
pub fn math_accuracy(
    engine: &Engine,
    examples: &[MathExample],
    batch: usize,
    max_new: usize,
) -> (f64, Vec<bool>) {
    let mut correct = Vec::with_capacity(examples.len());
    for chunk in examples.chunks(batch.max(1)) {
        let prompts: Vec<Vec<i32>> = chunk.iter().map(|e| tokenize(&e.prompt)).collect();
        let outs = engine.generate_batch(&prompts, max_new);
        for (e, out) in chunk.iter().zip(outs) {
            let text = crate::data::detokenize(&out);
            correct.push(grade(&text, &e.answer));
        }
    }
    let acc = correct.iter().filter(|&&c| c).count() as f64 / correct.len().max(1) as f64;
    (acc, correct)
}

/// Multiple-choice accuracy (cloze scoring, the MMLU protocol): each of
/// the four candidate continuations is scored by its mean token
/// log-likelihood after the prompt; the argmax must be the correct value.
pub fn mcq_accuracy(engine: &Engine, examples: &[McqExample]) -> (f64, Vec<bool>) {
    let mut correct = Vec::with_capacity(examples.len());
    for e in examples {
        let prompt = tokenize(&e.prompt);
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, opt) in e.options.iter().enumerate() {
            let cont = tokenize(opt);
            let mut toks = prompt.clone();
            toks.extend_from_slice(&cont);
            let logits: Tensor = engine.full_logits(&toks);
            // Sum logprob of the continuation tokens (teacher forcing).
            let mut lp = 0.0f32;
            for (j, &t) in cont.iter().enumerate() {
                let row = logits.row(prompt.len() + j - 1);
                // log softmax at the target token.
                let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let lse: f32 = row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
                lp += row[t as usize] - lse;
            }
            let score = lp / cont.len().max(1) as f32;
            if score > best_v {
                best_v = score;
                best = i;
            }
        }
        correct.push(best == e.correct);
    }
    let acc = correct.iter().filter(|&&c| c).count() as f64 / correct.len().max(1) as f64;
    (acc, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{MathTask, McqTask};
    use crate::infer::{Backend, EngineWeights};
    use crate::model::ParamStore;
    use crate::runtime::ModelCfg;
    use crate::util::rng::Rng;

    fn tiny_engine() -> Engine {
        let cfg = ModelCfg {
            name: "t".into(),
            vocab_size: 256,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 64,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 4,
            batch_size: 2,
            ctx_keep: 0.5,
        };
        let mut rng = Rng::new(600);
        let base = ParamStore::init_base(&cfg, &mut rng);
        Engine::new(EngineWeights::dense_merged(&cfg, &base, None), Backend::Dense)
    }

    #[test]
    fn random_model_scores_near_chance() {
        let engine = tiny_engine();
        let math = MathTask::pretrain().test_examples(8);
        let (acc, flags) = math_accuracy(&engine, &math, 4, 4);
        assert_eq!(flags.len(), 8);
        assert!(acc < 0.5, "random weights should not solve math (acc={acc})");
        let mcq = McqTask::default_task().test_examples(12);
        let (acc_mc, _) = mcq_accuracy(&engine, &mcq);
        // Chance is 0.25; allow wide slack for a tiny sample.
        assert!(acc_mc <= 0.8, "acc_mc={acc_mc}");
    }
}
