//! SALR model surgery: prune the frozen base weights with a static mask
//! (Theorem 2, Method 1), capture each layer's pruning residual in a
//! rank-r adapter via truncated SVD (Theorem 3), and report the per-layer
//! MSE against the theoretical bound.

use crate::linalg::truncated_svd;
use crate::model::ParamStore;
use crate::prune::theory;
use crate::prune::{global_threshold, prune_with_threshold};
use crate::runtime::ModelCfg;
use crate::tensor::{mse, sub, Tensor};

/// Per-layer diagnostics from the build.
#[derive(Clone, Debug)]
pub struct SalrLayerStats {
    pub name: String,
    pub sparsity: f64,
    /// Per-entry MSE of pruning alone: ‖W − Ŵ‖² / dk.
    pub mse_prune: f64,
    /// Per-entry MSE after the rank-r residual correction.
    pub mse_after_svd: f64,
    /// Theorem-3 bound `(1 − r/min(d,k))·MSE_prune` for this layer.
    pub theorem3_bound: f64,
    /// Cumulative singular energy of the residual at rank r.
    pub energy_at_r: f64,
}

/// Result of applying SALR to a model.
pub struct SalrBuild {
    /// Base params with adapted weights pruned in place.
    pub params: ParamStore,
    /// Residual adapters (`{layer}.res_a/res_b`), SVD-initialized.
    pub residual_adapters: ParamStore,
    /// The global magnitude threshold used.
    pub threshold: f32,
    pub stats: Vec<SalrLayerStats>,
}

impl SalrBuild {
    /// Mean per-entry MSE across layers, before/after the SVD correction.
    pub fn mean_mse(&self) -> (f64, f64) {
        let n = self.stats.len().max(1) as f64;
        (
            self.stats.iter().map(|s| s.mse_prune).sum::<f64>() / n,
            self.stats.iter().map(|s| s.mse_after_svd).sum::<f64>() / n,
        )
    }
}

/// Apply SALR to the adapted linear layers of `params` at global prune
/// ratio `p`, capturing residuals at rank `cfg.residual_rank`.
pub fn build_salr(cfg: &ModelCfg, params: &ParamStore, p: f64, seed: u64) -> SalrBuild {
    let mut out = params.clone();
    let names = cfg.adapted_layers();
    // Global threshold across the adapted weights only (embeddings, norms
    // and the LM head stay dense — the paper prunes the transformer
    // linears).
    let views: Vec<&Tensor> = names.iter().map(|n| params.get(n).unwrap()).collect();
    let threshold = global_threshold(&views, p);

    let mut residual_adapters = ParamStore::new();
    let mut stats = Vec::with_capacity(names.len());
    for (i, name) in names.iter().enumerate() {
        let w = params.get(name).unwrap();
        let mut w_hat = w.clone();
        prune_with_threshold(&mut w_hat, threshold);
        // Residual E = W − Ŵ holds exactly the pruned (small) entries.
        let e = sub(w, &w_hat);
        let r = cfg.residual_rank.min(w.rows()).min(w.cols());
        let svd = truncated_svd(&e, r, seed ^ (i as u64) << 8);
        let energy_at_r = svd.cumulative_energy().last().copied().unwrap_or(0.0)
            * (svd_energy_fraction(&e, &svd));
        let (ra, rb) = svd.into_adapter();
        let e_rec = crate::tensor::matmul(&ra, &rb);
        let mse_prune = mse(w, &w_hat);
        let mse_after = mse(&e, &e_rec);
        let q = w.rows().min(w.cols());
        stats.push(SalrLayerStats {
            name: name.clone(),
            sparsity: w_hat.sparsity(),
            mse_prune,
            mse_after_svd: mse_after,
            theorem3_bound: (1.0 - r as f64 / q as f64) * mse_prune,
            energy_at_r,
        });
        out.insert(name, w_hat);
        residual_adapters.insert(&format!("{name}.res_a"), ra);
        residual_adapters.insert(&format!("{name}.res_b"), rb);
    }
    SalrBuild {
        params: out,
        residual_adapters,
        threshold,
        stats,
    }
}

/// Fraction of ‖E‖² captured by the truncated factors.
fn svd_energy_fraction(e: &Tensor, svd: &crate::linalg::Svd) -> f64 {
    let total = e.sq_sum();
    if total <= 0.0 {
        return 1.0;
    }
    let captured: f64 = svd.s.iter().map(|&x| (x as f64).powi(2)).sum();
    (captured / total).min(1.0)
}

/// Closed-form sanity reference: Theorem 1 MSE at ratio `p` for unit-σ²
/// weights, scaled by the empirical variance of the tensor.
pub fn theoretical_mse(w: &Tensor, p: f64) -> f64 {
    let var = w.sq_sum() / w.len().max(1) as f64;
    theory::mse_prune(p, var)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 16,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 8,
            batch_size: 2,
            ctx_keep: 0.5,
        }
    }

    #[test]
    fn build_achieves_global_sparsity() {
        let cfg = test_cfg();
        let mut rng = Rng::new(310);
        let params = ParamStore::init_base(&cfg, &mut rng);
        let build = build_salr(&cfg, &params, 0.5, 7);
        let names = cfg.adapted_layers();
        let total: usize = names.iter().map(|n| build.params.get(n).unwrap().len()).sum();
        let zeros: usize = total
            - names
                .iter()
                .map(|n| build.params.get(n).unwrap().nnz())
                .sum::<usize>();
        let sparsity = zeros as f64 / total as f64;
        assert!((sparsity - 0.5).abs() < 0.02, "sparsity={sparsity}");
        // Non-adapted tensors untouched.
        assert_eq!(build.params.get("embed").unwrap(), params.get("embed").unwrap());
    }

    #[test]
    fn svd_residual_reduces_mse_and_respects_bound() {
        let cfg = test_cfg();
        let mut rng = Rng::new(311);
        let params = ParamStore::init_base(&cfg, &mut rng);
        let build = build_salr(&cfg, &params, 0.5, 8);
        for s in &build.stats {
            assert!(
                s.mse_after_svd <= s.mse_prune + 1e-12,
                "{}: svd must not increase error",
                s.name
            );
            // Theorem 3: the residual correction obeys the worst-case bound
            // (with slack for the randomized SVD).
            assert!(
                s.mse_after_svd <= s.theorem3_bound * 1.1 + 1e-9,
                "{}: {} > bound {}",
                s.name,
                s.mse_after_svd,
                s.theorem3_bound
            );
        }
        let (before, after) = build.mean_mse();
        assert!(after < before);
    }

    #[test]
    fn empirical_mse_matches_theorem1_closed_form() {
        // Gaussian layers + global 50% prune → per-entry MSE ≈ 0.072·σ²
        // (the paper's headline Theorem-1 number).
        let cfg = test_cfg();
        let mut rng = Rng::new(312);
        let params = ParamStore::init_base(&cfg, &mut rng);
        let build = build_salr(&cfg, &params, 0.5, 9);
        for s in &build.stats {
            let w = params.get(&s.name).unwrap();
            let theo = theoretical_mse(w, 0.5);
            // Within 35%: the global threshold is shared across layers with
            // different variances (wq..wo have σ²=1/d_model, w_out 1/d_ff),
            // so per-layer ratios deviate from the single-σ formula.
            assert!(
                s.mse_prune < theo * 3.0 && s.mse_prune > theo * 0.2,
                "{}: emp={} theo={}",
                s.name,
                s.mse_prune,
                theo
            );
        }
    }

    #[test]
    fn residual_adapter_shapes() {
        let cfg = test_cfg();
        let mut rng = Rng::new(313);
        let params = ParamStore::init_base(&cfg, &mut rng);
        let build = build_salr(&cfg, &params, 0.3, 10);
        let ra = build.residual_adapters.get("layer0.w_in.res_a").unwrap();
        let rb = build.residual_adapters.get("layer0.w_in.res_b").unwrap();
        assert_eq!(ra.shape(), &[32, 8]);
        assert_eq!(rb.shape(), &[8, 64]);
        assert_eq!(build.residual_adapters.len(), 12);
    }

    #[test]
    fn zero_ratio_is_identity() {
        let cfg = test_cfg();
        let mut rng = Rng::new(314);
        let params = ParamStore::init_base(&cfg, &mut rng);
        let build = build_salr(&cfg, &params, 0.0, 11);
        for name in cfg.adapted_layers() {
            assert_eq!(build.params.get(&name).unwrap(), params.get(&name).unwrap());
        }
        let (before, _) = build.mean_mse();
        assert!(before.abs() < 1e-12);
    }
}
