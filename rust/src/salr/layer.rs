//! A deployed SALR linear layer: compressed base weight (bitmap-sparse or
//! bitmap+NF4, held as a [`WeightStore`] — never a resident dense matrix
//! in compressed modes) + concatenated low-rank adapters, executed through
//! the compressed-weight GEMM tiers.

use crate::gemm::fused::AdapterStack;
use crate::gemm::pipeline::{salr_gemm_pipelined_pool, PipelineConfig};
use crate::model::{WeightStore, WeightView};
use crate::tensor::Tensor;

/// One adapted linear layer in deployment form.
#[derive(Clone, Debug)]
pub struct SalrLayer {
    /// Pruned base weight `Ŵ[d_in, d_out]` in its resident (compressed)
    /// form. GEMMs decode it per tile/panel inside the kernels; no path
    /// through this layer materializes a persistent dense copy.
    pub base: WeightStore,
    /// Concatenated adapters: LoRA (scaled) ‖ residual.
    pub adapters: AdapterStack,
    pub d_in: usize,
    pub d_out: usize,
}

impl SalrLayer {
    /// Assemble from components. The LoRA scaling `s = α/r` is folded into
    /// `A` so the fused GEMM needs no per-adapter scalars.
    pub fn new(
        base: WeightStore,
        lora_a: &Tensor,
        lora_b: &Tensor,
        scaling: f32,
        residual: Option<(&Tensor, &Tensor)>,
    ) -> SalrLayer {
        let (d_in, d_out) = (base.rows(), base.cols());
        let mut a_scaled = lora_a.clone();
        a_scaled.scale(scaling);
        let adapters = match residual {
            Some((ra, rb)) => AdapterStack::concat(&[(&a_scaled, lora_b), (ra, rb)]),
            None => AdapterStack::concat(&[(&a_scaled, lora_b)]),
        };
        SalrLayer {
            base,
            adapters,
            d_in,
            d_out,
        }
    }

    /// `out = x @ Ŵ` for decode-sized batches, dispatching on the resident
    /// representation: both compressed forms take the zero-skipping direct
    /// sparse kernel (walking masks, dequantizing NF4 codes per element);
    /// a dense store takes the packed dense GEMM.
    fn base_direct(&self, x: &[f32], m: usize, out: &mut [f32], pool: &crate::util::pool::WorkerPool) {
        match self.base.view() {
            WeightView::Bitmap(bm) => {
                crate::gemm::sparse::sparse_gemm_direct_pool(x, bm, out, m, pool)
            }
            WeightView::BitmapNf4(snf) => {
                crate::gemm::sparse::sparse_gemm_direct_pool(x, snf, out, m, pool)
            }
            WeightView::Dense(t) => {
                crate::gemm::dense::gemm_f32_pool(x, t.data(), out, m, self.d_in, self.d_out, pool)
            }
        }
    }

    /// `y[m, d_out] = x @ Ŵ + (x A_cat) B_cat`, on the caller's `pool`.
    ///
    /// Dispatches on batch height: decode-sized batches (small m) use the
    /// zero-skipping *direct* sparse kernel — at 50% sparsity it does half
    /// the MACs and half the weight traffic of the dense GEMM, which is
    /// where the paper's inference speedup comes from on this CPU testbed.
    /// Large (prefill-sized) batches use the two-stage pipelined
    /// decode+GEMM, where amortizing the decode across many rows wins.
    ///
    /// `pool` is the engine's own worker pool — threaded down explicitly
    /// so a hot decode step never does a global pool-registry lookup, and
    /// so private per-engine-worker pools (which are *not* in the
    /// registry) are honored on **every** path: the small-m direct kernel
    /// stripes its columns across `pool`, and the pipelined large-m path
    /// runs its stage workers on `pool` too (`cfg.num_threads` no longer
    /// resolves a separate registry pool — the `--threads 1` ablation is
    /// apples-to-apples everywhere). All scratch (the direct kernel's
    /// transposed working set, the fused-adapter intermediate, pipeline
    /// ring slots) comes from the per-worker arena, so a steady-state
    /// forward allocates nothing.
    pub fn forward(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        cfg: PipelineConfig,
        pool: &crate::util::pool::WorkerPool,
    ) {
        const DIRECT_M_MAX: usize = 32;
        if m <= DIRECT_M_MAX {
            self.base_direct(x, m, out, pool);
            self.adapters.apply_fused_acc_pool(x, m, out, pool);
        } else {
            salr_gemm_pipelined_pool(
                x,
                &self.base,
                self.adapters.a_cat.data(),
                self.adapters.b_cat.data(),
                self.adapters.total_rank(),
                out,
                m,
                cfg,
                pool,
            );
        }
    }

    /// `y[m, d_out] = x @ Ŵ` — the sparse base **without** the fused
    /// adapter correction.
    ///
    /// This is the paper-native speculative *drafter*: the pruned base is a
    /// cheap approximation of the full layer (it skips the entire
    /// `(x A_cat) B_cat` fused GEMM, i.e. the LoRA update plus the
    /// truncated-SVD residual correction), and the exact greedy verify pass
    /// through [`SalrLayer::forward`] restores precisely what was dropped.
    /// Draft batches are decode-sized (`m = spec_k ≤ 32` in practice) so
    /// small m takes the zero-skipping direct kernel; larger m takes the
    /// fused pack-decode blocked GEMM (per-tile decode inside the B pack —
    /// no dense scratch copy of Ŵ) — never the pipelined path, whose
    /// decode-amortization setup is wasted on adapter-free work.
    pub fn forward_base_only(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        pool: &crate::util::pool::WorkerPool,
    ) {
        const DIRECT_M_MAX: usize = 32;
        if m <= DIRECT_M_MAX {
            self.base_direct(x, m, out, pool);
        } else {
            crate::gemm::dense::gemm_src_pool(x, &self.base, out, m, pool);
        }
    }

    /// Sequential (non-pipelined) reference forward, for tests.
    pub fn forward_reference(&self, x: &Tensor) -> Tensor {
        let dense = self.base.decode();
        let base = crate::tensor::matmul(x, &dense);
        let mut out = base.into_vec();
        self.adapters.apply_fused_acc(x.data(), x.rows(), &mut out);
        Tensor::from_vec(&[x.rows(), self.d_out], out)
    }

    /// Merge everything into one dense matrix (for eval through the HLO
    /// path or for measuring the effective update).
    pub fn merge_dense(&self) -> Tensor {
        let dense = self.base.decode();
        let update = crate::tensor::matmul(
            &self.adapters.a_cat,
            &self.adapters.b_cat,
        );
        crate::tensor::add(&dense, &update)
    }

    /// Deployment storage: compressed base + adapter factors.
    pub fn storage_bytes(&self) -> usize {
        self.base.storage_bytes()
            + (self.adapters.a_cat.len() + self.adapters.b_cat.len()) * 4
    }

    /// Dense-equivalent storage for the same layer.
    pub fn dense_bytes(&self) -> usize {
        self.d_in * self.d_out * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WeightFormat;
    use crate::prune::prune_global;
    use crate::tensor::{matmul, max_abs_diff};
    use crate::util::rng::Rng;

    fn make_layer_fmt(
        rng: &mut Rng,
        d_in: usize,
        d_out: usize,
        r: usize,
        rr: usize,
        fmt: WeightFormat,
    ) -> SalrLayer {
        let mut w = Tensor::randn(&[d_in, d_out], 1.0, rng);
        prune_global(&mut [&mut w], 0.5);
        let la = Tensor::randn(&[d_in, r], 0.1, rng);
        let lb = Tensor::randn(&[r, d_out], 0.1, rng);
        let ra = Tensor::randn(&[d_in, rr], 0.1, rng);
        let rb = Tensor::randn(&[rr, d_out], 0.1, rng);
        SalrLayer::new(WeightStore::encode(&w, fmt), &la, &lb, 2.0, Some((&ra, &rb)))
    }

    fn make_layer(rng: &mut Rng, d_in: usize, d_out: usize, r: usize, rr: usize) -> SalrLayer {
        make_layer_fmt(rng, d_in, d_out, r, rr, WeightFormat::Bitmap)
    }

    #[test]
    fn pipelined_forward_matches_reference() {
        let mut rng = Rng::new(300);
        let layer = make_layer(&mut rng, 96, 64, 8, 16);
        let x = Tensor::randn(&[5, 96], 1.0, &mut rng);
        let want = layer.forward_reference(&x);
        let mut got = vec![0.0f32; 5 * 64];
        let pool = crate::util::pool::WorkerPool::global();
        layer.forward(x.data(), 5, &mut got, PipelineConfig::default(), &pool);
        let got = Tensor::from_vec(&[5, 64], got);
        assert!(max_abs_diff(&got, &want) < 1e-2);
    }

    #[test]
    fn forward_runs_on_the_caller_pool() {
        // The small-m path must use exactly the pool it is handed (no
        // global-registry lookup): a private 1-thread pool and a private
        // 3-thread pool both work and agree bitwise.
        let mut rng = Rng::new(304);
        let layer = make_layer(&mut rng, 96, 64, 8, 16);
        let x = Tensor::randn(&[4, 96], 1.0, &mut rng);
        let p1 = crate::util::pool::WorkerPool::new(1);
        let p3 = crate::util::pool::WorkerPool::new(3);
        let mut y1 = vec![0.0f32; 4 * 64];
        let mut y3 = vec![0.0f32; 4 * 64];
        layer.forward(x.data(), 4, &mut y1, PipelineConfig::default(), &p1);
        layer.forward(x.data(), 4, &mut y3, PipelineConfig::default(), &p3);
        assert_eq!(y1, y3, "pool width must not change the bits");
        let want = layer.forward_reference(&x);
        assert!(max_abs_diff(&Tensor::from_vec(&[4, 64], y1), &want) < 1e-2);
    }

    #[test]
    fn prefill_sized_forward_honors_private_pools() {
        // The large-m (pipelined) path must also run on exactly the pool
        // it is handed: private 1-thread and 3-thread pools agree bitwise
        // with each other and stay close to the reference.
        let mut rng = Rng::new(305);
        let layer = make_layer(&mut rng, 96, 64, 8, 16);
        let m = 40; // > DIRECT_M_MAX → pipelined path
        let x = Tensor::randn(&[m, 96], 1.0, &mut rng);
        let p1 = crate::util::pool::WorkerPool::new(1);
        let p3 = crate::util::pool::WorkerPool::new(3);
        let mut y1 = vec![0.0f32; m * 64];
        let mut y3 = vec![0.0f32; m * 64];
        layer.forward(x.data(), m, &mut y1, PipelineConfig::default(), &p1);
        layer.forward(x.data(), m, &mut y3, PipelineConfig::default(), &p3);
        assert_eq!(y1, y3, "pipelined pool width must not change the bits");
        let want = layer.forward_reference(&x);
        assert!(max_abs_diff(&Tensor::from_vec(&[m, 64], y1), &want) < 1e-2);
    }

    #[test]
    fn base_only_forward_is_the_sparse_base_exactly() {
        // Both the small-m (direct) and large-m (sequential) draft paths
        // must equal x @ decode(Ŵ) with no adapter contribution, and the
        // full forward must differ — otherwise self-drafting degenerates
        // into verifying against itself.
        let mut rng = Rng::new(306);
        let layer = make_layer(&mut rng, 96, 64, 8, 16);
        let pool = crate::util::pool::WorkerPool::new(2);
        let dense = layer.base.decode();
        for m in [3usize, 40] {
            let x = Tensor::randn(&[m, 96], 1.0, &mut rng);
            let want = matmul(&x, &dense);
            let mut got = vec![0.0f32; m * 64];
            layer.forward_base_only(x.data(), m, &mut got, &pool);
            let got = Tensor::from_vec(&[m, 64], got);
            assert!(max_abs_diff(&got, &want) < 1e-3, "m={m}");
            let full = layer.forward_reference(&x);
            assert!(
                max_abs_diff(&got, &full) > 1e-3,
                "adapters must contribute on this layer (m={m})"
            );
        }
    }

    #[test]
    fn scaling_folded_into_a() {
        let mut rng = Rng::new(301);
        let mut w = Tensor::randn(&[32, 24], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let la = Tensor::randn(&[32, 4], 0.2, &mut rng);
        let lb = Tensor::randn(&[4, 24], 0.2, &mut rng);
        let layer = SalrLayer::new(
            WeightStore::encode(&w, WeightFormat::Bitmap),
            &la,
            &lb,
            3.0,
            None,
        );
        let x = Tensor::randn(&[2, 32], 1.0, &mut rng);
        let want = crate::tensor::add(&matmul(&x, &w), &{
            let mut u = matmul(&matmul(&x, &la), &lb);
            u.scale(3.0);
            u
        });
        let got = layer.forward_reference(&x);
        assert!(max_abs_diff(&got, &want) < 1e-3);
    }

    #[test]
    fn merge_equals_forward() {
        let mut rng = Rng::new(302);
        let layer = make_layer(&mut rng, 48, 40, 4, 8);
        let merged = layer.merge_dense();
        let x = Tensor::randn(&[3, 48], 1.0, &mut rng);
        let via_merge = matmul(&x, &merged);
        let via_layer = layer.forward_reference(&x);
        assert!(max_abs_diff(&via_merge, &via_layer) < 1e-3);
    }

    #[test]
    fn storage_reflects_sparsity() {
        let mut rng = Rng::new(303);
        let layer = make_layer(&mut rng, 256, 256, 8, 16);
        // ~0.53x dense for the bitmap + small adapters.
        let ratio = layer.storage_bytes() as f64 / layer.dense_bytes() as f64;
        assert!(ratio < 0.75, "ratio={ratio}");
        // NF4 shrinks the value payload 8x on top of the bitmap.
        let mut rng = Rng::new(303);
        let nf4 = make_layer_fmt(&mut rng, 256, 256, 8, 16, WeightFormat::Nf4);
        assert!(nf4.storage_bytes() < layer.storage_bytes());
    }

    #[test]
    fn every_format_forwards_close_to_its_own_reference() {
        // Each resident format must agree with its own decode()-based
        // reference on both batch tiers (direct kernel at m=4, pipelined
        // at m=40) — the quantization error lives in the stored values,
        // never in the kernels.
        let mut rng = Rng::new(307);
        for fmt in [WeightFormat::F32, WeightFormat::Bitmap, WeightFormat::Nf4] {
            let mut lrng = Rng::new(308);
            let layer = make_layer_fmt(&mut lrng, 96, 64, 8, 16, fmt);
            assert_eq!(layer.base.format(), fmt);
            let pool = crate::util::pool::WorkerPool::new(2);
            for m in [4usize, 40] {
                let x = Tensor::randn(&[m, 96], 1.0, &mut rng);
                let want = layer.forward_reference(&x);
                let mut got = vec![0.0f32; m * 64];
                layer.forward(x.data(), m, &mut got, PipelineConfig::default(), &pool);
                let got = Tensor::from_vec(&[m, 64], got);
                assert!(
                    max_abs_diff(&got, &want) < 1e-2,
                    "{fmt:?} m={m} diff={}",
                    max_abs_diff(&got, &want)
                );
            }
        }
    }
}
