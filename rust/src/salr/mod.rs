//! The SALR algorithm: sparsity-preservation pruning (static W0 mask +
//! truncated-SVD residual adapter), adapter concatenation, and the
//! baseline constructions (LoSA / SparseLoRA / DeepSparse analogues).

mod baselines;
mod builder;
mod layer;

pub use baselines::{Baseline, BaselineSpec};
pub use builder::{build_salr, theoretical_mse, SalrBuild, SalrLayerStats};
pub use layer::SalrLayer;
