//! Baseline constructions used by the paper's comparisons (Table 2/4):
//!
//! * **LoRA** — dense frozen base, adapters only;
//! * **LoSA-like** — dynamic mask on the merged `U = W0 + s·A·B`
//!   (Theorem 2, Method 3), mask refreshed by the trainer; deploys sparse
//!   *merged* weights;
//! * **SparseLoRA-like** — contextual compute sparsity during training,
//!   dense deployment (no compression, no inference speedup);
//! * **DeepSparse-like** — one-shot static prune of W0, LoRA on top, *no*
//!   residual recovery (SALR minus its Theorem-3 component).

use crate::model::ParamStore;
use crate::prune::{global_threshold, prune_with_threshold, MaskPolicy};
use crate::runtime::ModelCfg;
use crate::tensor::{add, matmul, Tensor};

/// Which method a fine-tuning run reproduces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Baseline {
    Pretrained,
    Lora,
    Losa,
    SparseLora,
    DeepSparse,
    Salr,
    /// SALR with the residual adapter frozen (Table-5 ablation).
    SalrFrozenResidual,
}

impl Baseline {
    pub fn all() -> [Baseline; 7] {
        [
            Baseline::Pretrained,
            Baseline::Lora,
            Baseline::Losa,
            Baseline::SparseLora,
            Baseline::DeepSparse,
            Baseline::Salr,
            Baseline::SalrFrozenResidual,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Pretrained => "Pretrained",
            Baseline::Lora => "LoRA",
            Baseline::Losa => "LoSA",
            Baseline::SparseLora => "SparseLoRA",
            Baseline::DeepSparse => "DeepSparse",
            Baseline::Salr => "SALR",
            Baseline::SalrFrozenResidual => "SALR (frozen residual)",
        }
    }

    /// Which AOT train-step variant drives this baseline.
    pub fn train_variant(&self) -> Option<&'static str> {
        match self {
            Baseline::Pretrained => None,
            Baseline::Lora => Some("lora"),
            Baseline::Losa => Some("losa"),
            Baseline::SparseLora => Some("sparselora"),
            // DeepSparse = LoRA step over a pruned frozen base.
            Baseline::DeepSparse => Some("lora"),
            Baseline::Salr | Baseline::SalrFrozenResidual => Some("salr"),
        }
    }

    /// Which eval artifact scores this baseline.
    pub fn eval_variant(&self) -> &'static str {
        match self {
            Baseline::Salr | Baseline::SalrFrozenResidual => "salr",
            Baseline::Losa => "losa",
            _ => "lora",
        }
    }

    /// Does the deployed model end up sparse?
    pub fn deploys_sparse(&self) -> bool {
        matches!(
            self,
            Baseline::Losa
                | Baseline::DeepSparse
                | Baseline::Salr
                | Baseline::SalrFrozenResidual
        )
    }

    /// Does the method claim an inference speedup (Table 1)?
    pub fn claims_speedup(&self) -> bool {
        self.deploys_sparse()
    }
}

/// Everything the trainer needs to set a baseline up.
pub struct BaselineSpec {
    pub baseline: Baseline,
    /// Frozen base (pruned for DeepSparse/SALR).
    pub params: ParamStore,
    /// Extra frozen inputs: LoSA masks.
    pub masks: Option<ParamStore>,
    /// SVD residual adapters (SALR only).
    pub residual: Option<ParamStore>,
    /// Residual learning rate η (0 freezes it).
    pub eta_scale: f64,
}

impl BaselineSpec {
    /// Construct the frozen state for a baseline at prune ratio `p`.
    pub fn build(cfg: &ModelCfg, base: &ParamStore, b: Baseline, p: f64, seed: u64) -> BaselineSpec {
        match b {
            Baseline::Pretrained | Baseline::Lora | Baseline::SparseLora => BaselineSpec {
                baseline: b,
                params: base.clone(),
                masks: None,
                residual: None,
                eta_scale: 0.0,
            },
            Baseline::DeepSparse => {
                // One-shot static prune, no residual recovery.
                let mut params = base.clone();
                let names = cfg.adapted_layers();
                let views: Vec<&Tensor> =
                    names.iter().map(|n| base.get(n).unwrap()).collect();
                let th = global_threshold(&views, p);
                for n in &names {
                    prune_with_threshold(params.get_mut(n).unwrap(), th);
                }
                BaselineSpec {
                    baseline: b,
                    params,
                    masks: None,
                    residual: None,
                    eta_scale: 0.0,
                }
            }
            Baseline::Losa => {
                // Dynamic mask (Method 3) — initial mask derived from W0
                // (adapters are zero at t=0), refreshed during training.
                let mut masks = ParamStore::new();
                for n in cfg.adapted_layers() {
                    let w = base.get(&n).unwrap();
                    let m = MaskPolicy::DynamicU.derive(w, None, p);
                    masks.insert(&format!("{n}.mask"), mask_to_tensor(&m));
                }
                BaselineSpec {
                    baseline: b,
                    params: base.clone(),
                    masks: Some(masks),
                    residual: None,
                    eta_scale: 0.0,
                }
            }
            Baseline::Salr | Baseline::SalrFrozenResidual => {
                let build = crate::salr::build_salr(cfg, base, p, seed);
                BaselineSpec {
                    baseline: b,
                    params: build.params,
                    masks: None,
                    residual: Some(build.residual_adapters),
                    eta_scale: if b == Baseline::SalrFrozenResidual { 0.0 } else { 1.0 },
                }
            }
        }
    }

    /// Refresh the LoSA dynamic masks from the current merged weights
    /// `U = W0 + s·A·B` (the "dynamic" in dynamic low-rank sparse
    /// adaptation), keeping the global ratio `p`.
    pub fn refresh_losa_masks(
        &mut self,
        cfg: &ModelCfg,
        adapters: &ParamStore,
        p: f64,
    ) {
        let masks = match &mut self.masks {
            Some(m) => m,
            None => return,
        };
        let s = cfg.lora_scaling();
        for n in cfg.adapted_layers() {
            let w = self.params.get(&n).unwrap();
            let a = adapters.get(&format!("{n}.lora_a")).unwrap();
            let b = adapters.get(&format!("{n}.lora_b")).unwrap();
            let mut ab = matmul(a, b);
            ab.scale(s);
            let u = add(w, &ab);
            let m = MaskPolicy::DynamicU.derive(&u, None, p);
            masks.insert(&format!("{n}.mask"), mask_to_tensor(&m));
        }
    }
}

fn mask_to_tensor(m: &crate::prune::Mask) -> Tensor {
    let mut t = Tensor::zeros(&[m.rows(), m.cols()]);
    for i in 0..m.rows() {
        for j in 0..m.cols() {
            if m.get(i, j) {
                t.set(i, j, 1.0);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq_len: 16,
            rank: 4,
            lora_alpha: 8.0,
            residual_rank: 8,
            batch_size: 2,
            ctx_keep: 0.5,
        }
    }

    #[test]
    fn table1_feature_matrix() {
        // The qualitative Table-1 claims, encoded.
        assert!(!Baseline::SparseLora.deploys_sparse());
        assert!(!Baseline::SparseLora.claims_speedup());
        assert!(Baseline::Losa.deploys_sparse());
        assert!(Baseline::Salr.deploys_sparse() && Baseline::Salr.claims_speedup());
    }

    #[test]
    fn deepsparse_prunes_base() {
        let cfg = test_cfg();
        let mut rng = Rng::new(320);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let spec = BaselineSpec::build(&cfg, &base, Baseline::DeepSparse, 0.5, 1);
        let w = spec.params.get("layer0.wq").unwrap();
        assert!((w.sparsity() - 0.5).abs() < 0.05);
        assert!(spec.residual.is_none());
    }

    #[test]
    fn salr_has_residual_deepsparse_does_not() {
        let cfg = test_cfg();
        let mut rng = Rng::new(321);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let salr = BaselineSpec::build(&cfg, &base, Baseline::Salr, 0.5, 2);
        assert!(salr.residual.is_some());
        assert_eq!(salr.eta_scale, 1.0);
        let frozen = BaselineSpec::build(&cfg, &base, Baseline::SalrFrozenResidual, 0.5, 2);
        assert_eq!(frozen.eta_scale, 0.0);
    }

    #[test]
    fn losa_masks_and_refresh() {
        let cfg = test_cfg();
        let mut rng = Rng::new(322);
        let base = ParamStore::init_base(&cfg, &mut rng);
        let mut spec = BaselineSpec::build(&cfg, &base, Baseline::Losa, 0.5, 3);
        let m0 = spec
            .masks
            .as_ref()
            .unwrap()
            .get("layer0.wq.mask")
            .unwrap()
            .clone();
        assert!((m0.sparsity() - 0.5).abs() < 0.05);
        // Large trained adapters shift the dynamic mask.
        let mut adapters = ParamStore::init_adapters(&cfg, &mut rng, false);
        for (_, t) in adapters.iter_mut() {
            let mut r = Rng::new(99);
            r.fill_normal(t.data_mut(), 1.0);
        }
        spec.refresh_losa_masks(&cfg, &adapters, 0.5);
        let m1 = spec.masks.as_ref().unwrap().get("layer0.wq.mask").unwrap();
        assert_ne!(&m0, m1, "dynamic mask should move with the adapters");
        assert!((m1.sparsity() - 0.5).abs() < 0.05);
    }
}
