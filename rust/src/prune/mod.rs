//! Pruning: magnitude-based unstructured pruning, the three mask policies
//! analysed in Theorem 2, N:M semi-structured pruning (2:4), and the
//! closed-form MSE theory of Theorems 1–2 (with its own erf/Φ
//! implementation — no libm special functions in the vendor set).

pub mod magnitude;
pub mod mask;
pub mod nm;
pub mod theory;

pub use magnitude::{global_threshold, prune_global, prune_with_threshold};
pub use mask::{apply_mask, mask_from_dense, Mask, MaskPolicy};
pub use nm::{prune_nm, NmPattern};
