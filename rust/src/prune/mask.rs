//! Pruning masks and the three mask *policies* of Theorem 2:
//!
//! * **Method 1** — static mask from `|W0|` (SALR's choice; lowest MSE);
//! * **Method 2** — mask driven by `|U| = |W0 + AB|` but applied to `W0` only;
//! * **Method 3** — mask on the full `U` applied to everything (LoSA-style).

use crate::prune::magnitude::global_threshold;
use crate::tensor::{add, Tensor};

/// A binary keep-mask stored as packed u64 words (1 = keep).
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    rows: usize,
    cols: usize,
    words: Vec<u64>,
}

impl Mask {
    pub fn new_ones(rows: usize, cols: usize) -> Mask {
        let nbits = rows * cols;
        let nwords = nbits.div_ceil(64);
        let mut words = vec![u64::MAX; nwords];
        // Clear tail bits beyond nbits.
        let tail = nbits % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last = (1u64 << tail) - 1;
            }
        }
        Mask { rows, cols, words }
    }

    pub fn new_zeros(rows: usize, cols: usize) -> Mask {
        let nwords = (rows * cols).div_ceil(64);
        Mask {
            rows,
            cols,
            words: vec![0; nwords],
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        let bit = i * self.cols + j;
        (self.words[bit / 64] >> (bit % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, keep: bool) {
        let bit = i * self.cols + j;
        if keep {
            self.words[bit / 64] |= 1 << (bit % 64);
        } else {
            self.words[bit / 64] &= !(1 << (bit % 64));
        }
    }

    /// Number of kept (1) entries.
    pub fn count_kept(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction pruned.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count_kept() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Storage size of the packed mask in bytes.
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// Build a keep-mask from a dense tensor and threshold (|x| > T kept).
pub fn mask_from_dense(t: &Tensor, threshold: f32) -> Mask {
    let (r, c) = (t.rows(), t.cols());
    let mut m = Mask::new_zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            if t.at(i, j).abs() > threshold {
                m.set(i, j, true);
            }
        }
    }
    m
}

/// Zero out entries of `t` where the mask is 0.
pub fn apply_mask(t: &mut Tensor, mask: &Mask) {
    assert_eq!(t.rows(), mask.rows);
    assert_eq!(t.cols(), mask.cols);
    for i in 0..t.rows() {
        let row = t.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            if !mask.get(i, j) {
                *v = 0.0;
            }
        }
    }
}

/// The three Theorem-2 policies for deriving a mask in the LoRA setting
/// `W = W0 + AB`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskPolicy {
    /// Method 1: static mask from `|W0|` alone (SALR).
    StaticW0,
    /// Method 2: mask from `|W0 + AB|`, applied to `W0` only.
    DynamicUOnW0,
    /// Method 3: mask from `|W0 + AB|`, applied to the merged `U` (LoSA).
    DynamicU,
}

impl MaskPolicy {
    /// Derive a keep-mask at global rate `p` for base weights `w0` and
    /// (optional) adapter product `ab`.
    pub fn derive(&self, w0: &Tensor, ab: Option<&Tensor>, p: f64) -> Mask {
        match self {
            MaskPolicy::StaticW0 => {
                let th = global_threshold(&[w0], p);
                mask_from_dense(w0, th)
            }
            MaskPolicy::DynamicUOnW0 | MaskPolicy::DynamicU => {
                let u = match ab {
                    Some(ab) => add(w0, ab),
                    None => w0.clone(),
                };
                let th = global_threshold(&[&u], p);
                mask_from_dense(&u, th)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mask_bit_ops() {
        let mut m = Mask::new_zeros(3, 70); // crosses word boundary
        assert_eq!(m.count_kept(), 0);
        m.set(0, 0, true);
        m.set(1, 69, true);
        m.set(2, 35, true);
        assert!(m.get(0, 0) && m.get(1, 69) && m.get(2, 35));
        assert!(!m.get(0, 1));
        assert_eq!(m.count_kept(), 3);
        m.set(1, 69, false);
        assert_eq!(m.count_kept(), 2);
    }

    #[test]
    fn ones_mask_tail_bits_clean() {
        let m = Mask::new_ones(3, 33); // 99 bits, 2 words
        assert_eq!(m.count_kept(), 99);
        assert!((m.sparsity() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn apply_mask_zeroes() {
        let mut rng = Rng::new(50);
        let mut t = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let th = global_threshold(&[&t], 0.5);
        let m = mask_from_dense(&t, th);
        apply_mask(&mut t, &m);
        assert!((t.sparsity() - 0.5).abs() < 0.02);
        // Every kept entry exceeds the threshold.
        for i in 0..16 {
            for j in 0..16 {
                if m.get(i, j) {
                    assert!(t.at(i, j).abs() > th);
                } else {
                    assert_eq!(t.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn policies_differ_when_adapter_large() {
        let mut rng = Rng::new(51);
        let w0 = Tensor::randn(&[32, 32], 1.0, &mut rng);
        let ab = Tensor::randn(&[32, 32], 2.0, &mut rng);
        let m1 = MaskPolicy::StaticW0.derive(&w0, Some(&ab), 0.5);
        let m3 = MaskPolicy::DynamicU.derive(&w0, Some(&ab), 0.5);
        assert_ne!(m1, m3, "large adapter should shift the dynamic mask");
        assert!((m1.sparsity() - 0.5).abs() < 0.02);
        assert!((m3.sparsity() - 0.5).abs() < 0.02);
    }

    #[test]
    fn storage_is_one_bit_per_entry() {
        let m = Mask::new_ones(128, 128);
        assert_eq!(m.storage_bytes(), 128 * 128 / 8);
    }
}
