//! N:M semi-structured pruning (e.g. 2:4): within every group of `m`
//! consecutive weights along the input dimension, keep the `n` largest by
//! magnitude. This is the deployment pattern of the paper's Table 4
//! (inference speedup follows the N:M sparsity protocol of LoSA).

use crate::tensor::Tensor;

/// An N:M sparsity pattern (`n` kept out of every `m`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

impl NmPattern {
    pub const TWO_FOUR: NmPattern = NmPattern { n: 2, m: 4 };

    pub fn sparsity(&self) -> f64 {
        1.0 - self.n as f64 / self.m as f64
    }
}

/// Prune `t` in place to the N:M pattern along rows (row-major groups of m).
/// Returns the number of zeroed entries.
pub fn prune_nm(t: &mut Tensor, pat: NmPattern) -> usize {
    assert!(pat.n <= pat.m && pat.m > 0);
    let cols = t.cols();
    let mut zeroed = 0;
    let mut idx: Vec<usize> = Vec::with_capacity(pat.m);
    for r in 0..t.rows() {
        let row = t.row_mut(r);
        let mut g = 0;
        while g < cols {
            let end = (g + pat.m).min(cols);
            let glen = end - g;
            let keep = pat.n.min(glen);
            idx.clear();
            idx.extend(g..end);
            // Partial selection: keep the `keep` largest magnitudes.
            idx.sort_by(|&a, &b| row[b].abs().partial_cmp(&row[a].abs()).unwrap());
            for &i in idx.iter().skip(keep) {
                if row[i] != 0.0 {
                    zeroed += 1;
                }
                row[i] = 0.0;
            }
            g = end;
        }
    }
    zeroed
}

/// Verify a tensor satisfies the N:M constraint (each full group of m has at
/// most n nonzeros).
pub fn check_nm(t: &Tensor, pat: NmPattern) -> bool {
    let cols = t.cols();
    for r in 0..t.rows() {
        let row = t.row(r);
        let mut g = 0;
        while g + pat.m <= cols {
            let nnz = row[g..g + pat.m].iter().filter(|&&x| x != 0.0).count();
            if nnz > pat.n {
                return false;
            }
            g += pat.m;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn two_four_halves_density() {
        let mut rng = Rng::new(60);
        let mut t = Tensor::randn(&[64, 64], 1.0, &mut rng);
        prune_nm(&mut t, NmPattern::TWO_FOUR);
        assert!(check_nm(&t, NmPattern::TWO_FOUR));
        assert!((t.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn keeps_the_largest_in_each_group() {
        let mut t = Tensor::from_vec(&[1, 4], vec![0.1, -5.0, 3.0, 0.2]);
        prune_nm(&mut t, NmPattern::TWO_FOUR);
        assert_eq!(t.data(), &[0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn ragged_tail_group_handled() {
        let mut t = Tensor::from_vec(&[1, 6], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        prune_nm(&mut t, NmPattern::TWO_FOUR);
        // First group keeps 3,4; tail group of 2 keeps both (n=2).
        assert_eq!(t.data(), &[0.0, 0.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn prop_nm_invariant_and_magnitude_optimality() {
        Prop::new(24).check(
            "n:m pattern holds and kept >= dropped per group",
            |rng| {
                let r = 1 + rng.below(10);
                let c = 4 * (1 + rng.below(10));
                Tensor::randn(&[r, c], 1.0, rng)
            },
            |t| {
                let mut p = t.clone();
                prune_nm(&mut p, NmPattern::TWO_FOUR);
                if !check_nm(&p, NmPattern::TWO_FOUR) {
                    return Err("pattern violated".into());
                }
                // Within each group, min kept magnitude >= max dropped.
                for r in 0..t.rows() {
                    for g in (0..t.cols()).step_by(4) {
                        let orig = &t.row(r)[g..g + 4];
                        let kept = &p.row(r)[g..g + 4];
                        let min_kept = kept
                            .iter()
                            .filter(|&&x| x != 0.0)
                            .fold(f32::INFINITY, |m, &x| m.min(x.abs()));
                        let max_dropped = orig
                            .iter()
                            .zip(kept)
                            .filter(|(_, &k)| k == 0.0)
                            .fold(0.0f32, |m, (&o, _)| m.max(o.abs()));
                        if min_kept < max_dropped {
                            return Err(format!("kept {min_kept} < dropped {max_dropped}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
