//! Magnitude-based pruning: `Ŵ_ij = W_ij if |W_ij| > T_p else 0`, with the
//! threshold chosen so a target fraction `p` of entries is pruned
//! (paper, Preliminary section).

use crate::tensor::Tensor;

/// Exact global threshold: the `⌈p·n⌉`-th smallest |value| across all
/// tensors. Uses quickselect (O(n) average) over a copied magnitude buffer.
pub fn global_threshold(tensors: &[&Tensor], p: f64) -> f32 {
    assert!((0.0..1.0).contains(&p), "prune ratio must be in [0,1)");
    if p == 0.0 {
        return -1.0; // threshold below any magnitude: nothing pruned
    }
    let total: usize = tensors.iter().map(|t| t.len()).sum();
    if total == 0 {
        return -1.0;
    }
    let mut mags: Vec<f32> = Vec::with_capacity(total);
    for t in tensors {
        mags.extend(t.data().iter().map(|x| x.abs()));
    }
    let k = ((p * total as f64).ceil() as usize).clamp(1, total) - 1;
    *order_stat(&mut mags, k)
}

/// k-th order statistic (0-based) via in-place quickselect.
fn order_stat(xs: &mut [f32], k: usize) -> &f32 {
    let (mut lo, mut hi) = (0usize, xs.len());
    let mut k = k;
    loop {
        if hi - lo <= 1 {
            return &xs[lo];
        }
        // Median-of-three pivot.
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (xs[lo], xs[mid], xs[hi - 1]);
        let pivot = a.max(b.min(c)).min(b.max(c));
        // Three-way partition.
        let (mut lt, mut i, mut gt) = (lo, lo, hi);
        while i < gt {
            if xs[i] < pivot {
                xs.swap(i, lt);
                lt += 1;
                i += 1;
            } else if xs[i] > pivot {
                gt -= 1;
                xs.swap(i, gt);
            } else {
                i += 1;
            }
        }
        let (nlt, neq) = (lt - lo, gt - lt);
        if k < nlt {
            hi = lt;
        } else if k < nlt + neq {
            return &xs[lt];
        } else {
            k -= nlt + neq;
            lo = gt;
        }
    }
}

/// Prune a tensor in place with an explicit threshold; returns pruned count.
/// Entries with `|w| <= threshold` are zeroed (matches the paper's `≤ T_p`).
pub fn prune_with_threshold(t: &mut Tensor, threshold: f32) -> usize {
    let mut pruned = 0;
    for v in t.data_mut() {
        if v.abs() <= threshold {
            if *v != 0.0 {
                // count newly-zeroed and already-zero uniformly below
            }
            *v = 0.0;
            pruned += 1;
        }
    }
    pruned
}

/// Globally prune a set of tensors to ratio `p`; returns the threshold used.
pub fn prune_global(tensors: &mut [&mut Tensor], p: f64) -> f32 {
    let views: Vec<&Tensor> = tensors.iter().map(|t| &**t).collect();
    let threshold = global_threshold(&views, p);
    drop(views);
    for t in tensors.iter_mut() {
        prune_with_threshold(t, threshold);
    }
    threshold
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn threshold_achieves_ratio() {
        let mut rng = Rng::new(40);
        let mut t = Tensor::randn(&[100, 100], 1.0, &mut rng);
        let th = prune_global(&mut [&mut t], 0.5);
        assert!(th > 0.0);
        let sparsity = t.sparsity();
        assert!(
            (sparsity - 0.5).abs() < 0.01,
            "sparsity={sparsity} threshold={th}"
        );
    }

    #[test]
    fn zero_ratio_prunes_nothing() {
        let mut rng = Rng::new(41);
        let mut t = Tensor::randn(&[10, 10], 1.0, &mut rng);
        let orig = t.clone();
        prune_global(&mut [&mut t], 0.0);
        assert_eq!(t, orig);
    }

    #[test]
    fn global_across_tensors_prunes_smaller_tensor_more() {
        // t_small has tiny entries, t_big has large: global 50% should wipe
        // mostly t_small.
        let mut rng = Rng::new(42);
        let mut t_small = Tensor::randn(&[50, 50], 0.01, &mut rng);
        let mut t_big = Tensor::randn(&[50, 50], 10.0, &mut rng);
        prune_global(&mut [&mut t_small, &mut t_big], 0.5);
        assert!(t_small.sparsity() > 0.95);
        assert!(t_big.sparsity() < 0.05);
    }

    #[test]
    fn order_stat_matches_sort() {
        let mut rng = Rng::new(43);
        for _ in 0..20 {
            let n = 1 + rng.below(500);
            let mut xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let k = rng.below(n);
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let got = *order_stat(&mut xs, k);
            assert_eq!(got, sorted[k]);
        }
    }

    #[test]
    fn prop_sparsity_close_to_p() {
        Prop::new(16).check(
            "prune ratio achieved",
            |rng| {
                let n = 20 + rng.below(80);
                let p = 0.05 + rng.uniform() * 0.9;
                (Tensor::randn(&[n, n], 1.0, rng), p)
            },
            |(t, p)| {
                let mut t = t.clone();
                prune_global(&mut [&mut t], *p);
                let s = t.sparsity();
                // Exact up to ties + ceil: within 1 element / n^2 + epsilon.
                let tol = 2.0 / (t.len() as f64) + 1e-9;
                if s >= *p - tol && s <= *p + 0.02 {
                    Ok(())
                } else {
                    Err(format!("p={p} sparsity={s}"))
                }
            },
        );
    }
}
