//! Closed-form MSE theory of Theorems 1–3, plus the Gaussian special
//! functions it needs (erf, Φ, Φ⁻¹) — implemented from scratch.
//!
//! * Theorem 1: `MSE(p) = 2σ²·Q(t_p)` with `t_p = Φ⁻¹((1+p)/2)` and
//!   `Q(t) = Φ(t) − ½ − t·φ(t)`.
//! * Theorem 2: per-entry MSEs `E1 ≤ E3 ≤ E2` of the three mask policies.
//! * Theorem 3: `MSE_{prune+SVD}(p, r) ≤ (1 − r/min(d,k))·MSE(p)`.
//!
//! `salr exp theory` regenerates the paper's numeric claims (e.g.
//! `MSE(0.5) ≈ 0.072σ²`) and Monte-Carlo-validates every formula.

use std::f64::consts::{PI, SQRT_2};

/// Error function, Abramowitz–Stegun 7.1.26-style rational approximation
/// refined with one Newton step against the exact derivative; |err| < 1e-12
/// after refinement on the tested range.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x > 6.0 {
        return 1.0;
    }
    // High-accuracy series/continued-fraction split.
    let v = if x < 2.0 {
        // Maclaurin series: erf(x) = 2/sqrt(pi) * sum (-1)^n x^(2n+1)/(n!(2n+1))
        let mut term = x;
        let mut sum = x;
        let x2 = x * x;
        for n in 1..=60 {
            term *= -x2 / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-17 * sum.abs() {
                break;
            }
        }
        2.0 / PI.sqrt() * sum
    } else {
        // Continued fraction for erfc.
        1.0 - erfc_cf(x)
    };
    v.clamp(-1.0, 1.0)
}

/// Complementary error function for x >= 2 via the continued fraction
/// `erfc(x) = exp(-x²)/(x√π) · 1/(1 + u₁/(1 + u₂/(1 + …)))` with
/// `u_k = k/(2x²)`, evaluated bottom-up.
fn erfc_cf(x: f64) -> f64 {
    let x2 = x * x;
    let mut cf = 1.0f64;
    for k in (1..=120).rev() {
        cf = 1.0 + (k as f64 / (2.0 * x2)) / cf;
    }
    ((-x2).exp() / (x * PI.sqrt())) / cf
}

/// Standard normal PDF φ(t).
pub fn phi_pdf(t: f64) -> f64 {
    (-0.5 * t * t).exp() / (2.0 * PI).sqrt()
}

/// Standard normal CDF Φ(t).
pub fn phi_cdf(t: f64) -> f64 {
    0.5 * (1.0 + erf(t / SQRT_2))
}

/// Inverse standard normal CDF Φ⁻¹(q) (Acklam's algorithm + Newton polish).
pub fn phi_inv(q: f64) -> f64 {
    assert!((0.0..1.0).contains(&q) && q > 0.0, "phi_inv domain");
    // Acklam coefficients.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    let mut x = if q < plow {
        let u = (-2.0 * q.ln()).sqrt();
        (((((C[0] * u + C[1]) * u + C[2]) * u + C[3]) * u + C[4]) * u + C[5])
            / ((((D[0] * u + D[1]) * u + D[2]) * u + D[3]) * u + 1.0)
    } else if q <= 1.0 - plow {
        let u = q - 0.5;
        let t = u * u;
        (((((A[0] * t + A[1]) * t + A[2]) * t + A[3]) * t + A[4]) * t + A[5]) * u
            / (((((B[0] * t + B[1]) * t + B[2]) * t + B[3]) * t + B[4]) * t + 1.0)
    } else {
        let u = (-2.0 * (1.0 - q).ln()).sqrt();
        -(((((C[0] * u + C[1]) * u + C[2]) * u + C[3]) * u + C[4]) * u + C[5])
            / ((((D[0] * u + D[1]) * u + D[2]) * u + D[3]) * u + 1.0)
    };
    // Two Newton refinements.
    for _ in 0..2 {
        let e = phi_cdf(x) - q;
        x -= e / phi_pdf(x).max(1e-300);
    }
    x
}

/// `t_p = Φ⁻¹((1+p)/2)`: the standardized pruning threshold for ratio p.
pub fn t_p(p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p));
    if p == 0.0 {
        0.0
    } else {
        phi_inv((1.0 + p) / 2.0)
    }
}

/// `Q(t) = Φ(t) − ½ − t·φ(t)` (paper's Theorem 2 notation).
pub fn q_fn(t: f64) -> f64 {
    phi_cdf(t) - 0.5 - t * phi_pdf(t)
}

/// Theorem 1: per-entry pruning MSE for `W ~ N(0, σ²)` at ratio p.
pub fn mse_prune(p: f64, sigma2: f64) -> f64 {
    2.0 * sigma2 * q_fn(t_p(p))
}

/// Theorem 2, Method 1 (static mask on W0): `E1(p) = 2σ²·Q(t_p)`.
pub fn e1(p: f64, sigma2: f64) -> f64 {
    2.0 * sigma2 * q_fn(t_p(p))
}

/// Theorem 2, Method 2 (dynamic mask from U, pruning W0 only):
/// `E2(p) = σ²τ²/(σ²+τ²)·p + 2σ⁴/(σ²+τ²)·Q(t_p)`.
pub fn e2(p: f64, sigma2: f64, tau2: f64) -> f64 {
    let v2 = sigma2 + tau2;
    sigma2 * tau2 / v2 * p + 2.0 * sigma2 * sigma2 / v2 * q_fn(t_p(p))
}

/// Theorem 2, Method 3 (dynamic mask on full U): `E3(p) = 2(σ²+τ²)·Q(t_p)`.
pub fn e3(p: f64, sigma2: f64, tau2: f64) -> f64 {
    2.0 * (sigma2 + tau2) * q_fn(t_p(p))
}

/// `E2(p) − E1(p) = σ²τ²/(σ²+τ²)·2·t_p·φ(t_p) ≥ 0` — Method 1 always beats
/// Method 2. NOTE: the paper labels this expression `E2 − E3`, which is an
/// algebra slip in its Comparison step: expanding `E2 − E3` directly gives
/// `τ²/V²·[2·t_p·φ(t_p)·(2σ²+τ²) − p·V²]`, which is *negative* for large τ²
/// (e.g. σ²=0.5, τ²=2, p=0.55) or p → 1. The paper's headline claim — that
/// the static-W0 mask (Method 1) has the lowest bound — is unaffected:
/// `E1 ≤ E2` and `E1 ≤ E3` hold for every (p, σ², τ²). We verify the true
/// ordering by Monte Carlo and document the discrepancy in EXPERIMENTS.md.
pub fn e2_minus_e1(p: f64, sigma2: f64, tau2: f64) -> f64 {
    let v2 = sigma2 + tau2;
    let t = t_p(p);
    sigma2 * tau2 / v2 * 2.0 * t * phi_pdf(t)
}

/// Exact sign-bearing expression for `E2 − E3` (see [`e2_minus_e1`] note):
/// `τ²/V²·[2·t_p·φ(t_p)·(2σ²+τ²) − p·(σ²+τ²)]`.
pub fn e2_minus_e3(p: f64, sigma2: f64, tau2: f64) -> f64 {
    let v2 = sigma2 + tau2;
    let t = t_p(p);
    tau2 / v2 * (2.0 * t * phi_pdf(t) * (2.0 * sigma2 + tau2) - p * v2)
}

/// Theorem 3 bound: `MSE_{prune+SVD}(p, r) ≤ (1 − r/min(d,k))·MSE(p)`.
pub fn mse_prune_svd_bound(p: f64, sigma2: f64, r: usize, d: usize, k: usize) -> f64 {
    let q = d.min(k) as f64;
    (1.0 - (r as f64 / q).min(1.0)) * mse_prune(p, sigma2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn erf_reference_values() {
        // Known values (Wolfram): erf(0.5)=0.5204998778, erf(1)=0.8427007929,
        // erf(2)=0.9953222650, erf(3)=0.9999779095.
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(0.5) - 0.5204998778130465).abs() < 1e-10);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-10);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-9);
        assert!((erf(3.0) - 0.9999779095030014).abs() < 1e-9);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-10);
    }

    #[test]
    fn phi_cdf_inv_roundtrip() {
        for &q in &[0.001, 0.01, 0.25, 0.5, 0.75, 0.975, 0.999] {
            let x = phi_inv(q);
            assert!((phi_cdf(x) - q).abs() < 1e-10, "q={q} x={x}");
        }
        // Φ⁻¹(0.75) ≈ 0.6745 (the paper's t_{0.5}).
        assert!((phi_inv(0.75) - 0.6744897501960817).abs() < 1e-8);
    }

    #[test]
    fn paper_numeric_mse_at_half() {
        // Paper: MSE(0.5) ≈ 0.072 σ² (they round via φ(0.674)≈0.318).
        let mse = mse_prune(0.5, 1.0);
        assert!(
            (mse - 0.0719).abs() < 5e-3,
            "MSE(0.5)={mse}, paper says ≈0.072"
        );
    }

    #[test]
    fn theorem2_method1_is_always_best() {
        // The paper's load-bearing claim: E1 <= E2 and E1 <= E3 everywhere.
        for i in 1..20 {
            let p = i as f64 / 20.0;
            for &(s2, t2) in &[(1.0, 0.1), (1.0, 1.0), (0.5, 2.0), (2.0, 0.01)] {
                let (a, b, c) = (e1(p, s2), e3(p, s2, t2), e2(p, s2, t2));
                assert!(a <= b + 1e-12, "E1 > E3 at p={p}");
                assert!(a <= c + 1e-12, "E1 > E2 at p={p} (s2={s2},t2={t2})");
                // Closed-form gaps match the direct differences.
                assert!((e2_minus_e1(p, s2, t2) - (c - a)).abs() < 1e-9);
                assert!((e2_minus_e3(p, s2, t2) - (c - b)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn theorem2_e3_le_e2_in_paper_regime_but_not_globally() {
        // In the LoRA regime (adapter energy well below base-weight energy,
        // moderate p) the paper's secondary ordering E3 <= E2 holds...
        for i in 1..=16 {
            let p = i as f64 / 20.0; // p in [0.05, 0.8]
            assert!(
                e3(p, 1.0, 0.1) <= e2(p, 1.0, 0.1) + 1e-12,
                "E3 > E2 at p={p} in small-tau regime"
            );
        }
        // ...but NOT for every (sigma, tau, p): the paper's Comparison step
        // actually derives E2 - E1 (see e2_minus_e1 docs). Counterexample:
        let (p, s2, t2) = (0.55, 0.5, 2.0);
        assert!(
            e3(p, s2, t2) > e2(p, s2, t2),
            "expected documented counterexample to the paper's E3<=E2"
        );
        assert!(e2_minus_e3(p, s2, t2) < 0.0);
    }

    #[test]
    fn monte_carlo_validates_theorem1() {
        let mut rng = Rng::new(70);
        let n = 400_000;
        let sigma = 1.3f64;
        for &p in &[0.2, 0.5, 0.8] {
            let threshold = sigma * t_p(p);
            let mut se = 0.0f64;
            for _ in 0..n {
                let w = rng.normal() * sigma;
                let pruned = if w.abs() <= threshold { 0.0 } else { w };
                se += (w - pruned).powi(2);
            }
            let emp = se / n as f64;
            let theo = mse_prune(p, sigma * sigma);
            assert!(
                (emp - theo).abs() / theo < 0.03,
                "p={p} empirical={emp} theoretical={theo}"
            );
        }
    }

    #[test]
    fn monte_carlo_validates_theorem2() {
        let mut rng = Rng::new(71);
        let n = 300_000;
        let (sigma, tau) = (1.0f64, 0.6f64);
        let v = (sigma * sigma + tau * tau).sqrt();
        let p = 0.5;
        let (mut se1, mut se2, mut se3) = (0.0f64, 0.0, 0.0);
        for _ in 0..n {
            let w0 = rng.normal() * sigma;
            let delta = rng.normal() * tau;
            let u = w0 + delta;
            // Method 1: static mask on |w0| at rate p → threshold σ t_p.
            let err1 = if w0.abs() <= sigma * t_p(p) { w0 } else { 0.0 };
            se1 += err1 * err1;
            // Method 2: mask from |u| (threshold V t_p) zeroes w0 only.
            let err2 = if u.abs() <= v * t_p(p) { w0 } else { 0.0 };
            se2 += err2 * err2;
            // Method 3: mask from |u| zeroes u entirely.
            let err3 = if u.abs() <= v * t_p(p) { u } else { 0.0 };
            se3 += err3 * err3;
        }
        let (m1, m2, m3) = (se1 / n as f64, se2 / n as f64, se3 / n as f64);
        let (t1, t2v, t3) = (
            e1(p, sigma * sigma),
            e2(p, sigma * sigma, tau * tau),
            e3(p, sigma * sigma, tau * tau),
        );
        assert!((m1 - t1).abs() / t1 < 0.05, "E1 emp={m1} theo={t1}");
        assert!((m2 - t2v).abs() / t2v < 0.05, "E2 emp={m2} theo={t2v}");
        assert!((m3 - t3).abs() / t3 < 0.05, "E3 emp={m3} theo={t3}");
    }

    #[test]
    fn theorem3_bound_decreases_linearly_in_r() {
        let m0 = mse_prune_svd_bound(0.5, 1.0, 0, 64, 256);
        let mh = mse_prune_svd_bound(0.5, 1.0, 32, 64, 256);
        let mf = mse_prune_svd_bound(0.5, 1.0, 64, 64, 256);
        assert!((mh / m0 - 0.5).abs() < 1e-9);
        assert!(mf.abs() < 1e-12);
    }

    #[test]
    fn q_fn_properties() {
        // Q(0)=0, Q increasing, Q(t) <= Φ(t) - 1/2 <= 1/2.
        assert!(q_fn(0.0).abs() < 1e-12);
        let mut prev = 0.0;
        for i in 1..40 {
            let t = i as f64 * 0.1;
            let q = q_fn(t);
            assert!(q >= prev - 1e-12);
            assert!(q <= 0.5);
            prev = q;
        }
    }
}
