//! NF4 ("NormalFloat-4") quantization, as used by the paper's QSALR
//! ablation (Table 6: 20% sparsity + NF4 → ~5× model-size reduction).

pub mod nf4;

pub use nf4::{Nf4Matrix, SparseNf4Matrix, NF4_CODEBOOK};
