//! NF4 quantization (QLoRA's NormalFloat-4): a 16-level codebook of
//! normal-distribution quantiles, applied blockwise with absmax scaling,
//! two 4-bit codes packed per byte.
//!
//! QSALR (paper Table 6) composes this with a 20% static sparsity mask:
//! the *kept* values are NF4-quantized, the mask stays a bitmap.

use crate::tensor::Tensor;

/// The standard NF4 codebook (QLoRA, Dettmers et al. 2023): 16 values in
/// [-1, 1], quantiles of N(0,1) normalized to unit absmax, asymmetric with
/// an exact zero.
pub const NF4_CODEBOOK: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Blockwise-NF4-quantized matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Nf4Matrix {
    rows: usize,
    cols: usize,
    block: usize,
    /// Packed 4-bit codes, two per byte, row-major over elements.
    codes: Vec<u8>,
    /// One f32 absmax scale per block.
    scales: Vec<f32>,
}

/// Nearest codebook index for a value in [-1, 1].
#[inline]
fn nearest_code(x: f32) -> u8 {
    // Binary search over the sorted codebook, then pick nearer neighbor.
    let mut lo = 0usize;
    let mut hi = NF4_CODEBOOK.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if NF4_CODEBOOK[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    if (x - NF4_CODEBOOK[lo]).abs() <= (NF4_CODEBOOK[hi] - x).abs() {
        lo as u8
    } else {
        hi as u8
    }
}

impl Nf4Matrix {
    /// Quantize with the given block size (64 is the QLoRA default).
    pub fn quantize(t: &Tensor, block: usize) -> Nf4Matrix {
        assert!(block > 0);
        let n = t.len();
        let data = t.data();
        let nblocks = n.div_ceil(block);
        let mut scales = Vec::with_capacity(nblocks);
        let mut codes = vec![0u8; n.div_ceil(2)];
        for bi in 0..nblocks {
            let s = bi * block;
            let e = (s + block).min(n);
            let absmax = data[s..e].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax } else { 1.0 };
            scales.push(scale);
            let inv = 1.0 / scale;
            for (k, &x) in data[s..e].iter().enumerate() {
                let code = nearest_code(x * inv);
                let idx = s + k;
                if idx % 2 == 0 {
                    codes[idx / 2] |= code;
                } else {
                    codes[idx / 2] |= code << 4;
                }
            }
        }
        Nf4Matrix {
            rows: t.rows(),
            cols: t.cols(),
            block,
            codes,
            scales,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Dequantize to dense f32.
    pub fn dequantize(&self) -> Tensor {
        let n = self.rows * self.cols;
        let mut out = vec![0.0f32; n];
        for (idx, o) in out.iter_mut().enumerate() {
            let code = if idx % 2 == 0 {
                self.codes[idx / 2] & 0x0F
            } else {
                self.codes[idx / 2] >> 4
            };
            let scale = self.scales[idx / self.block];
            *o = NF4_CODEBOOK[code as usize] * scale;
        }
        Tensor::from_vec(&[self.rows, self.cols], out)
    }

    /// Serialized size: codes + scales (+20B header).
    pub fn storage_bytes(&self) -> usize {
        20 + self.codes.len() + self.scales.len() * 4
    }

    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }

    /// Serialize (header + codes + scales).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.storage_bytes());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        out.extend_from_slice(&(self.block as u32).to_le_bytes());
        out.extend_from_slice(&(self.scales.len() as u32).to_le_bytes());
        out.extend_from_slice(&0x4E46u32.to_le_bytes()); // "NF"
        out.extend_from_slice(&self.codes);
        for &s in &self.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Nf4Matrix> {
        use anyhow::{bail, ensure};
        ensure!(bytes.len() >= 20, "nf4: truncated header");
        let rows = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let cols = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let block = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
        let nscales = u32::from_le_bytes(bytes[12..16].try_into()?) as usize;
        let magic = u32::from_le_bytes(bytes[16..20].try_into()?);
        if magic != 0x4E46 {
            bail!("nf4: bad magic");
        }
        let ncodes = (rows * cols).div_ceil(2);
        ensure!(
            bytes.len() == 20 + ncodes + nscales * 4,
            "nf4: bad payload size"
        );
        let codes = bytes[20..20 + ncodes].to_vec();
        let mut scales = Vec::with_capacity(nscales);
        let mut p = 20 + ncodes;
        for _ in 0..nscales {
            scales.push(f32::from_le_bytes(bytes[p..p + 4].try_into()?));
            p += 4;
        }
        Ok(Nf4Matrix {
            rows,
            cols,
            block,
            codes,
            scales,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn codebook_is_sorted_with_zero() {
        for w in NF4_CODEBOOK.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4_CODEBOOK[7], 0.0);
        assert_eq!(NF4_CODEBOOK[0], -1.0);
        assert_eq!(NF4_CODEBOOK[15], 1.0);
    }

    #[test]
    fn nearest_code_exact_hits() {
        for (i, &c) in NF4_CODEBOOK.iter().enumerate() {
            assert_eq!(nearest_code(c) as usize, i);
        }
        assert_eq!(nearest_code(-2.0), 0);
        assert_eq!(nearest_code(2.0), 15);
    }

    #[test]
    fn quantization_error_is_small_for_gaussian() {
        let mut rng = Rng::new(100);
        let t = Tensor::randn(&[64, 64], 0.02, &mut rng);
        let q = Nf4Matrix::quantize(&t, 64);
        let dq = q.dequantize();
        let rel = crate::tensor::sub(&dq, &t).fro_norm() / t.fro_norm();
        // NF4 on gaussian data: typical relative error ~6-9%.
        assert!(rel < 0.12, "rel={rel}");
    }

    #[test]
    fn zeros_roundtrip_exactly() {
        let t = Tensor::zeros(&[10, 10]);
        let q = Nf4Matrix::quantize(&t, 64);
        assert_eq!(q.dequantize(), t);
    }

    #[test]
    fn compression_near_8x() {
        let mut rng = Rng::new(101);
        let t = Tensor::randn(&[256, 256], 1.0, &mut rng);
        let q = Nf4Matrix::quantize(&t, 64);
        // 4 bits + f32 scale / 64 elems = 4.5 bits/elem → ~7.1x
        let ratio = q.compression_ratio();
        assert!(ratio > 6.5 && ratio < 8.0, "ratio={ratio}");
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Rng::new(102);
        let t = Tensor::randn(&[17, 31], 1.0, &mut rng);
        let q = Nf4Matrix::quantize(&t, 32);
        let back = Nf4Matrix::from_bytes(&q.to_bytes()).unwrap();
        assert_eq!(back, q);
        assert_eq!(back.dequantize(), q.dequantize());
    }

    #[test]
    fn prop_dequantized_within_block_absmax() {
        Prop::new(24).check(
            "nf4 |dq - x| <= scale * max_gap/2",
            |rng| {
                let r = 1 + rng.below(12);
                let c = 1 + rng.below(40);
                Tensor::randn(&[r, c], 0.5, rng)
            },
            |t| {
                let q = Nf4Matrix::quantize(t, 16);
                let dq = q.dequantize();
                // Per-entry error bounded by half the widest codebook gap
                // times the block scale.
                let max_gap = NF4_CODEBOOK
                    .windows(2)
                    .map(|w| w[1] - w[0])
                    .fold(0.0f32, f32::max);
                for idx in 0..t.len() {
                    let scale = t.data()
                        [idx / 16 * 16..((idx / 16 + 1) * 16).min(t.len())]
                        .iter()
                        .fold(0.0f32, |m, &x| m.max(x.abs()));
                    let err = (dq.data()[idx] - t.data()[idx]).abs();
                    if err > scale * max_gap / 2.0 + 1e-6 {
                        return Err(format!("idx={idx} err={err} scale={scale}"));
                    }
                }
                Ok(())
            },
        );
    }
}
