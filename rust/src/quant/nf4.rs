//! NF4 quantization (QLoRA's NormalFloat-4): a 16-level codebook of
//! normal-distribution quantiles, applied blockwise with absmax scaling,
//! two 4-bit codes packed per byte.
//!
//! QSALR (paper Table 6) composes this with a 20% static sparsity mask:
//! the *kept* values are NF4-quantized, the mask stays a bitmap.

use crate::sparse::BitmapMatrix;
use crate::tensor::Tensor;

/// The standard NF4 codebook (QLoRA, Dettmers et al. 2023): 16 values in
/// [-1, 1], quantiles of N(0,1) normalized to unit absmax, asymmetric with
/// an exact zero.
pub const NF4_CODEBOOK: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Blockwise-NF4-quantized matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Nf4Matrix {
    rows: usize,
    cols: usize,
    block: usize,
    /// Packed 4-bit codes, two per byte, row-major over elements.
    codes: Vec<u8>,
    /// One f32 absmax scale per block.
    scales: Vec<f32>,
}

/// Nearest codebook index for a value in [-1, 1].
#[inline]
fn nearest_code(x: f32) -> u8 {
    // Binary search over the sorted codebook, then pick nearer neighbor.
    let mut lo = 0usize;
    let mut hi = NF4_CODEBOOK.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if NF4_CODEBOOK[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    if (x - NF4_CODEBOOK[lo]).abs() <= (NF4_CODEBOOK[hi] - x).abs() {
        lo as u8
    } else {
        hi as u8
    }
}

impl Nf4Matrix {
    /// Quantize with the given block size (64 is the QLoRA default).
    pub fn quantize(t: &Tensor, block: usize) -> Nf4Matrix {
        assert!(block > 0);
        let n = t.len();
        let data = t.data();
        let nblocks = n.div_ceil(block);
        let mut scales = Vec::with_capacity(nblocks);
        let mut codes = vec![0u8; n.div_ceil(2)];
        for bi in 0..nblocks {
            let s = bi * block;
            let e = (s + block).min(n);
            let absmax = data[s..e].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax } else { 1.0 };
            scales.push(scale);
            let inv = 1.0 / scale;
            for (k, &x) in data[s..e].iter().enumerate() {
                let code = nearest_code(x * inv);
                let idx = s + k;
                if idx % 2 == 0 {
                    codes[idx / 2] |= code;
                } else {
                    codes[idx / 2] |= code << 4;
                }
            }
        }
        Nf4Matrix {
            rows: t.rows(),
            cols: t.cols(),
            block,
            codes,
            scales,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Dequantize to dense f32.
    pub fn dequantize(&self) -> Tensor {
        let n = self.rows * self.cols;
        let mut out = vec![0.0f32; n];
        for (idx, o) in out.iter_mut().enumerate() {
            let code = if idx % 2 == 0 {
                self.codes[idx / 2] & 0x0F
            } else {
                self.codes[idx / 2] >> 4
            };
            let scale = self.scales[idx / self.block];
            *o = NF4_CODEBOOK[code as usize] * scale;
        }
        Tensor::from_vec(&[self.rows, self.cols], out)
    }

    /// Serialized size: codes + scales (+20B header).
    pub fn storage_bytes(&self) -> usize {
        20 + self.codes.len() + self.scales.len() * 4
    }

    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }

    /// Serialize (header + codes + scales).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.storage_bytes());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        out.extend_from_slice(&(self.block as u32).to_le_bytes());
        out.extend_from_slice(&(self.scales.len() as u32).to_le_bytes());
        out.extend_from_slice(&0x4E46u32.to_le_bytes()); // "NF"
        out.extend_from_slice(&self.codes);
        for &s in &self.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Nf4Matrix> {
        use anyhow::{bail, ensure};
        ensure!(bytes.len() >= 20, "nf4: truncated header");
        let rows = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let cols = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let block = u32::from_le_bytes(bytes[8..12].try_into()?) as usize;
        let nscales = u32::from_le_bytes(bytes[12..16].try_into()?) as usize;
        let magic = u32::from_le_bytes(bytes[16..20].try_into()?);
        if magic != 0x4E46 {
            bail!("nf4: bad magic");
        }
        let ncodes = (rows * cols).div_ceil(2);
        ensure!(
            bytes.len() == 20 + ncodes + nscales * 4,
            "nf4: bad payload size"
        );
        let codes = bytes[20..20 + ncodes].to_vec();
        let mut scales = Vec::with_capacity(nscales);
        let mut p = 20 + ncodes;
        for _ in 0..nscales {
            scales.push(f32::from_le_bytes(bytes[p..p + 4].try_into()?));
            p += 4;
        }
        Ok(Nf4Matrix {
            rows,
            cols,
            block,
            codes,
            scales,
        })
    }
}

/// Bitmap sparsity pattern + NF4-quantized nonzero stream: the QSALR
/// compressed form (paper Table 6). The mask is the same byte-blocked
/// bitmap as [`BitmapMatrix`]; the kept values are NF4-quantized as one
/// `1 × max(nnz, 1)` tensor, so a value's block scale depends on its
/// *rank in the nonzero stream*, not its matrix position.
///
/// [`SparseNf4Matrix::value`] is the single dequantization rule: every
/// consumer (full decode, per-row pipeline decode, the fused GEMM pack)
/// computes `NF4_CODEBOOK[code] * scale` through it, which is what makes
/// the fused kernel path bitwise identical to dequantize-then-GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseNf4Matrix {
    rows: usize,
    cols: usize,
    /// `ceil(cols/8)` mask bytes per row, row-major (BitmapMatrix layout).
    masks: Vec<u8>,
    /// Per-row offsets into the nonzero stream (len = rows + 1).
    row_offsets: Vec<u32>,
    nnz: usize,
    /// NF4 codes + scales over the nonzero stream (shape 1 × max(nnz,1)).
    values: Nf4Matrix,
}

impl SparseNf4Matrix {
    /// Encode a dense matrix: exact zeros become mask holes, kept values
    /// are NF4-quantized with the given block size.
    pub fn encode(t: &Tensor, block: usize) -> SparseNf4Matrix {
        Self::from_bitmap(&BitmapMatrix::encode(t), block)
    }

    /// Re-quantize an already-bitmap-encoded matrix. The kept values are
    /// quantized as a `1 × max(nnz, 1)` tensor (a zero placeholder when
    /// the matrix is empty, so the NF4 payload is never zero-length).
    pub fn from_bitmap(bm: &BitmapMatrix, block: usize) -> SparseNf4Matrix {
        let mut kept = bm.values().to_vec();
        if kept.is_empty() {
            kept.push(0.0);
        }
        let len = kept.len();
        let values = Nf4Matrix::quantize(&Tensor::from_vec(&[1, len], kept), block);
        SparseNf4Matrix {
            rows: bm.rows(),
            cols: bm.cols(),
            masks: bm.masks().to_vec(),
            row_offsets: bm.row_offsets().to_vec(),
            nnz: bm.nnz(),
            values,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Bytes per row of bitmap.
    pub fn bytes_per_row(&self) -> usize {
        self.cols.div_ceil(8)
    }

    pub fn masks(&self) -> &[u8] {
        &self.masks
    }

    pub fn row_offsets(&self) -> &[u32] {
        &self.row_offsets
    }

    /// Dequantize the `voff`-th nonzero of the stream. One LUT lookup and
    /// one multiply — the inlined per-element decode the fused GEMM pack
    /// and the pipelined row decode both go through.
    #[inline]
    pub fn value(&self, voff: usize) -> f32 {
        let byte = self.values.codes[voff / 2];
        let code = if voff % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        NF4_CODEBOOK[code as usize] * self.values.scales[voff / self.values.block]
    }

    /// Decode one row into a caller-provided buffer of length `cols`,
    /// word-at-a-time like [`BitmapMatrix::decode_row_into`], but scattering
    /// LUT-dequantized values instead of stored f32s.
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert!(out.len() >= self.cols);
        let bpr = self.bytes_per_row();
        let mut voff = self.row_offsets[i] as usize;
        let row_masks = &self.masks[i * bpr..(i + 1) * bpr];
        let words = self.cols / 64;
        for wi in 0..words {
            let mbytes: [u8; 8] = row_masks[wi * 8..wi * 8 + 8].try_into().unwrap();
            let mut m = u64::from_le_bytes(mbytes);
            let seg = &mut out[wi * 64..wi * 64 + 64];
            seg.fill(0.0);
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                seg[t] = self.value(voff);
                voff += 1;
                m &= m - 1;
            }
        }
        // Byte tail for the remaining < 64 columns.
        for b in words * 8..bpr {
            let base = b * 8;
            let lanes = (self.cols - base).min(8);
            out[base..base + lanes].fill(0.0);
            let mut m = row_masks[b];
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                out[base + t] = self.value(voff);
                voff += 1;
                m &= m - 1;
            }
        }
    }

    /// Decode a contiguous block of rows `[r0, r1)` into `out` (row-major,
    /// `(r1-r0) × cols`) — the pipeline's decode-stage unit of work.
    pub fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        let cols = self.cols;
        for (k, i) in (r0..r1).enumerate() {
            self.decode_row_into(i, &mut out[k * cols..(k + 1) * cols]);
        }
    }

    /// Decode the full matrix to dense (dequantized) f32.
    pub fn decode(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let cols = self.cols;
        for i in 0..self.rows {
            self.decode_row_into(i, &mut out.data_mut()[i * cols..(i + 1) * cols]);
        }
        out
    }

    /// Serialized size: length prefixes + pattern + NF4 payload.
    pub fn storage_bytes(&self) -> usize {
        8 + 16 + self.masks.len() + self.values.storage_bytes()
    }

    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.storage_bytes() as f64
    }

    /// Serialize: `[u32 pattern_len][u32 nf4_len][pattern][nf4]` — the
    /// exact `Encoding::SparseNf4` tensor payload of the model file format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut pattern = Vec::with_capacity(16 + self.masks.len());
        pattern.extend_from_slice(&(self.rows as u32).to_le_bytes());
        pattern.extend_from_slice(&(self.cols as u32).to_le_bytes());
        pattern.extend_from_slice(&(self.nnz as u32).to_le_bytes());
        pattern.extend_from_slice(&0xB17Bu32.to_le_bytes()); // pattern magic
        pattern.extend_from_slice(&self.masks);
        let nf = self.values.to_bytes();
        let mut out = Vec::with_capacity(8 + pattern.len() + nf.len());
        out.extend_from_slice(&(pattern.len() as u32).to_le_bytes());
        out.extend_from_slice(&(nf.len() as u32).to_le_bytes());
        out.extend_from_slice(&pattern);
        out.extend_from_slice(&nf);
        out
    }

    /// Deserialize from `to_bytes` output.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<SparseNf4Matrix> {
        use anyhow::{bail, ensure};
        ensure!(bytes.len() >= 8, "sparse-nf4: truncated length prefix");
        let plen = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let nlen = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        ensure!(bytes.len() == 8 + plen + nlen, "sparse-nf4: bad payload size");
        let pattern = &bytes[8..8 + plen];
        ensure!(pattern.len() >= 16, "sparse-nf4: truncated pattern header");
        let rows = u32::from_le_bytes(pattern[0..4].try_into()?) as usize;
        let cols = u32::from_le_bytes(pattern[4..8].try_into()?) as usize;
        let nnz = u32::from_le_bytes(pattern[8..12].try_into()?) as usize;
        let magic = u32::from_le_bytes(pattern[12..16].try_into()?);
        if magic != 0xB17B {
            bail!("sparse-nf4: bad pattern magic {magic:#x}");
        }
        let bpr = cols.div_ceil(8);
        ensure!(pattern.len() == 16 + rows * bpr, "sparse-nf4: bad pattern size");
        let masks = pattern[16..].to_vec();
        let mut row_offsets = Vec::with_capacity(rows + 1);
        row_offsets.push(0u32);
        let mut acc = 0u32;
        for i in 0..rows {
            for b in 0..bpr {
                acc += masks[i * bpr + b].count_ones();
            }
            row_offsets.push(acc);
        }
        ensure!(acc as usize == nnz, "sparse-nf4: popcount != nnz");
        let values = Nf4Matrix::from_bytes(&bytes[8 + plen..])?;
        ensure!(
            values.rows * values.cols == nnz.max(1),
            "sparse-nf4: value stream length mismatch"
        );
        Ok(SparseNf4Matrix {
            rows,
            cols,
            masks,
            row_offsets,
            nnz,
            values,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn codebook_is_sorted_with_zero() {
        for w in NF4_CODEBOOK.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4_CODEBOOK[7], 0.0);
        assert_eq!(NF4_CODEBOOK[0], -1.0);
        assert_eq!(NF4_CODEBOOK[15], 1.0);
    }

    #[test]
    fn nearest_code_exact_hits() {
        for (i, &c) in NF4_CODEBOOK.iter().enumerate() {
            assert_eq!(nearest_code(c) as usize, i);
        }
        assert_eq!(nearest_code(-2.0), 0);
        assert_eq!(nearest_code(2.0), 15);
    }

    #[test]
    fn quantization_error_is_small_for_gaussian() {
        let mut rng = Rng::new(100);
        let t = Tensor::randn(&[64, 64], 0.02, &mut rng);
        let q = Nf4Matrix::quantize(&t, 64);
        let dq = q.dequantize();
        let rel = crate::tensor::sub(&dq, &t).fro_norm() / t.fro_norm();
        // NF4 on gaussian data: typical relative error ~6-9%.
        assert!(rel < 0.12, "rel={rel}");
    }

    #[test]
    fn zeros_roundtrip_exactly() {
        let t = Tensor::zeros(&[10, 10]);
        let q = Nf4Matrix::quantize(&t, 64);
        assert_eq!(q.dequantize(), t);
    }

    #[test]
    fn compression_near_8x() {
        let mut rng = Rng::new(101);
        let t = Tensor::randn(&[256, 256], 1.0, &mut rng);
        let q = Nf4Matrix::quantize(&t, 64);
        // 4 bits + f32 scale / 64 elems = 4.5 bits/elem → ~7.1x
        let ratio = q.compression_ratio();
        assert!(ratio > 6.5 && ratio < 8.0, "ratio={ratio}");
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Rng::new(102);
        let t = Tensor::randn(&[17, 31], 1.0, &mut rng);
        let q = Nf4Matrix::quantize(&t, 32);
        let back = Nf4Matrix::from_bytes(&q.to_bytes()).unwrap();
        assert_eq!(back, q);
        assert_eq!(back.dequantize(), q.dequantize());
    }

    fn random_sparse(rng: &mut Rng, r: usize, c: usize, p: f64) -> Tensor {
        let mut t = Tensor::randn(&[r, c], 1.0, rng);
        crate::prune::prune_global(&mut [&mut t], p);
        t
    }

    #[test]
    fn sparse_nf4_decode_matches_pattern_plus_dequantize_oracle() {
        // The fused representation must reproduce exactly what the
        // two-step serialize path produces: quantize the kept values as a
        // 1×nnz tensor, dequantize, scatter through the bitmap pattern.
        let mut rng = Rng::new(110);
        for &(r, c, p) in &[(16usize, 64usize, 0.5), (7, 13, 0.3), (3, 130, 0.9), (1, 1, 1.0)] {
            let t = random_sparse(&mut rng, r, c, p);
            let bm = BitmapMatrix::encode(&t);
            let snf = SparseNf4Matrix::from_bitmap(&bm, 64);
            let mut kept = bm.values().to_vec();
            if kept.is_empty() {
                kept.push(0.0);
            }
            let klen = kept.len();
            let q = Nf4Matrix::quantize(&Tensor::from_vec(&[1, klen], kept), 64);
            let mut dq = q.dequantize().data().to_vec();
            dq.truncate(bm.nnz());
            let oracle = BitmapMatrix::from_pattern_and_values(&bm.pattern_bytes(), dq)
                .unwrap()
                .decode();
            let got = snf.decode();
            assert_eq!(got.data().len(), oracle.data().len());
            for (a, b) in got.data().iter().zip(oracle.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "({r},{c},{p})");
            }
            // And the inline accessor agrees with the decoded stream.
            for v in 0..bm.nnz() {
                assert_eq!(snf.value(v).to_bits(), q.dequantize().data()[v].to_bits());
            }
        }
    }

    #[test]
    fn sparse_nf4_serialization_roundtrip() {
        let mut rng = Rng::new(111);
        let t = random_sparse(&mut rng, 19, 41, 0.5);
        let snf = SparseNf4Matrix::encode(&t, 64);
        let bytes = snf.to_bytes();
        assert_eq!(bytes.len(), snf.storage_bytes());
        let back = SparseNf4Matrix::from_bytes(&bytes).unwrap();
        assert_eq!(back, snf);
        assert!(SparseNf4Matrix::from_bytes(&bytes[..6]).is_err());
        let mut corrupt = bytes.clone();
        corrupt[8 + 12] = 0xFF; // pattern magic
        assert!(SparseNf4Matrix::from_bytes(&corrupt).is_err());
    }

    #[test]
    fn sparse_nf4_empty_matrix_roundtrips() {
        let t = Tensor::zeros(&[5, 9]);
        let snf = SparseNf4Matrix::encode(&t, 64);
        assert_eq!(snf.nnz(), 0);
        assert_eq!(snf.decode(), t);
        let back = SparseNf4Matrix::from_bytes(&snf.to_bytes()).unwrap();
        assert_eq!(back, snf);
    }

    #[test]
    fn sparse_nf4_worst_case_error_is_bounded() {
        // Per-entry worst case: half the widest codebook gap times the
        // absmax of the value's 64-wide *stream* block (zeros are exact —
        // they are mask holes, never quantized).
        let mut rng = Rng::new(112);
        let t = random_sparse(&mut rng, 24, 96, 0.5);
        let bm = BitmapMatrix::encode(&t);
        let snf = SparseNf4Matrix::from_bitmap(&bm, 64);
        let dq = snf.decode();
        let max_gap = NF4_CODEBOOK
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0f32, f32::max);
        let kept = bm.values();
        for (v, &x) in kept.iter().enumerate() {
            let blk = &kept[v / 64 * 64..((v / 64 + 1) * 64).min(kept.len())];
            let scale = blk.iter().fold(0.0f32, |m, &y| m.max(y.abs()));
            let err = (snf.value(v) - x).abs();
            assert!(
                err <= scale * max_gap / 2.0 + 1e-6,
                "voff={v} err={err} scale={scale}"
            );
        }
        for idx in 0..t.len() {
            if t.data()[idx] == 0.0 {
                assert_eq!(dq.data()[idx], 0.0, "hole {idx} must decode to exact zero");
            }
        }
    }

    #[test]
    fn prop_dequantized_within_block_absmax() {
        Prop::new(24).check(
            "nf4 |dq - x| <= scale * max_gap/2",
            |rng| {
                let r = 1 + rng.below(12);
                let c = 1 + rng.below(40);
                Tensor::randn(&[r, c], 0.5, rng)
            },
            |t| {
                let q = Nf4Matrix::quantize(t, 16);
                let dq = q.dequantize();
                // Per-entry error bounded by half the widest codebook gap
                // times the block scale.
                let max_gap = NF4_CODEBOOK
                    .windows(2)
                    .map(|w| w[1] - w[0])
                    .fold(0.0f32, f32::max);
                for idx in 0..t.len() {
                    let scale = t.data()
                        [idx / 16 * 16..((idx / 16 + 1) * 16).min(t.len())]
                        .iter()
                        .fold(0.0f32, |m, &x| m.max(x.abs()));
                    let err = (dq.data()[idx] - t.data()[idx]).abs();
                    if err > scale * max_gap / 2.0 + 1e-6 {
                        return Err(format!("idx={idx} err={err} scale={scale}"));
                    }
                }
                Ok(())
            },
        );
    }
}
