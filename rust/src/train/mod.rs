//! Fine-tuning driver: executes the AOT train-step artifacts in a loop,
//! owning all state (params, optimizer moments, batches) on the rust side.
//! Implements the paper's training protocol: Adam on the LoRA adapters,
//! Theorem-4 SGD (η = 1/σ_max(X)², power-iteration estimated) on the
//! sparsity-preservation residual, and periodic dynamic-mask refresh for
//! the LoSA baseline.

mod driver;
mod step;

pub use driver::{finetune, pretrain, FinetuneData, FinetuneReport, TrainConfig};
pub use step::StepLoop;
