//! High-level training drivers: pretraining the base model and fine-tuning
//! each baseline, with loss logging and the Theorem-4 η schedule.

use super::step::StepLoop;
use crate::data::{Batch, BatchBuilder, CorpusGen, MathExample, McqExample};
use crate::linalg::PowerIter;
use crate::model::ParamStore;
use crate::runtime::{ModelCfg, Runtime};
use crate::salr::{Baseline, BaselineSpec};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;

/// Knobs shared by the training drivers.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
    /// Refresh LoSA dynamic masks every n steps (0 = never).
    pub mask_refresh: usize,
    /// Safety factor on η* = 1/σ_max(X)² (paper: "or more conservatively,
    /// half this value").
    pub eta_safety: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 1e-3,
            seed: 17,
            log_every: 50,
            mask_refresh: 25,
            eta_safety: 0.5,
        }
    }
}

/// Pretrain the dense base model on the synthetic corpus. Returns the
/// trained parameters and the loss history.
pub fn pretrain(
    runtime: &Runtime,
    cfg: &ModelCfg,
    tc: &TrainConfig,
) -> Result<(ParamStore, Vec<f32>)> {
    let mut rng = Rng::new(tc.seed);
    let params = ParamStore::init_base(cfg, &mut rng);
    let opt_m = params.zeros_like();
    let opt_v = params.zeros_like();
    let artifact = format!("pretrain_{}", cfg.name);
    let mut looph = StepLoop::new(
        runtime,
        &artifact,
        &[("param:", &params), ("m:", &opt_m), ("v:", &opt_v)],
    )?;
    let mut corpus = CorpusGen::new(tc.seed ^ 0xC0);
    let bb = BatchBuilder::new(cfg.batch_size, cfg.max_seq_len);
    let mut losses = Vec::with_capacity(tc.steps);
    for step in 0..tc.steps {
        let windows: Vec<Vec<i32>> = (0..cfg.batch_size)
            .map(|_| corpus.next_window(cfg.max_seq_len))
            .collect();
        let batch = bb.from_windows(&windows);
        let loss = looph.step(&batch, tc.lr, 0.0)?;
        losses.push(loss);
        if tc.log_every > 0 && (step + 1) % tc.log_every == 0 {
            log::info!("pretrain step {:>5}: loss {:.4}", step + 1, loss);
        }
    }
    Ok((looph.extract("param:"), losses))
}

/// The fine-tuning corpus: either math SFT pairs or MCQ SFT pairs.
pub enum FinetuneData {
    Math(Vec<MathExample>),
    Mcq(Vec<McqExample>),
}

impl FinetuneData {
    fn sample_batch(&self, bb: &BatchBuilder, rng: &mut Rng) -> Batch {
        // Packed rows: several (prompt, answer) pairs per sequence, loss on
        // answers only — the supervision-dense SFT layout.
        match self {
            FinetuneData::Math(ex) => {
                bb.sample_packed(ex, rng, |e| (e.prompt.clone(), e.target.clone()))
            }
            FinetuneData::Mcq(ex) => bb.sample_packed(ex, rng, |e| {
                (e.prompt.clone(), e.answer().to_string())
            }),
        }
    }
}

/// Result of a fine-tuning run.
pub struct FinetuneReport {
    /// Trained adapters (`*.lora_a/b` and, for SALR, `*.res_a/b`).
    pub adapters: ParamStore,
    pub losses: Vec<f32>,
    /// The Theorem-4 step size used for the residual.
    pub eta: f32,
    /// Wall time of the optimization loop.
    pub train_secs: f64,
    /// Peak RSS observed (bytes).
    pub peak_rss: u64,
}

/// Fine-tune a baseline. `spec` carries the (possibly pruned) frozen base,
/// masks and SVD residual; this function owns adapters + optimizer state.
pub fn finetune(
    runtime: &Runtime,
    cfg: &ModelCfg,
    spec: &mut BaselineSpec,
    data: &FinetuneData,
    tc: &TrainConfig,
) -> Result<FinetuneReport> {
    let variant = spec
        .baseline
        .train_variant()
        .expect("finetune called on Pretrained");
    let mut rng = Rng::new(tc.seed ^ 0xF1);
    let with_residual = variant == "salr";
    let mut adapters = ParamStore::init_adapters(cfg, &mut rng, with_residual);
    if let Some(res) = &spec.residual {
        for (k, v) in res.iter() {
            adapters.insert(k, v.clone());
        }
    }
    let opt_m = adapters.zeros_like();
    let opt_v = adapters.zeros_like();

    // Theorem 4: η* = 1/σ_max(X)², X = layer inputs on a representative
    // mini-batch. We estimate σ_max on the embedded token batch (the
    // first-layer input; deeper activations are RMS-normalized to the same
    // scale) and apply the safety factor.
    let bb = BatchBuilder::new(cfg.batch_size, cfg.max_seq_len);
    let probe = data.sample_batch(&bb, &mut rng);
    let eta = if spec.eta_scale > 0.0 {
        let x = embed_batch(cfg, &spec.params, &probe);
        let sigma = PowerIter::default().sigma_max(&x);
        ((tc.eta_safety / (sigma * sigma).max(1e-12)) * spec.eta_scale) as f32
    } else {
        0.0
    };

    let artifact = format!("train_{}_{}", variant, cfg.name);
    let mut stores: Vec<(&str, &ParamStore)> = vec![
        ("train:", &adapters),
        ("m:", &opt_m),
        ("v:", &opt_v),
    ];
    // LoSA masks live in the frozen group (python keeps them beside the
    // base params).
    let frozen_with_masks;
    if let Some(masks) = &spec.masks {
        let mut f = spec.params.clone();
        for (k, v) in masks.iter() {
            f.insert(k, v.clone());
        }
        frozen_with_masks = f;
        stores.push(("frozen:", &frozen_with_masks));
    } else {
        stores.push(("frozen:", &spec.params));
    }
    let mut looph = StepLoop::new(runtime, &artifact, &stores)?;
    drop(stores);

    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(tc.steps);
    for step in 0..tc.steps {
        let batch = data.sample_batch(&bb, &mut rng);
        let loss = looph.step(&batch, tc.lr, eta)?;
        losses.push(loss);
        if tc.log_every > 0 && (step + 1) % tc.log_every == 0 {
            log::info!(
                "finetune[{}] step {:>5}: loss {:.4}",
                spec.baseline.name(),
                step + 1,
                loss
            );
        }
        // Dynamic-mask refresh for LoSA: recompute the Method-3 mask from
        // the current merged weights.
        if spec.baseline == Baseline::Losa
            && tc.mask_refresh > 0
            && (step + 1) % tc.mask_refresh == 0
            && step + 1 < tc.steps
        {
            let current = looph.extract("train:");
            spec.refresh_losa_masks(cfg, &current, losa_ratio(spec));
            if let Some(masks) = &spec.masks {
                for (k, v) in masks.iter() {
                    looph.rebind(&format!("frozen:{k}"), v)?;
                }
            }
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    Ok(FinetuneReport {
        adapters: looph.extract("train:"),
        losses,
        eta,
        train_secs,
        peak_rss: crate::util::mem::peak_rss_bytes(),
    })
}

/// Current LoSA sparsity target (stored on the spec's first mask).
fn losa_ratio(spec: &BaselineSpec) -> f64 {
    spec.masks
        .as_ref()
        .and_then(|m| m.iter().next().map(|(_, t)| t.sparsity()))
        .unwrap_or(0.5)
}

/// Embed a token batch through the (frozen) embedding + positions:
/// the Theorem-4 design matrix X ∈ R^{(B·S) × d_model}.
fn embed_batch(cfg: &ModelCfg, params: &ParamStore, batch: &Batch) -> Tensor {
    let embed = params.get("embed").expect("embed");
    let pos = params.get("pos_embed").expect("pos_embed");
    let rows = batch.batch * batch.seq;
    let mut x = Tensor::zeros(&[rows, cfg.d_model]);
    for b in 0..batch.batch {
        for s in 0..batch.seq {
            let tok = batch.tokens[b * batch.seq + s].clamp(0, cfg.vocab_size as i32 - 1)
                as usize;
            let row = b * batch.seq + s;
            for d in 0..cfg.d_model {
                x.set(row, d, embed.at(tok, d) + pos.at(s, d));
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_config_defaults_sane() {
        let tc = TrainConfig::default();
        assert!(tc.steps > 0 && tc.lr > 0.0 && tc.eta_safety <= 1.0);
    }
}
