//! `StepLoop`: compile-once / execute-many wrapper around a train-step
//! artifact. Keeps every input as a packed literal; per step only the
//! changing inputs (batch, scalars, updated trainables) are re-packed —
//! the large frozen weights are packed exactly once.

use crate::data::Batch;
use crate::model::ParamStore;
use crate::runtime::{Dtype, Executor, Runtime, Value};
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// A reusable train-step execution loop.
pub struct StepLoop {
    exec: Arc<Executor>,
    /// Packed literals in manifest input order.
    literals: Vec<xla::Literal>,
    /// input name → index.
    pos: HashMap<String, usize>,
    /// (output index, input index, name) for state that feeds back
    /// (train/m/v for finetune, param/m/v for pretrain).
    feedback: Vec<(usize, usize, String)>,
    loss_index: usize,
    /// Shadow copies of the fed-back state, keyed by full name.
    state: HashMap<String, Tensor>,
    /// Step counter (drives Adam bias correction).
    t: f32,
}

impl StepLoop {
    /// Prepare a loop for the named artifact. `stores` binds input-name
    /// prefixes to parameter stores, e.g.
    /// `[("frozen:", &spec.params), ("train:", &adapters), ...]`.
    pub fn new(
        runtime: &Runtime,
        artifact: &str,
        stores: &[(&str, &ParamStore)],
    ) -> Result<StepLoop> {
        let exec = runtime.executor(artifact)?;
        let spec = exec.spec().clone();
        let mut pos = HashMap::new();
        for (i, io) in spec.inputs.iter().enumerate() {
            pos.insert(io.name.clone(), i);
        }
        let mut literals: Vec<Option<xla::Literal>> = Vec::new();
        for _ in &spec.inputs {
            literals.push(None);
        }
        for io in &spec.inputs {
            let i = pos[&io.name];
            // Tensor inputs come from the bound stores; the per-step
            // inputs (t/tokens/loss_mask/lr/eta) start as zeros.
            let mut bound = false;
            for (prefix, store) in stores {
                if let Some(key) = io.name.strip_prefix(prefix) {
                    if let Some(t) = store.get(key) {
                        ensure!(
                            t.shape() == io.shape.as_slice(),
                            "shape mismatch for {}: store {:?} vs manifest {:?}",
                            io.name,
                            t.shape(),
                            io.shape
                        );
                        literals[i] = Some(exec.literal_for(&io.name, &t.into())?);
                        bound = true;
                        break;
                    }
                }
            }
            if !bound {
                let v = match io.dtype {
                    Dtype::F32 => {
                        Value::F32(vec![0.0; io.elems()])
                    }
                    Dtype::I32 => {
                        Value::I32(vec![0; io.elems()])
                    }
                    Dtype::U32 => {
                        Value::U32(vec![0; io.elems()])
                    }
                };
                literals[i] = Some(exec.literal_for(&io.name, &v)?);
            }
        }
        // Feedback wiring: any output whose name is also an input.
        let mut feedback = Vec::new();
        let mut state = HashMap::new();
        for (oi, out) in spec.outputs.iter().enumerate() {
            if let Some(&ii) = pos.get(&out.name) {
                feedback.push((oi, ii, out.name.clone()));
                // Seed the shadow state from the bound stores.
                for (prefix, store) in stores {
                    if let Some(key) = out.name.strip_prefix(prefix) {
                        if let Some(t) = store.get(key) {
                            state.insert(out.name.clone(), t.clone());
                        }
                    }
                }
            }
        }
        let loss_index = spec
            .output_index("loss")
            .context("artifact has no loss output")?;
        Ok(StepLoop {
            exec,
            literals: literals.into_iter().map(Option::unwrap).collect(),
            pos,
            feedback,
            loss_index,
            state,
            t: 0.0,
        })
    }

    /// Rebind one named input (e.g. refreshed LoSA masks).
    pub fn rebind(&mut self, name: &str, t: &Tensor) -> Result<()> {
        let &i = self
            .pos
            .get(name)
            .with_context(|| format!("no input {name}"))?;
        self.literals[i] = self.exec.literal_for(name, &t.into())?;
        Ok(())
    }

    /// Run one optimization step; returns the loss. `eta` is ignored by
    /// artifacts without an `eta` input (pretrain / non-SALR variants).
    pub fn step(&mut self, batch: &Batch, lr: f32, eta: f32) -> Result<f32> {
        self.t += 1.0;
        self.set("t", Value::F32(vec![self.t]))?;
        self.set("tokens", Value::I32(batch.tokens.clone()))?;
        self.set("loss_mask", Value::F32(batch.loss_mask.clone()))?;
        self.set("lr", Value::F32(vec![lr]))?;
        if self.pos.contains_key("eta") {
            self.set("eta", Value::F32(vec![eta]))?;
        }
        let outputs = self.exec.run_literals(&self.literals)?;
        for (oi, ii, name) in &self.feedback {
            let t = &outputs[*oi];
            self.literals[*ii] = self.exec.literal_for(name, &t.into())?;
            self.state.insert(name.clone(), t.clone());
        }
        Ok(outputs[self.loss_index].data()[0])
    }

    fn set(&mut self, name: &str, v: Value) -> Result<()> {
        if let Some(&i) = self.pos.get(name) {
            self.literals[i] = self.exec.literal_for(name, &v)?;
        }
        Ok(())
    }

    /// Extract the current fed-back state for a prefix (e.g. `"train:"`).
    pub fn extract(&self, prefix: &str) -> ParamStore {
        let mut out = ParamStore::new();
        for (name, t) in &self.state {
            if let Some(key) = name.strip_prefix(prefix) {
                out.insert(key, t.clone());
            }
        }
        out
    }

    /// Number of optimizer steps taken.
    pub fn steps_taken(&self) -> usize {
        self.t as usize
    }
}
