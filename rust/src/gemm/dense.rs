//! Blocked, register-tiled, multi-core dense f32 GEMM.
//!
//! Row-major `C[m,n] = A[m,k] @ B[k,n]`. The serial kernel tiles M×N into
//! 4×16 register blocks accumulated over a K panel, with an L2-friendly
//! outer blocking and a packed-B layout so the micro-kernel streams
//! contiguous memory. The parallel entry points partition M into fixed
//! `BAND`-row bands executed on the persistent worker pool: bands own
//! disjoint C row blocks, so there is no locking and — because band
//! boundaries are independent of the thread count — the output is
//! **bitwise identical** at every pool size. This is the compute stage of
//! the two-stage sparse pipeline and the dense baseline for every speedup
//! table, so it needs to be fast enough that the *pipeline*, not the MACs,
//! is what the benchmarks compare.

use crate::util::pool::{SendPtr, WorkerPool};

/// Outer cache blocking: M rows per L2 block.
pub const MC: usize = 64;
/// Outer cache blocking: K depth per packed panel.
pub const KC: usize = 256;
/// Outer cache blocking: N columns per packed panel group.
pub const NC: usize = 512;

/// Register micro-tile.
const MR: usize = 4;
const NR: usize = 16;

/// Rows per parallel band. A fixed multiple of `MR` (so tile boundaries
/// match the serial kernel's) and small enough that a 64-row GEMM still
/// spreads across 4 workers; the extra per-band B packing costs
/// `BAND⁻¹ ≈ 6%` of the MAC traffic.
const BAND: usize = 16;

/// `C = A @ B` (C overwritten), on the process-global pool.
pub fn gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c[..m * n].fill(0.0);
    gemm_f32_acc(a, b, c, m, k, n);
}

/// `C += A @ B`, on the process-global pool.
pub fn gemm_f32_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_f32_acc_pool(a, b, c, m, k, n, &WorkerPool::global());
}

/// `C = A @ B` on an explicit pool.
pub fn gemm_f32_pool(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
) {
    c[..m * n].fill(0.0);
    gemm_f32_acc_pool(a, b, c, m, k, n, pool);
}

/// `C += A @ B` on an explicit pool.
pub fn gemm_f32_acc_pool(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
) {
    assert!(a.len() >= m * k, "A too small");
    assert!(b.len() >= k * n, "B too small");
    assert!(c.len() >= m * n, "C too small");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Small problems: skip blocking and packing overhead.
    if m * n * k <= 32 * 32 * 32 {
        return gemm_small_acc(a, b, c, m, k, n);
    }
    let bands = m.div_ceil(BAND);
    if bands == 1 || pool.threads() == 1 {
        let mut packed = Vec::new();
        return gemm_band_acc(a, b, c, m, k, n, &mut packed);
    }
    let cptr = SendPtr(c.as_mut_ptr());
    pool.run(bands, &|bi| {
        let r0 = bi * BAND;
        let r1 = ((bi + 1) * BAND).min(m);
        let rows = r1 - r0;
        // SAFETY: band `bi` exclusively owns C rows [r0, r1) (and only
        // reads the matching A rows), so bands race on nothing.
        let band_c = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), rows * n) };
        let mut packed = Vec::new();
        gemm_band_acc(&a[r0 * k..], b, band_c, rows, k, n, &mut packed);
    });
}

/// Serial blocked GEMM over one row band (`C[m,n] += A[m,k] @ B[k,n]`),
/// packing each B panel once per (jc, pc) block.
#[allow(clippy::too_many_arguments)]
fn gemm_band_acc(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    packed: &mut Vec<f32>,
) {
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            pack_b_panels(b, packed, n, pc, jc, kb, nb);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                block_kernel(a, packed, c, k, n, ic, pc, jc, mb, kb, nb);
            }
        }
    }
}

/// Pack `B[pc..pc+kb, jc..jc+nb]` into NR-wide column panels, panel-major
/// (`packed[panel][p][lane]`, zero-padded to NR lanes), so the micro-kernel
/// reads one contiguous NR-row per k step instead of striding by `n`.
#[allow(clippy::too_many_arguments)]
fn pack_b_panels(
    b: &[f32],
    packed: &mut Vec<f32>,
    n: usize,
    pc: usize,
    jc: usize,
    kb: usize,
    nb: usize,
) {
    let npanels = nb.div_ceil(NR);
    let len = npanels * kb * NR;
    // Zero only when the geometry changes. Stale values in a reused
    // buffer's padding lanes are harmless: the micro-kernels accumulate
    // all NR lanes but write back only the `nr` real ones.
    if packed.len() != len {
        packed.clear();
        packed.resize(len, 0.0);
    }
    for pj in 0..npanels {
        let j0 = jc + pj * NR;
        let lanes = NR.min(jc + nb - j0);
        let dst_base = pj * kb * NR;
        for p in 0..kb {
            let src = (pc + p) * n + j0;
            let dst = dst_base + p * NR;
            packed[dst..dst + lanes].copy_from_slice(&b[src..src + lanes]);
        }
    }
}

/// One (mb × nb) block over a kb panel, micro-tiled MR×NR against packed B.
#[allow(clippy::too_many_arguments)]
fn block_kernel(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    ic: usize,
    pc: usize,
    jc: usize,
    mb: usize,
    kb: usize,
    nb: usize,
) {
    let mut i = 0;
    while i < mb {
        let mr = MR.min(mb - i);
        let mut pj = 0;
        while pj * NR < nb {
            let j = pj * NR;
            let nr = NR.min(nb - j);
            let panel = &packed[pj * kb * NR..(pj + 1) * kb * NR];
            if mr == MR {
                micro_4x16(a, panel, c, k, n, ic + i, pc, jc + j, kb, nr);
            } else {
                micro_edge(a, panel, c, k, n, ic + i, pc, jc + j, mr, kb, nr);
            }
            pj += 1;
        }
        i += MR;
    }
}

/// 4×16 register-tiled micro-kernel over a packed B panel:
/// `C[i0..i0+4, j0..j0+nr] += A-panel @ B-panel`.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_4x16(
    a: &[f32],
    panel: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    i0: usize,
    p0: usize,
    j0: usize,
    kb: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kb {
        let brow = &panel[p * NR..p * NR + NR];
        // Unrolled over the 4 A rows; the NR-wide inner loop vectorizes.
        let a0 = a[i0 * k + p0 + p];
        let a1 = a[(i0 + 1) * k + p0 + p];
        let a2 = a[(i0 + 2) * k + p0 + p];
        let a3 = a[(i0 + 3) * k + p0 + p];
        for jj in 0..NR {
            let bv = brow[jj];
            acc[0][jj] += a0 * bv;
            acc[1][jj] += a1 * bv;
            acc[2][jj] += a2 * bv;
            acc[3][jj] += a3 * bv;
        }
    }
    for (ii, accrow) in acc.iter().enumerate() {
        let crow = &mut c[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nr];
        for jj in 0..nr {
            crow[jj] += accrow[jj];
        }
    }
}

/// Edge micro-kernel for ragged row tiles (mr < 4), same packed panel.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_edge(
    a: &[f32],
    panel: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    i0: usize,
    p0: usize,
    j0: usize,
    mr: usize,
    kb: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kb {
        let brow = &panel[p * NR..p * NR + NR];
        for (ii, accrow) in acc.iter_mut().take(mr).enumerate() {
            let av = a[(i0 + ii) * k + p0 + p];
            if av == 0.0 {
                continue;
            }
            for jj in 0..NR {
                accrow[jj] += av * brow[jj];
            }
        }
    }
    for (ii, accrow) in acc.iter().take(mr).enumerate() {
        let crow = &mut c[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nr];
        for jj in 0..nr {
            crow[jj] += accrow[jj];
        }
    }
}

/// Simple ikj kernel for small problems.
fn gemm_small_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..p * n + n];
            let crow = &mut c[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `y = x @ W` for a single row vector `x[k]`, `W[k,n]` — the decode hot path.
pub fn gemv_row(x: &[f32], w: &[f32], y: &mut [f32], k: usize, n: usize) {
    y.fill(0.0);
    gemv_row_acc(x, w, y, k, n);
}

/// `y += x @ W` for a single row vector.
pub fn gemv_row_acc(x: &[f32], w: &[f32], y: &mut [f32], k: usize, n: usize) {
    assert!(x.len() >= k && w.len() >= k * n && y.len() >= n);
    for p in 0..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[p * n..p * n + n];
        for j in 0..n {
            y[j] += xv * wrow[j];
        }
    }
}

/// FLOPs of an `m×k×n` GEMM (2 per MAC).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_naive, max_abs_diff, Tensor};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(10);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 16, 16),
            (5, 17, 33),
            (64, 256, 64),
            (65, 257, 130),
            (128, 128, 128),
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let mut c = vec![0.0; m * n];
            gemm_f32(a.data(), b.data(), &mut c, m, k, n);
            let c = Tensor::from_vec(&[m, n], c);
            let want = matmul_naive(&a, &b);
            let diff = max_abs_diff(&c, &want);
            assert!(diff < 1e-2 * (k as f32).sqrt(), "({m},{k},{n}) diff={diff}");
        }
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let mut c = vec![1.0f32; 64];
        gemm_f32_acc(a.data(), b.data(), &mut c, 8, 8, 8);
        let want = matmul_naive(&a, &b);
        for i in 0..64 {
            assert!((c[i] - 1.0 - want.data()[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Rng::new(12);
        let x = Tensor::randn(&[1, 100], 1.0, &mut rng);
        let w = Tensor::randn(&[100, 37], 1.0, &mut rng);
        let mut y = vec![0.0; 37];
        gemv_row(x.data(), w.data(), &mut y, 100, 37);
        let want = matmul_naive(&x, &w);
        for j in 0..37 {
            assert!((y[j] - want.data()[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_gemm_matches_naive() {
        Prop::new(24).check(
            "gemm == naive",
            |rng| {
                let m = 1 + rng.below(40);
                let k = 1 + rng.below(70);
                let n = 1 + rng.below(40);
                let a = Tensor::randn(&[m, k], 1.0, rng);
                let b = Tensor::randn(&[k, n], 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let (m, k, n) = (a.rows(), a.cols(), b.cols());
                let mut c = vec![0.0; m * n];
                gemm_f32(a.data(), b.data(), &mut c, m, k, n);
                let c = Tensor::from_vec(&[m, n], c);
                let want = matmul_naive(a, b);
                let diff = max_abs_diff(&c, &want);
                if diff < 1e-2 {
                    Ok(())
                } else {
                    Err(format!("diff={diff}"))
                }
            },
        );
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c = vec![5.0f32; 0];
        gemm_f32(&[], &[], &mut c, 0, 0, 0);
        let mut c2 = vec![0.0f32; 4];
        gemm_f32(&[], &[], &mut c2, 2, 0, 2);
        assert_eq!(c2, vec![0.0; 4]);
    }

    #[test]
    fn pool_sizes_are_bitwise_identical() {
        // Band boundaries are fixed at BAND rows regardless of the pool
        // size, so the thread count must not change a bit of the output.
        let mut rng = Rng::new(13);
        for &(m, k, n) in &[(65usize, 257usize, 130usize), (256, 128, 96), (200, 520, 48)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let want = matmul_naive(&a, &b);
            let mut reference: Option<Vec<f32>> = None;
            for &t in &[1usize, 2, 3, 4] {
                let pool = WorkerPool::with_threads(t);
                let mut c = vec![0.0f32; m * n];
                gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
                let ct = Tensor::from_vec(&[m, n], c.clone());
                let diff = max_abs_diff(&ct, &want);
                assert!(diff < 1e-2 * (k as f32).sqrt(), "({m},{k},{n}) t={t} diff={diff}");
                match &reference {
                    None => reference = Some(c),
                    Some(r) => assert_eq!(&c, r, "({m},{k},{n}) t={t} changed bits"),
                }
            }
        }
    }

    #[test]
    fn acc_pool_accumulates_on_top() {
        let mut rng = Rng::new(14);
        let (m, k, n) = (70usize, 64usize, 40usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let pool = WorkerPool::with_threads(3);
        let mut c = vec![2.0f32; m * n];
        gemm_f32_acc_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
        let want = matmul_naive(&a, &b);
        for i in 0..m * n {
            assert!((c[i] - 2.0 - want.data()[i]).abs() < 1e-2);
        }
    }
}
