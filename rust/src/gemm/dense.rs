//! Blocked, register-tiled, multi-core dense f32 GEMM.
//!
//! Row-major `C[m,n] = A[m,k] @ B[k,n]`. The serial kernel tiles M×N into
//! 4×16 register blocks accumulated over a K panel, with an L2-friendly
//! outer blocking and **both operands packed**: B into NR-wide column
//! panels, A into MR-row panels, so the micro-kernel streams two
//! contiguous buffers. The micro-kernel itself is runtime-dispatched
//! ([`crate::gemm::kernel`]): AVX2 on capable x86_64, NEON on aarch64,
//! scalar otherwise — all bitwise interchangeable. Pack buffers come from
//! the per-worker scratch arena ([`crate::util::arena`]), so steady-state
//! calls allocate nothing.
//!
//! The parallel entry points partition M into fixed `BAND`-row bands
//! executed on the persistent worker pool: bands own disjoint C row
//! blocks, so there is no locking and — because band boundaries are
//! independent of the thread count — the output is **bitwise identical**
//! at every pool size. This is the compute stage of the two-stage sparse
//! pipeline and the dense baseline for every speedup table, so it needs
//! to be fast enough that the *pipeline*, not the MACs, is what the
//! benchmarks compare.

use crate::gemm::kernel::{Kernel, MR, NR};
use crate::model::{WeightStore, WeightView};
use crate::quant::SparseNf4Matrix;
use crate::sparse::BitmapMatrix;
use crate::util::arena::{scratch_raw, scratch_undef};
use crate::util::pool::{SendPtr, WorkerPool};
use crate::util::trace::{self, TraceKind};

/// Outer cache blocking: M rows per L2 block.
pub const MC: usize = 64;
/// Outer cache blocking: K depth per packed panel.
pub const KC: usize = 256;
/// Outer cache blocking: N columns per packed panel group.
pub const NC: usize = 512;

/// Rows per parallel band. A fixed multiple of `MR` (so tile boundaries
/// match the serial kernel's) and small enough that a 64-row GEMM still
/// spreads across 4 workers; the extra per-band B packing costs
/// `BAND⁻¹ ≈ 6%` of the MAC traffic.
const BAND: usize = 16;

/// `C = A @ B` (C overwritten), on the process-global pool.
pub fn gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c[..m * n].fill(0.0);
    gemm_f32_acc(a, b, c, m, k, n);
}

/// `C += A @ B`, on the process-global pool.
pub fn gemm_f32_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_f32_acc_pool(a, b, c, m, k, n, &WorkerPool::global());
}

/// `C = A @ B` on an explicit pool.
pub fn gemm_f32_pool(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
) {
    gemm_f32_pool_with_kernel(a, b, c, m, k, n, pool, Kernel::active());
}

/// `C += A @ B` on an explicit pool.
pub fn gemm_f32_acc_pool(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
) {
    gemm_f32_acc_pool_with_kernel(a, b, c, m, k, n, pool, Kernel::active());
}

/// [`gemm_f32_pool`] with an explicit micro-kernel — the benches and the
/// bitwise scalar-vs-SIMD parity tests pin the kernel this way; normal
/// callers use the runtime-dispatched entry points.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_pool_with_kernel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    c[..m * n].fill(0.0);
    gemm_f32_acc_pool_with_kernel(a, b, c, m, k, n, pool, kern);
}

/// [`gemm_f32_acc_pool`] with an explicit micro-kernel.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_acc_pool_with_kernel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    // One `gemm_call` span per entry call (never per band), attributed to
    // the caller's active trace id. Disabled cost: one relaxed load.
    if !trace::enabled() {
        return gemm_f32_acc_inner(a, b, c, m, k, n, pool, kern);
    }
    let t0 = trace::now_us();
    gemm_f32_acc_inner(a, b, c, m, k, n, pool, kern);
    trace::record_span(
        TraceKind::GemmCall,
        trace::current_trace(),
        t0,
        (m * n * k) as u64,
    );
}

#[allow(clippy::too_many_arguments)]
fn gemm_f32_acc_inner(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    assert!(a.len() >= m * k, "A too small");
    assert!(b.len() >= k * n, "B too small");
    assert!(c.len() >= m * n, "C too small");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Small problems: skip blocking and packing overhead. Kernel-agnostic
    // by construction (one shared code path), so forcing the scalar
    // kernel never changes small-GEMM bits either.
    if m * n * k <= 32 * 32 * 32 {
        return gemm_small_acc(a, b, c, m, k, n);
    }
    let src = DenseB { b, k, n };
    let bands = m.div_ceil(BAND);
    if bands == 1 || pool.threads() == 1 {
        return gemm_band_acc(a, &src, c, m, k, n, kern);
    }
    // Pool workers have their own (empty) trace context; carry the
    // caller's id across the fan-out so band-level `pack_b` spans still
    // attribute to the request that triggered them.
    let tid = trace::current_trace();
    let cptr = SendPtr(c.as_mut_ptr());
    pool.run(bands, &|bi| {
        let r0 = bi * BAND;
        let r1 = ((bi + 1) * BAND).min(m);
        let rows = r1 - r0;
        // SAFETY: band `bi` exclusively owns C rows [r0, r1) (and only
        // reads the matching A rows), so bands race on nothing.
        let band_c = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), rows * n) };
        trace::with_trace(tid, || gemm_band_acc(&a[r0 * k..], &src, band_c, rows, k, n, kern));
    });
}

/// A B-operand the blocked GEMM can pack panels from directly — dense f32
/// slices or *compressed* weight matrices (bitmap / bitmap+NF4), which
/// expand inside the pack step so no dense copy of the operand ever
/// exists. The packed panel layout is identical for every source
/// (`packed[panel][p][lane]`, zero-padded to NR lanes), and the expanded
/// values are bit-for-bit the ones a full decode would produce, so the
/// fused path's output is bitwise identical to decode-then-GEMM.
pub trait PackB: Sync {
    /// Rows of the B operand (the GEMM's `k`).
    fn k_rows(&self) -> usize;
    /// Columns of the B operand (the GEMM's `n`).
    fn n_cols(&self) -> usize;
    /// Pack `B[pc..pc+kb, jc..jc+nb]` into NR-wide column panels
    /// (`packed[pj*kb*NR + p*NR + lane]`, zero-padded), decoding from the
    /// native representation. `jc` is always a multiple of [`NC`].
    fn pack_b_panels(&self, packed: &mut Vec<f32>, pc: usize, jc: usize, kb: usize, nb: usize);
    /// Decode rows `[r0, r1)` to dense row-major f32 — the small-problem
    /// fallback (and the pipeline's decode stage) share this.
    fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]);
}

/// A dense row-major `k × n` f32 slice as a [`PackB`] source.
pub struct DenseB<'a> {
    /// Row-major `k × n` data.
    pub b: &'a [f32],
    /// Rows.
    pub k: usize,
    /// Columns.
    pub n: usize,
}

impl PackB for DenseB<'_> {
    fn k_rows(&self) -> usize {
        self.k
    }

    fn n_cols(&self) -> usize {
        self.n
    }

    fn pack_b_panels(&self, packed: &mut Vec<f32>, pc: usize, jc: usize, kb: usize, nb: usize) {
        let n = self.n;
        let b = self.b;
        let npanels = nb.div_ceil(NR);
        let len = npanels * kb * NR;
        // Zero only when the geometry changes. Stale values in a reused
        // buffer's padding lanes are harmless: the micro-kernels accumulate
        // all NR lanes but write back only the `nr` real ones.
        if packed.len() != len {
            packed.clear();
            packed.resize(len, 0.0);
        }
        for pj in 0..npanels {
            let j0 = jc + pj * NR;
            let lanes = NR.min(jc + nb - j0);
            let dst_base = pj * kb * NR;
            for p in 0..kb {
                let src = (pc + p) * n + j0;
                let dst = dst_base + p * NR;
                packed[dst..dst + lanes].copy_from_slice(&b[src..src + lanes]);
            }
        }
    }

    fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        out[..(r1 - r0) * self.n].copy_from_slice(&self.b[r0 * self.n..r1 * self.n]);
    }
}

/// Shared compressed-pack walk: expand the bitmap tile
/// `[pc..pc+kb) × [jc..jc+nb)` straight into zeroed NR-lane panels,
/// word-at-a-time (one u64 mask load per 64 columns, popcount-driven
/// scatter touching only set bits). `value(voff)` supplies the `voff`-th
/// nonzero of the row-major stream — stored f32s for the bitmap format,
/// LUT-dequantized NF4 for the quantized one. Bits are consumed in
/// ascending column order, so values land exactly where a full
/// decode-then-pack would put them.
#[allow(clippy::too_many_arguments)]
fn pack_sparse_panels(
    masks: &[u8],
    row_offsets: &[u32],
    bpr: usize,
    value: impl Fn(usize) -> f32,
    packed: &mut Vec<f32>,
    pc: usize,
    jc: usize,
    kb: usize,
    nb: usize,
) {
    let npanels = nb.div_ceil(NR);
    let len = npanels * kb * NR;
    // Scatter writes only the nonzeros, so (unlike the dense pack) the
    // whole tile re-zeroes on every call.
    packed.clear();
    packed.resize(len, 0.0);
    // `jc` is a multiple of NC (a multiple of 8), so the tile starts on a
    // mask-byte boundary; `jc+nb` is either the next NC boundary or the
    // final column, so every set bit in bytes [b0, bend) belongs to the
    // tile (encode zero-pads mask bits past the last column).
    let b0 = jc / 8;
    let bend = bpr.min((jc + nb).div_ceil(8));
    for p in 0..kb {
        let gp = pc + p;
        let row_masks = &masks[gp * bpr..(gp + 1) * bpr];
        // Value offset at column jc: row offset + popcount of the mask
        // prefix, folded 64 bits at a time.
        let mut voff = row_offsets[gp] as usize;
        let prefix = &row_masks[..b0];
        let mut iw = 0;
        while iw + 8 <= prefix.len() {
            let w: [u8; 8] = prefix[iw..iw + 8].try_into().unwrap();
            voff += u64::from_le_bytes(w).count_ones() as usize;
            iw += 8;
        }
        for &byte in &prefix[iw..] {
            voff += byte.count_ones() as usize;
        }
        // Scatter the tile's set bits into the panel layout.
        let bytes = &row_masks[b0..bend];
        let mut bi = 0;
        while bi + 8 <= bytes.len() {
            let w: [u8; 8] = bytes[bi..bi + 8].try_into().unwrap();
            let mut mword = u64::from_le_bytes(w);
            let base = (b0 + bi) * 8;
            while mword != 0 {
                let t = mword.trailing_zeros() as usize;
                let j = base + t - jc;
                packed[(j / NR) * kb * NR + p * NR + (j % NR)] = value(voff);
                voff += 1;
                mword &= mword - 1;
            }
            bi += 8;
        }
        for (off, &byte) in bytes[bi..].iter().enumerate() {
            let mut mb = byte;
            let base = (b0 + bi + off) * 8;
            while mb != 0 {
                let t = mb.trailing_zeros() as usize;
                let j = base + t - jc;
                packed[(j / NR) * kb * NR + p * NR + (j % NR)] = value(voff);
                voff += 1;
                mb &= mb - 1;
            }
        }
    }
}

impl PackB for BitmapMatrix {
    fn k_rows(&self) -> usize {
        self.rows()
    }

    fn n_cols(&self) -> usize {
        self.cols()
    }

    fn pack_b_panels(&self, packed: &mut Vec<f32>, pc: usize, jc: usize, kb: usize, nb: usize) {
        let values = self.values();
        pack_sparse_panels(
            self.masks(),
            self.row_offsets(),
            self.bytes_per_row(),
            |voff| values[voff],
            packed,
            pc,
            jc,
            kb,
            nb,
        );
    }

    fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        BitmapMatrix::decode_rows_into(self, r0, r1, out);
    }
}

impl PackB for SparseNf4Matrix {
    fn k_rows(&self) -> usize {
        self.rows()
    }

    fn n_cols(&self) -> usize {
        self.cols()
    }

    fn pack_b_panels(&self, packed: &mut Vec<f32>, pc: usize, jc: usize, kb: usize, nb: usize) {
        pack_sparse_panels(
            self.masks(),
            self.row_offsets(),
            self.bytes_per_row(),
            |voff| self.value(voff),
            packed,
            pc,
            jc,
            kb,
            nb,
        );
    }

    fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        SparseNf4Matrix::decode_rows_into(self, r0, r1, out);
    }
}

impl PackB for WeightStore {
    fn k_rows(&self) -> usize {
        self.rows()
    }

    fn n_cols(&self) -> usize {
        self.cols()
    }

    fn pack_b_panels(&self, packed: &mut Vec<f32>, pc: usize, jc: usize, kb: usize, nb: usize) {
        match self.view() {
            WeightView::Dense(t) => DenseB {
                b: t.data(),
                k: t.rows(),
                n: t.cols(),
            }
            .pack_b_panels(packed, pc, jc, kb, nb),
            WeightView::Bitmap(bm) => PackB::pack_b_panels(bm, packed, pc, jc, kb, nb),
            WeightView::BitmapNf4(snf) => PackB::pack_b_panels(snf, packed, pc, jc, kb, nb),
        }
    }

    fn decode_rows_into(&self, r0: usize, r1: usize, out: &mut [f32]) {
        WeightStore::decode_rows_into(self, r0, r1, out);
    }
}

/// `C = X @ W` where W is any [`PackB`] source (overwrite), dispatched
/// kernel, explicit pool — the engine's fused compressed-weight GEMM.
pub fn gemm_src_pool<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
) {
    gemm_src_pool_with_kernel(a, src, c, m, pool, Kernel::active());
}

/// `C += X @ W` for any [`PackB`] source on an explicit pool.
pub fn gemm_src_acc_pool<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
) {
    gemm_src_acc_pool_with_kernel(a, src, c, m, pool, Kernel::active());
}

/// [`gemm_src_pool`] with an explicit micro-kernel (parity tests).
pub fn gemm_src_pool_with_kernel<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    c[..m * src.n_cols()].fill(0.0);
    gemm_src_acc_pool_with_kernel(a, src, c, m, pool, kern);
}

/// `C += X @ W` from any packable B source, mirroring
/// [`gemm_f32_acc_pool_with_kernel`]'s dispatch structure *exactly* —
/// same small-problem cutoff (decode to arena scratch, same ikj kernel),
/// same BAND partitioning, same packed-panel blocking — which is what
/// makes the fused output bitwise identical to decode-then-GEMM at every
/// shape, pool width and kernel.
pub fn gemm_src_acc_pool_with_kernel<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    // Same one-span-per-entry discipline as the dense path.
    if !trace::enabled() {
        return gemm_src_acc_inner(a, src, c, m, pool, kern);
    }
    let t0 = trace::now_us();
    let macs = (m * src.k_rows() * src.n_cols()) as u64;
    gemm_src_acc_inner(a, src, c, m, pool, kern);
    trace::record_span(TraceKind::GemmCall, trace::current_trace(), t0, macs);
}

fn gemm_src_acc_inner<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
    kern: Kernel,
) {
    let k = src.k_rows();
    let n = src.n_cols();
    assert!(a.len() >= m * k, "A too small");
    assert!(c.len() >= m * n, "C too small");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m * n * k <= 32 * 32 * 32 {
        // The dense path skips packing here, so there is no pack step to
        // fuse into: decode the (tiny) operand into arena scratch and run
        // the identical ikj kernel.
        let mut dense = scratch_undef(k * n);
        src.decode_rows_into(0, k, &mut dense);
        return gemm_small_acc(a, &dense, c, m, k, n);
    }
    let bands = m.div_ceil(BAND);
    if bands == 1 || pool.threads() == 1 {
        return gemm_band_acc(a, src, c, m, k, n, kern);
    }
    // Carry the caller's trace id across the pool fan-out (see the dense
    // path).
    let tid = trace::current_trace();
    let cptr = SendPtr(c.as_mut_ptr());
    pool.run(bands, &|bi| {
        let r0 = bi * BAND;
        let r1 = ((bi + 1) * BAND).min(m);
        let rows = r1 - r0;
        // SAFETY: band `bi` exclusively owns C rows [r0, r1) (and only
        // reads the matching A rows), so bands race on nothing.
        let band_c = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), rows * n) };
        trace::with_trace(tid, || gemm_band_acc(&a[r0 * k..], src, band_c, rows, k, n, kern));
    });
}

/// Serial blocked GEMM over one row band (`C[m,n] += A[m,k] @ B[k,n]`),
/// packing each B panel once per (jc, pc) block — decoding it from the
/// source's native (possibly compressed) representation — and each A
/// block once per (pc, ic). Pack buffers are borrowed from the executing
/// thread's scratch arena — pool workers are persistent, so after warmup
/// this function performs zero heap allocations.
fn gemm_band_acc<S: PackB + ?Sized>(
    a: &[f32],
    src: &S,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    kern: Kernel,
) {
    // Hints sized to the first (jc, pc, ic) block — the largest the packs
    // will need for this problem, so best-fit pairs slabs stably.
    let mut packed_b = scratch_raw(NC.min(n).div_ceil(NR) * NR * KC.min(k));
    let mut packed_a = scratch_raw(MC.min(m).div_ceil(MR) * MR * KC.min(k));
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            let t0 = if trace::enabled() { trace::now_us() } else { 0 };
            src.pack_b_panels(&mut packed_b, pc, jc, kb, nb);
            if trace::enabled() {
                trace::record_span(
                    TraceKind::PackB,
                    trace::current_trace(),
                    t0,
                    (kb * nb) as u64,
                );
            }
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                pack_a_panels(a, &mut packed_a, k, ic, pc, mb, kb);
                block_kernel(&packed_a, &packed_b, c, n, ic, jc, mb, kb, nb, kern);
            }
        }
    }
}

/// Pack `A[ic..ic+mb, pc..pc+kb]` into MR-row panels, panel-major
/// (`packed[tile][p][row]`), so the micro-kernel reads MR contiguous A
/// values per k step instead of striding by `k`. Ragged row tiles are
/// explicitly zero-padded (the padded rows' accumulators are computed and
/// discarded — cheaper than a dedicated edge kernel, and it keeps one
/// SIMD path for every tile).
fn pack_a_panels(
    a: &[f32],
    packed: &mut Vec<f32>,
    k: usize,
    ic: usize,
    pc: usize,
    mb: usize,
    kb: usize,
) {
    let ntiles = mb.div_ceil(MR);
    let len = ntiles * kb * MR;
    if packed.len() != len {
        packed.clear();
        packed.resize(len, 0.0);
    }
    for ti in 0..ntiles {
        let i0 = ic + ti * MR;
        let rows = MR.min(ic + mb - i0);
        let dst_base = ti * kb * MR;
        for p in 0..kb {
            let dst = dst_base + p * MR;
            for ii in 0..rows {
                packed[dst + ii] = a[(i0 + ii) * k + pc + p];
            }
            // Re-zero the padding every call: the buffer is reused with
            // arbitrary prior contents and these lanes feed the kernel.
            packed[dst + rows..dst + MR].fill(0.0);
        }
    }
}

/// One (mb × nb) block over a kb panel, micro-tiled MR×NR against the
/// packed operands. Every tile — interior or ragged — runs the same
/// dispatched micro-kernel on a full (zero-padded) MR×NR accumulator;
/// the write-back masks to the `mr × nr` real elements.
#[allow(clippy::too_many_arguments)]
fn block_kernel(
    packed_a: &[f32],
    packed_b: &[f32],
    c: &mut [f32],
    n: usize,
    ic: usize,
    jc: usize,
    mb: usize,
    kb: usize,
    nb: usize,
    kern: Kernel,
) {
    let ntiles = mb.div_ceil(MR);
    let npanels = nb.div_ceil(NR);
    for ti in 0..ntiles {
        let i0 = ic + ti * MR;
        let mr = MR.min(ic + mb - i0);
        let pa = &packed_a[ti * kb * MR..(ti + 1) * kb * MR];
        for pj in 0..npanels {
            let j0 = jc + pj * NR;
            let nr = NR.min(jc + nb - j0);
            let pb = &packed_b[pj * kb * NR..(pj + 1) * kb * NR];
            let mut acc = [[0.0f32; NR]; MR];
            kern.run(pa, pb, &mut acc, kb);
            for (ii, accrow) in acc.iter().take(mr).enumerate() {
                let crow = &mut c[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nr];
                for jj in 0..nr {
                    crow[jj] += accrow[jj];
                }
            }
        }
    }
}

/// Simple ikj kernel for small problems.
fn gemm_small_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..p * n + n];
            let crow = &mut c[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `y = x @ W` for a single row vector `x[k]`, `W[k,n]` — the decode hot path.
pub fn gemv_row(x: &[f32], w: &[f32], y: &mut [f32], k: usize, n: usize) {
    y.fill(0.0);
    gemv_row_acc(x, w, y, k, n);
}

/// `y += x @ W` for a single row vector.
pub fn gemv_row_acc(x: &[f32], w: &[f32], y: &mut [f32], k: usize, n: usize) {
    assert!(x.len() >= k && w.len() >= k * n && y.len() >= n);
    for p in 0..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[p * n..p * n + n];
        for j in 0..n {
            y[j] += xv * wrow[j];
        }
    }
}

/// FLOPs of an `m×k×n` GEMM (2 per MAC).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_naive, max_abs_diff, Tensor};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(10);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 16, 16),
            (5, 17, 33),
            (64, 256, 64),
            (65, 257, 130),
            (128, 128, 128),
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let mut c = vec![0.0; m * n];
            gemm_f32(a.data(), b.data(), &mut c, m, k, n);
            let c = Tensor::from_vec(&[m, n], c);
            let want = matmul_naive(&a, &b);
            let diff = max_abs_diff(&c, &want);
            assert!(diff < 1e-2 * (k as f32).sqrt(), "({m},{k},{n}) diff={diff}");
        }
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let mut c = vec![1.0f32; 64];
        gemm_f32_acc(a.data(), b.data(), &mut c, 8, 8, 8);
        let want = matmul_naive(&a, &b);
        for i in 0..64 {
            assert!((c[i] - 1.0 - want.data()[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Rng::new(12);
        let x = Tensor::randn(&[1, 100], 1.0, &mut rng);
        let w = Tensor::randn(&[100, 37], 1.0, &mut rng);
        let mut y = vec![0.0; 37];
        gemv_row(x.data(), w.data(), &mut y, 100, 37);
        let want = matmul_naive(&x, &w);
        for j in 0..37 {
            assert!((y[j] - want.data()[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_gemm_matches_naive() {
        Prop::new(24).check(
            "gemm == naive",
            |rng| {
                let m = 1 + rng.below(40);
                let k = 1 + rng.below(70);
                let n = 1 + rng.below(40);
                let a = Tensor::randn(&[m, k], 1.0, rng);
                let b = Tensor::randn(&[k, n], 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let (m, k, n) = (a.rows(), a.cols(), b.cols());
                let mut c = vec![0.0; m * n];
                gemm_f32(a.data(), b.data(), &mut c, m, k, n);
                let c = Tensor::from_vec(&[m, n], c);
                let want = matmul_naive(a, b);
                let diff = max_abs_diff(&c, &want);
                if diff < 1e-2 {
                    Ok(())
                } else {
                    Err(format!("diff={diff}"))
                }
            },
        );
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c = vec![5.0f32; 0];
        gemm_f32(&[], &[], &mut c, 0, 0, 0);
        let mut c2 = vec![0.0f32; 4];
        gemm_f32(&[], &[], &mut c2, 2, 0, 2);
        assert_eq!(c2, vec![0.0; 4]);
    }

    #[test]
    fn pool_sizes_are_bitwise_identical() {
        // Band boundaries are fixed at BAND rows regardless of the pool
        // size, so the thread count must not change a bit of the output.
        let mut rng = Rng::new(13);
        for &(m, k, n) in &[(65usize, 257usize, 130usize), (256, 128, 96), (200, 520, 48)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let want = matmul_naive(&a, &b);
            let mut reference: Option<Vec<f32>> = None;
            for &t in &[1usize, 2, 3, 4] {
                let pool = WorkerPool::with_threads(t);
                let mut c = vec![0.0f32; m * n];
                gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
                let ct = Tensor::from_vec(&[m, n], c.clone());
                let diff = max_abs_diff(&ct, &want);
                assert!(diff < 1e-2 * (k as f32).sqrt(), "({m},{k},{n}) t={t} diff={diff}");
                match &reference {
                    None => reference = Some(c),
                    Some(r) => assert_eq!(&c, r, "({m},{k},{n}) t={t} changed bits"),
                }
            }
        }
    }

    #[test]
    fn scalar_and_dispatched_kernels_bitwise_identical() {
        // The tentpole guarantee: whatever SIMD kernel dispatch selects,
        // its output matches the scalar kernel bit-for-bit — over ragged
        // tiles (m % 4 ≠ 0, n % 16 ≠ 0), k = 1, multi-KC depths, and at
        // every pool width. (On hosts without SIMD, or under
        // SALR_FORCE_SCALAR=1, both sides are the scalar kernel and the
        // test degenerates to a determinism check.)
        let mut rng = Rng::new(15);
        for &(m, k, n) in &[
            (5usize, 257usize, 33usize), // ragged m and n, k > KC boundary off by one
            (7, 300, 47),                // ragged everything
            (13, 128, 31),               // n % 16 = 15
            (200, 1, 200),               // k = 1
            (64, 256, 64),               // fully aligned
            (8, 600, 32),                // k spans multiple KC panels
            (70, 64, 130),               // m spans bands, ragged n
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let mut want_scalar = vec![0.0f32; m * n];
            let serial = WorkerPool::with_threads(1);
            gemm_f32_pool_with_kernel(
                a.data(),
                b.data(),
                &mut want_scalar,
                m,
                k,
                n,
                &serial,
                Kernel::scalar(),
            );
            // Approximate correctness of the scalar reference itself.
            let naive = matmul_naive(&a, &b);
            let ws = Tensor::from_vec(&[m, n], want_scalar.clone());
            assert!(max_abs_diff(&ws, &naive) < 1e-2 * (k as f32).sqrt().max(1.0));
            for &t in &[1usize, 2, 4] {
                let pool = WorkerPool::with_threads(t);
                for kern in [Kernel::scalar(), Kernel::active()] {
                    let mut c = vec![0.0f32; m * n];
                    gemm_f32_pool_with_kernel(a.data(), b.data(), &mut c, m, k, n, &pool, kern);
                    assert_eq!(
                        c,
                        want_scalar,
                        "({m},{k},{n}) t={t} kern={} diverged from scalar",
                        kern.name()
                    );
                }
            }
        }
    }

    #[test]
    fn repeated_calls_do_not_grow_the_arena() {
        // Steady-state GEMM must not allocate: one warmup call sizes the
        // thread-local pack slabs, then the counter stays put. Run on a
        // 1-thread pool so every checkout happens on this test's thread.
        let mut rng = Rng::new(16);
        let (m, k, n) = (48usize, 300usize, 64usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let pool = WorkerPool::with_threads(1);
        let mut c = vec![0.0f32; m * n];
        gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            gemm_f32_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "steady-state GEMM allocated"
        );
    }

    fn sparse_tensor(rng: &mut Rng, r: usize, c: usize, p: f64) -> Tensor {
        let mut t = Tensor::randn(&[r, c], 1.0, rng);
        crate::prune::prune_global(&mut [&mut t], p);
        t
    }

    #[test]
    fn fused_pack_decode_bitwise_matches_decode_then_gemm() {
        // The tentpole oracle matrix: {bitmap, bitmap+NF4} sources ×
        // ragged shapes (m % 4 ≠ 0, n % 16 ≠ 0, k = 1, k > KC, and a
        // small-path shape under the 32³ cutoff) × pool widths {1,2,4} ×
        // {scalar, dispatched} kernels. The fused pack expands compressed
        // bytes directly into the B panels; its output must be bitwise
        // identical to decoding the operand to dense f32 first and
        // running the ordinary blocked GEMM with the same pool + kernel.
        use crate::model::{WeightFormat, WeightStore};
        let mut rng = Rng::new(17);
        for &(m, k, n) in &[
            (5usize, 257usize, 33usize), // ragged m and n, k crosses KC
            (7, 300, 47),                // ragged everything
            (13, 128, 31),               // n % 16 = 15
            (200, 1, 200),               // k = 1
            (8, 600, 32),                // k spans multiple KC panels
            (70, 64, 130),               // m spans bands, ragged n
            (6, 20, 9),                  // under the small-problem cutoff
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let w = sparse_tensor(&mut rng, k, n, 0.5);
            for fmt in [WeightFormat::Bitmap, WeightFormat::Nf4] {
                let store = WeightStore::encode(&w, fmt);
                // Oracle operand: the *store's* decode (for NF4 the
                // dequantized values), densely multiplied.
                let dense_w = store.decode();
                for &t in &[1usize, 2, 4] {
                    let pool = WorkerPool::with_threads(t);
                    for kern in [Kernel::scalar(), Kernel::active()] {
                        let mut want = vec![0.0f32; m * n];
                        gemm_f32_pool_with_kernel(
                            x.data(),
                            dense_w.data(),
                            &mut want,
                            m,
                            k,
                            n,
                            &pool,
                            kern,
                        );
                        let mut got = vec![0.0f32; m * n];
                        gemm_src_pool_with_kernel(x.data(), &store, &mut got, m, &pool, kern);
                        assert!(
                            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "({m},{k},{n}) fmt={:?} t={t} kern={} fused diverged",
                            fmt,
                            kern.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_acc_accumulates_on_top() {
        use crate::model::{WeightFormat, WeightStore};
        let mut rng = Rng::new(18);
        let (m, k, n) = (37usize, 96usize, 50usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = sparse_tensor(&mut rng, k, n, 0.5);
        let store = WeightStore::encode(&w, WeightFormat::Bitmap);
        let pool = WorkerPool::with_threads(2);
        let mut want = vec![3.0f32; m * n];
        gemm_f32_acc_pool(x.data(), w.data(), &mut want, m, k, n, &pool);
        let mut got = vec![3.0f32; m * n];
        gemm_src_acc_pool(x.data(), &store, &mut got, m, &pool);
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn fused_pack_steady_state_does_not_grow_the_arena() {
        use crate::model::{WeightFormat, WeightStore};
        let mut rng = Rng::new(19);
        let (m, k, n) = (48usize, 300usize, 64usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = sparse_tensor(&mut rng, k, n, 0.5);
        let pool = WorkerPool::with_threads(1);
        for fmt in [WeightFormat::Bitmap, WeightFormat::Nf4] {
            let store = WeightStore::encode(&w, fmt);
            let mut c = vec![0.0f32; m * n];
            gemm_src_pool(x.data(), &store, &mut c, m, &pool);
            let before = crate::util::arena::thread_allocated_bytes();
            for _ in 0..10 {
                gemm_src_pool(x.data(), &store, &mut c, m, &pool);
            }
            assert_eq!(
                crate::util::arena::thread_allocated_bytes(),
                before,
                "steady-state fused GEMM allocated ({:?})",
                fmt
            );
        }
    }

    #[test]
    fn acc_pool_accumulates_on_top() {
        let mut rng = Rng::new(14);
        let (m, k, n) = (70usize, 64usize, 40usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let pool = WorkerPool::with_threads(3);
        let mut c = vec![2.0f32; m * n];
        gemm_f32_acc_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
        let want = matmul_naive(&a, &b);
        for i in 0..m * n {
            assert!((c[i] - 2.0 - want.data()[i]).abs() < 1e-2);
        }
    }
}
