//! Concatenated multi-adapter GEMM (paper, "Concatenating Multi-LoRA
//! adapters"): n adapters `(A_i ∈ R^{k×r}, B_i ∈ R^{r×n})` sharing an input
//! are fused into `A_cat ∈ R^{k×nr}`, `B_cat ∈ R^{nr×n}` so the cumulative
//! update `Δy = Σ (x A_i) B_i` costs two GEMMs instead of 2n.

use crate::gemm::dense;
use crate::tensor::Tensor;

/// A set of same-shape low-rank adapters over a shared input.
#[derive(Clone, Debug)]
pub struct AdapterStack {
    /// `A_cat[k, total_rank]` — columns of all A_i side by side.
    pub a_cat: Tensor,
    /// `B_cat[total_rank, n]` — rows of all B_i stacked.
    pub b_cat: Tensor,
    /// Rank of each constituent adapter, in order.
    pub ranks: Vec<usize>,
}

impl AdapterStack {
    /// Build from individual adapter pairs (all must share k and n).
    pub fn concat(adapters: &[(&Tensor, &Tensor)]) -> AdapterStack {
        assert!(!adapters.is_empty());
        let k = adapters[0].0.rows();
        let n = adapters[0].1.cols();
        let mut ranks = Vec::with_capacity(adapters.len());
        let total_rank: usize = adapters
            .iter()
            .map(|(a, b)| {
                assert_eq!(a.rows(), k, "adapter k mismatch");
                assert_eq!(b.cols(), n, "adapter n mismatch");
                assert_eq!(a.cols(), b.rows(), "adapter rank mismatch");
                a.cols()
            })
            .collect::<Vec<_>>()
            .iter()
            .inspect(|&&r| ranks.push(r))
            .sum();
        let mut a_cat = Tensor::zeros(&[k, total_rank]);
        let mut b_cat = Tensor::zeros(&[total_rank, n]);
        let mut off = 0usize;
        for (a, b) in adapters {
            let r = a.cols();
            for i in 0..k {
                for j in 0..r {
                    a_cat.set(i, off + j, a.at(i, j));
                }
            }
            for i in 0..r {
                b_cat.row_mut(off + i).copy_from_slice(b.row(i));
            }
            off += r;
        }
        AdapterStack {
            a_cat,
            b_cat,
            ranks,
        }
    }

    /// Sum of the constituent adapter ranks (columns of `A_cat`).
    pub fn total_rank(&self) -> usize {
        self.ranks.iter().sum()
    }

    /// Shared input width of every adapter.
    pub fn k(&self) -> usize {
        self.a_cat.rows()
    }

    /// Shared output width of every adapter.
    pub fn n(&self) -> usize {
        self.b_cat.cols()
    }

    /// Fused update: `Δy[m,n] = (X A_cat) B_cat` — two GEMMs total.
    pub fn apply_fused(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let (k, n, tr) = (self.k(), self.n(), self.total_rank());
        let mut u = crate::util::arena::scratch_undef(m * tr);
        dense::gemm_f32(x, self.a_cat.data(), &mut u, m, k, tr);
        dense::gemm_f32(&u, self.b_cat.data(), out, m, tr, n);
    }

    /// Fused accumulate variant (`out += Δy`), on the process-global pool.
    pub fn apply_fused_acc(&self, x: &[f32], m: usize, out: &mut [f32]) {
        self.apply_fused_acc_pool(x, m, out, &crate::util::pool::WorkerPool::global());
    }

    /// Fused accumulate on an explicit pool (the engine's thread knob).
    pub fn apply_fused_acc_pool(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        pool: &crate::util::pool::WorkerPool,
    ) {
        let (k, n, tr) = (self.k(), self.n(), self.total_rank());
        if tr == 0 {
            return;
        }
        // `u` is GEMM output (zero-filled internally) — arena scratch, so
        // every decode step's adapter update allocates nothing.
        let mut u = crate::util::arena::scratch_undef(m * tr);
        dense::gemm_f32_pool(x, self.a_cat.data(), &mut u, m, k, tr, pool);
        dense::gemm_f32_acc_pool(&u, self.b_cat.data(), out, m, tr, n, pool);
    }

    /// Full SALR forward on the compressed-weight pack path:
    /// `out = X @ W + (X A_cat) B_cat`, where `W` is any [`dense::PackB`]
    /// source (a [`crate::model::WeightStore`], a bitmap, an NF4 store, or
    /// a dense operand) decoded per tile inside the packed GEMM — no dense
    /// copy of W is ever materialized. The base product lands first, then
    /// the adapter update accumulates on top, matching the non-pipelined
    /// engine path's accumulation order.
    pub fn apply_with_base_pool<S: dense::PackB + ?Sized>(
        &self,
        x: &[f32],
        base: &S,
        m: usize,
        out: &mut [f32],
        pool: &crate::util::pool::WorkerPool,
    ) {
        dense::gemm_src_pool(x, base, out, m, pool);
        self.apply_fused_acc_pool(x, m, out, pool);
    }

    /// Sequential baseline: apply each adapter as two small GEMMs,
    /// accumulating — 2n kernel invocations (paper's inefficient case).
    pub fn apply_sequential(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let (k, n) = (self.k(), self.n());
        out[..m * n].fill(0.0);
        let mut off = 0usize;
        for &r in &self.ranks {
            // Slice A_i out of a_cat (strided copy), B_i out of b_cat.
            let mut a_i = vec![0.0f32; k * r];
            for i in 0..k {
                for j in 0..r {
                    a_i[i * r + j] = self.a_cat.at(i, off + j);
                }
            }
            let b_i = &self.b_cat.data()[off * n..(off + r) * n];
            let mut u = vec![0.0f32; m * r];
            dense::gemm_f32(x, &a_i, &mut u, m, k, r);
            dense::gemm_f32_acc(&u, b_i, out, m, r, n);
            off += r;
        }
    }

    /// FLOPs of the fused update for batch m.
    pub fn flops(&self, m: usize) -> f64 {
        dense::gemm_flops(m, self.k(), self.total_rank())
            + dense::gemm_flops(m, self.total_rank(), self.n())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, max_abs_diff};
    use crate::util::rng::Rng;

    fn random_adapters(
        rng: &mut Rng,
        k: usize,
        n: usize,
        ranks: &[usize],
    ) -> Vec<(Tensor, Tensor)> {
        ranks
            .iter()
            .map(|&r| {
                (
                    Tensor::randn(&[k, r], 0.5, rng),
                    Tensor::randn(&[r, n], 0.5, rng),
                )
            })
            .collect()
    }

    #[test]
    fn fused_equals_sum_of_adapters() {
        let mut rng = Rng::new(130);
        let (k, n, m) = (48usize, 36usize, 5usize);
        let adapters = random_adapters(&mut rng, k, n, &[4, 8, 2]);
        let refs: Vec<(&Tensor, &Tensor)> = adapters.iter().map(|(a, b)| (a, b)).collect();
        let stack = AdapterStack::concat(&refs);
        assert_eq!(stack.total_rank(), 14);

        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        // Reference: sum of individual updates.
        let mut want = Tensor::zeros(&[m, n]);
        for (a, b) in &adapters {
            let u = matmul(&x, a);
            let d = matmul(&u, b);
            want = crate::tensor::add(&want, &d);
        }
        let mut fused = vec![0.0f32; m * n];
        stack.apply_fused(x.data(), m, &mut fused);
        let fused = Tensor::from_vec(&[m, n], fused);
        assert!(max_abs_diff(&fused, &want) < 1e-3);

        let mut seq = vec![0.0f32; m * n];
        stack.apply_sequential(x.data(), m, &mut seq);
        let seq = Tensor::from_vec(&[m, n], seq);
        assert!(max_abs_diff(&seq, &want) < 1e-3);
    }

    #[test]
    fn single_adapter_degenerates_to_lora() {
        let mut rng = Rng::new(131);
        let (k, n, m, r) = (32usize, 24usize, 3usize, 8usize);
        let a = Tensor::randn(&[k, r], 1.0, &mut rng);
        let b = Tensor::randn(&[r, n], 1.0, &mut rng);
        let stack = AdapterStack::concat(&[(&a, &b)]);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let want = matmul(&matmul(&x, &a), &b);
        let mut got = vec![0.0f32; m * n];
        stack.apply_fused(x.data(), m, &mut got);
        assert!(max_abs_diff(&Tensor::from_vec(&[m, n], got), &want) < 1e-3);
    }

    #[test]
    fn acc_adds_on_top() {
        let mut rng = Rng::new(132);
        let adapters = random_adapters(&mut rng, 16, 12, &[4]);
        let stack = AdapterStack::concat(&[(&adapters[0].0, &adapters[0].1)]);
        let x = Tensor::randn(&[2, 16], 1.0, &mut rng);
        let mut base = vec![1.0f32; 2 * 12];
        stack.apply_fused_acc(x.data(), 2, &mut base);
        let mut delta = vec![0.0f32; 2 * 12];
        stack.apply_fused(x.data(), 2, &mut delta);
        for i in 0..24 {
            assert!((base[i] - 1.0 - delta[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn base_plus_adapters_matches_decode_then_gemm_bitwise() {
        // apply_with_base_pool decodes the compressed base inside the pack
        // step; the oracle decodes it up front and runs the same dense
        // GEMM + the same adapter accumulate — identical kernels in
        // identical order, so the bits must match.
        let mut rng = Rng::new(133);
        let (m, k, n) = (6usize, 96usize, 40usize);
        let adapters = random_adapters(&mut rng, k, n, &[4, 4]);
        let refs: Vec<(&Tensor, &Tensor)> = adapters.iter().map(|(a, b)| (a, b)).collect();
        let stack = AdapterStack::concat(&refs);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        crate::prune::prune_global(&mut [&mut w], 0.5);
        let pool = crate::util::pool::WorkerPool::new(2);
        for fmt in [
            crate::model::WeightFormat::Bitmap,
            crate::model::WeightFormat::Nf4,
        ] {
            let store = crate::model::WeightStore::encode(&w, fmt);
            let dense_w = store.decode();
            let mut want = vec![0.0f32; m * n];
            dense::gemm_f32_pool(x.data(), dense_w.data(), &mut want, m, k, n, &pool);
            stack.apply_fused_acc_pool(x.data(), m, &mut want, &pool);
            let mut got = vec![0.0f32; m * n];
            stack.apply_with_base_pool(x.data(), &store, m, &mut got, &pool);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{fmt:?} diverged from decode-then-GEMM"
            );
        }
    }

    #[test]
    #[should_panic(expected = "adapter k mismatch")]
    fn mismatched_shapes_panic() {
        let a1 = Tensor::zeros(&[8, 2]);
        let b1 = Tensor::zeros(&[2, 4]);
        let a2 = Tensor::zeros(&[9, 2]);
        let b2 = Tensor::zeros(&[2, 4]);
        AdapterStack::concat(&[(&a1, &b1), (&a2, &b2)]);
    }
}
