//! The paper's **two-stage pipelined decode+GEMM**.
//!
//! Stage 1 (decode): worker thread(s) reconstruct dense K-panels of the
//! bitmap-encoded weight matrix using the byte-mask/LUT rule.
//! Stage 2 (GEMM): the compute thread multiplies each reconstructed panel
//! into the accumulator.
//!
//! The two stages communicate through a fixed-depth **ring buffer** of
//! pre-allocated panel slots: while the GEMM stage multiplies panel `b`,
//! the decode stage fills panel `b+1` (paper, "Pipeline Design"). On GPU
//! the stages are CUDA cores vs Tensor Cores; here they are OS threads, but
//! the overlap structure and the ring buffer are identical.

use crate::gemm::sparse::panel_acc;
use crate::sparse::BitmapMatrix;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// True when the host has a second hardware thread to run the decode
/// stage on. On a single-core host the two-stage overlap has no parallel
/// resource and the panel-streamed path is strictly better.
fn overlap_available() -> bool {
    std::thread::available_parallelism()
        .map(|n| n.get() >= 2)
        .unwrap_or(false)
}

/// Bounded wait: brief spin, then yield to let the other stage run (on
/// SMT/single-core hosts pure spinning starves the producer).
#[inline]
fn stage_wait(iters: &mut u32) {
    *iters += 1;
    if *iters < 64 {
        std::hint::spin_loop();
    } else {
        std::thread::yield_now();
    }
}

/// A fixed-capacity ring of panel buffers shared between the decode and
/// GEMM stages. Slots cycle through EMPTY -> FULL -> EMPTY.
struct PanelRing {
    slots: Vec<Mutex<Vec<f32>>>,
    /// Sequence number of the next panel the decoder will produce.
    produced: AtomicUsize,
    /// Sequence number of the next panel the consumer will take.
    consumed: AtomicUsize,
    /// Set if either side panicked / finished early.
    dead: AtomicBool,
    depth: usize,
}

impl PanelRing {
    fn new(depth: usize, panel_elems: usize) -> Self {
        PanelRing {
            slots: (0..depth)
                .map(|_| Mutex::new(vec![0.0f32; panel_elems]))
                .collect(),
            produced: AtomicUsize::new(0),
            consumed: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
            depth,
        }
    }
}

/// Configuration of the two-stage pipeline.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Rows of W decoded per panel (K-panel height).
    pub panel_k: usize,
    /// Ring buffer depth (>= 2 for any overlap).
    pub ring_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            panel_k: 64,
            ring_depth: 3,
        }
    }
}

/// `C[m,n] = X[m,k] @ W[k,n]` with bitmap `W`, decode and GEMM overlapped.
///
/// The decoder thread walks K-panels of `W` writing into ring slots; the
/// calling thread consumes panels in order and accumulates into `C`.
pub fn bitmap_gemm_pipelined(
    x: &[f32],
    w: &BitmapMatrix,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
) {
    let (k, n) = (w.rows(), w.cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    c[..m * n].fill(0.0);
    if k == 0 || n == 0 || m == 0 {
        return;
    }
    let panel_k = cfg.panel_k.max(1).min(k);
    let npanels = k.div_ceil(panel_k);
    if npanels == 1 || cfg.ring_depth < 2 || !overlap_available() {
        // Degenerate: no overlap possible; run sequentially.
        let mut scratch = Vec::new();
        crate::gemm::sparse::bitmap_gemm_panelled(x, w, c, m, panel_k, &mut scratch);
        return;
    }
    let ring = PanelRing::new(cfg.ring_depth, panel_k * n);

    crossbeam_utils::thread::scope(|scope| {
        // ---- Stage 1: decode worker ----
        let ring_ref = &ring;
        scope.spawn(move |_| {
            for pi in 0..npanels {
                // Wait for a free slot: decoder may run at most `depth`
                // panels ahead of the consumer.
                let mut waited = 0u32;
                while pi >= ring_ref.consumed.load(Ordering::Acquire) + ring_ref.depth {
                    if ring_ref.dead.load(Ordering::Relaxed) {
                        return;
                    }
                    stage_wait(&mut waited);
                }
                let slot = &ring_ref.slots[pi % ring_ref.depth];
                {
                    let mut buf = slot.lock().unwrap();
                    let r0 = pi * panel_k;
                    let r1 = (r0 + panel_k).min(k);
                    w.decode_rows_into(r0, r1, &mut buf);
                }
                ring_ref.produced.store(pi + 1, Ordering::Release);
            }
        });

        // ---- Stage 2: GEMM consumer (this thread) ----
        for pi in 0..npanels {
            let mut waited = 0u32;
            while ring.produced.load(Ordering::Acquire) <= pi {
                stage_wait(&mut waited);
            }
            let r0 = pi * panel_k;
            let r1 = (r0 + panel_k).min(k);
            let kb = r1 - r0;
            {
                let buf = ring.slots[pi % ring.depth].lock().unwrap();
                panel_acc(x, &buf[..kb * n], c, m, k, n, r0, kb);
            }
            ring.consumed.store(pi + 1, Ordering::Release);
        }
    })
    .unwrap();
}

/// Fold the low-rank adapter update into the same call:
/// `C = X @ W_sparse + (X @ A_cat) @ B_cat` with the adapter GEMM executed
/// on the consumer thread *while the first panel decodes* — mirroring the
/// paper's note that "the LoRA module participates in GEMM computation"
/// during the decode stage.
#[allow(clippy::too_many_arguments)]
pub fn salr_gemm_pipelined(
    x: &[f32],
    w: &BitmapMatrix,
    a_cat: &[f32],
    b_cat: &[f32],
    rank_total: usize,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
) {
    let (k, n) = (w.rows(), w.cols());
    c[..m * n].fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    let panel_k = cfg.panel_k.max(1).min(k.max(1));
    let npanels = k.div_ceil(panel_k.max(1)).max(1);
    if !overlap_available() {
        // Single hardware thread: run the stages back to back (panel-
        // streamed), adapters first.
        if rank_total > 0 {
            let mut u = vec![0.0f32; m * rank_total];
            crate::gemm::dense::gemm_f32(x, a_cat, &mut u, m, k, rank_total);
            crate::gemm::dense::gemm_f32_acc(&u, b_cat, c, m, rank_total, n);
        }
        let mut scratch = Vec::new();
        let mut base = vec![0.0f32; m * n];
        crate::gemm::sparse::bitmap_gemm_panelled(x, w, &mut base, m, panel_k, &mut scratch);
        for (ci, bi) in c.iter_mut().zip(&base) {
            *ci += bi;
        }
        return;
    }
    let ring = PanelRing::new(cfg.ring_depth.max(2), panel_k * n);

    crossbeam_utils::thread::scope(|scope| {
        let ring_ref = &ring;
        scope.spawn(move |_| {
            for pi in 0..npanels {
                let mut waited = 0u32;
                while pi >= ring_ref.consumed.load(Ordering::Acquire) + ring_ref.depth {
                    stage_wait(&mut waited);
                }
                let slot = &ring_ref.slots[pi % ring_ref.depth];
                {
                    let mut buf = slot.lock().unwrap();
                    let r0 = pi * panel_k;
                    let r1 = (r0 + panel_k).min(k);
                    w.decode_rows_into(r0, r1, &mut buf);
                }
                ring_ref.produced.store(pi + 1, Ordering::Release);
            }
        });

        // Adapter GEMM overlaps the first panel's decode.
        if rank_total > 0 {
            let mut u = vec![0.0f32; m * rank_total];
            crate::gemm::dense::gemm_f32(x, a_cat, &mut u, m, k, rank_total);
            crate::gemm::dense::gemm_f32_acc(&u, b_cat, c, m, rank_total, n);
        }

        for pi in 0..npanels {
            let mut waited = 0u32;
            while ring.produced.load(Ordering::Acquire) <= pi {
                stage_wait(&mut waited);
            }
            let r0 = pi * panel_k;
            let r1 = (r0 + panel_k).min(k);
            let kb = r1 - r0;
            {
                let buf = ring.slots[pi % ring.depth].lock().unwrap();
                panel_acc(x, &buf[..kb * n], c, m, k, n, r0, kb);
            }
            ring.consumed.store(pi + 1, Ordering::Release);
        }
    })
    .unwrap();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::tensor::{add, matmul, matmul_naive, max_abs_diff, Tensor};
    use crate::util::rng::Rng;

    #[test]
    fn pipelined_matches_dense() {
        let mut rng = Rng::new(120);
        for &(m, k, n, pk, depth) in &[
            (4usize, 64usize, 32usize, 16usize, 2usize),
            (8, 200, 48, 33, 3),
            (1, 512, 64, 64, 4),
            (5, 10, 10, 4, 2),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            prune_global(&mut [&mut w], 0.5);
            let bm = BitmapMatrix::encode(&w);
            let want = matmul_naive(&x, &w);
            let mut c = vec![0.0f32; m * n];
            bitmap_gemm_pipelined(
                x.data(),
                &bm,
                &mut c,
                m,
                PipelineConfig {
                    panel_k: pk,
                    ring_depth: depth,
                },
            );
            let c = Tensor::from_vec(&[m, n], c);
            assert!(
                max_abs_diff(&c, &want) < 1e-3,
                "({m},{k},{n},{pk},{depth})"
            );
        }
    }

    #[test]
    fn salr_pipelined_includes_adapters() {
        let mut rng = Rng::new(121);
        let (m, k, n, r) = (6usize, 96usize, 40usize, 8usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let a = Tensor::randn(&[k, r], 0.1, &mut rng);
        let b = Tensor::randn(&[r, n], 0.1, &mut rng);
        let bm = BitmapMatrix::encode(&w);
        let want = add(&matmul_naive(&x, &w), &matmul(&matmul(&x, &a), &b));
        let mut c = vec![0.0f32; m * n];
        salr_gemm_pipelined(
            x.data(),
            &bm,
            a.data(),
            b.data(),
            r,
            &mut c,
            m,
            PipelineConfig::default(),
        );
        let c = Tensor::from_vec(&[m, n], c);
        assert!(max_abs_diff(&c, &want) < 1e-2, "diff={}", max_abs_diff(&c, &want));
    }

    #[test]
    fn ring_depth_one_falls_back() {
        let mut rng = Rng::new(122);
        let x = Tensor::randn(&[3, 32], 1.0, &mut rng);
        let mut w = Tensor::randn(&[32, 16], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        let want = matmul_naive(&x, &w);
        let mut c = vec![0.0f32; 3 * 16];
        bitmap_gemm_pipelined(
            x.data(),
            &bm,
            &mut c,
            3,
            PipelineConfig {
                panel_k: 8,
                ring_depth: 1,
            },
        );
        let c = Tensor::from_vec(&[3, 16], c);
        assert!(max_abs_diff(&c, &want) < 1e-3);
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        let mut rng = Rng::new(123);
        let x = Tensor::randn(&[4, 128], 1.0, &mut rng);
        let mut w = Tensor::randn(&[128, 32], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        let mut first = vec![0.0f32; 4 * 32];
        bitmap_gemm_pipelined(x.data(), &bm, &mut first, 4, PipelineConfig::default());
        for _ in 0..10 {
            let mut c = vec![0.0f32; 4 * 32];
            bitmap_gemm_pipelined(x.data(), &bm, &mut c, 4, PipelineConfig::default());
            assert_eq!(c, first, "pipeline must be deterministic");
        }
    }
}
