//! The paper's **two-stage pipelined decode+GEMM**, generalized to
//! multiple workers per stage.
//!
//! Stage 1 (decode): `P` decode workers reconstruct dense K-panels of the
//! compressed weight matrix (worker `d` owns panels `d, d+P, …`) using the
//! source's decode rule — bitmap byte-mask scatter, NF4 LUT dequantize, or
//! a plain copy for dense operands. The pipeline is generic over
//! [`PackB`], so any weight representation the packed GEMM accepts also
//! streams through the ring.
//! Stage 2 (GEMM): `C` consumer workers each own a disjoint stripe of
//! output columns and apply every panel — in panel order — to their stripe.
//!
//! The stages communicate through a fixed-depth **ring buffer** of
//! pre-allocated panel slots: while consumers multiply panel `b`, decoders
//! fill panels `b+1 … b+depth-1` (paper, "Pipeline Design"). Slot hand-off
//! is lock-free: a per-slot `ready` sequence number publishes decoded
//! panels, and per-consumer progress counters tell decoders when a slot
//! can be reused. On GPU the stages are CUDA cores vs Tensor Cores; here
//! they are persistent pool threads, but the overlap structure and the
//! ring buffer are identical.
//!
//! Determinism: each output element accumulates the adapter update first,
//! then panels in ascending order with a fixed in-panel order — the same
//! order the single-threaded fallback uses — so results are **bitwise
//! identical** across thread counts and across runs.

use crate::gemm::dense::PackB;
use crate::gemm::sparse::{addmul_stripe, panel_acc, panel_acc_stripe};
use crate::util::arena;
use crate::util::pool::{SendPtr, WorkerPool};
use crate::util::trace::{self, TraceKind};
use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Bounded wait: brief spin, then yield to let the other stage run (on
/// SMT/oversubscribed hosts pure spinning starves the producer).
#[inline]
fn stage_wait(iters: &mut u32) {
    *iters += 1;
    if *iters < 64 {
        std::hint::spin_loop();
    } else {
        std::thread::yield_now();
    }
}

/// Configuration of the two-stage pipeline.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Rows of W decoded per panel (K-panel height).
    pub panel_k: usize,
    /// Ring buffer depth (>= 2 for any overlap).
    pub ring_depth: usize,
    /// Total worker threads across both stages (0 = the process-global
    /// pool, i.e. every available core).
    pub num_threads: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            panel_k: 64,
            ring_depth: 3,
            num_threads: 0,
        }
    }
}

impl PipelineConfig {
    /// Default geometry with an explicit thread count.
    pub fn with_threads(num_threads: usize) -> Self {
        PipelineConfig {
            num_threads,
            ..Default::default()
        }
    }
}

/// One ring slot: a panel buffer plus the sequence number of its content.
struct RingSlot {
    buf: UnsafeCell<Vec<f32>>,
    /// `panel_id + 1` of the decoded content (0 = empty). Stored with
    /// Release after the decode writes, loaded with Acquire before reads.
    ready: CachePadded<AtomicUsize>,
}

// SAFETY: access to `buf` is serialized by the ready/progress protocol
// below — a decoder writes only after every consumer has passed the slot's
// previous panel, and consumers read only after `ready` publishes it.
unsafe impl Sync for RingSlot {}

/// The fixed-capacity panel ring shared between the two stages.
struct PanelRing {
    slots: Vec<RingSlot>,
    depth: usize,
    /// Per-consumer progress: consumer `c` has fully applied panels
    /// `< prog[c]` to its stripe.
    prog: Vec<CachePadded<AtomicUsize>>,
    /// Set when any stage panics so the others bail out of their spins.
    dead: AtomicBool,
}

impl PanelRing {
    /// Build the ring over caller-supplied slot buffers (checked out of
    /// the calling thread's scratch arena and returned after the run, so
    /// repeated pipelined GEMMs reuse the same slabs).
    fn new(bufs: Vec<Vec<f32>>, consumers: usize) -> PanelRing {
        let depth = bufs.len();
        PanelRing {
            slots: bufs
                .into_iter()
                .map(|buf| RingSlot {
                    buf: UnsafeCell::new(buf),
                    ready: CachePadded::new(AtomicUsize::new(0)),
                })
                .collect(),
            depth,
            prog: (0..consumers)
                .map(|_| CachePadded::new(AtomicUsize::new(0)))
                .collect(),
            dead: AtomicBool::new(false),
        }
    }

    /// Slowest consumer's next-needed panel.
    fn min_prog(&self) -> usize {
        self.prog
            .iter()
            .map(|p| p.load(Ordering::Acquire))
            .min()
            .unwrap_or(0)
    }
}

/// Sets the ring's dead flag if the holder unwinds, so the other stages'
/// spin loops exit instead of waiting forever on a panicked peer.
struct Bail<'a>(&'a AtomicBool);

impl Drop for Bail<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::SeqCst);
        }
    }
}

/// Split `threads` execution contexts between the stages.
fn stage_split(threads: usize, npanels: usize, n: usize) -> (usize, usize) {
    let decoders = (threads / 2).clamp(1, npanels);
    let consumers = threads.saturating_sub(decoders).clamp(1, n);
    (decoders, consumers)
}

/// Decode worker `d` of `stride`: reconstructs panels `d, d+stride, …`
/// into their ring slots, at most `depth` panels ahead of the slowest
/// consumer.
fn decode_role<S: PackB + ?Sized>(
    ring: &PanelRing,
    w: &S,
    panel_k: usize,
    npanels: usize,
    d: usize,
    stride: usize,
) {
    let _bail = Bail(&ring.dead);
    let k = w.k_rows();
    let mut pi = d;
    while pi < npanels {
        let mut waited = 0u32;
        while pi >= ring.min_prog() + ring.depth {
            if ring.dead.load(Ordering::Relaxed) {
                return;
            }
            stage_wait(&mut waited);
        }
        if ring.dead.load(Ordering::Relaxed) {
            return;
        }
        let slot = &ring.slots[pi % ring.depth];
        let r0 = pi * panel_k;
        let r1 = (r0 + panel_k).min(k);
        // SAFETY: every consumer has passed the panel this slot previously
        // held (min_prog handshake), and panel `pi` has exactly one owner,
        // so we have exclusive access to the buffer.
        let buf = unsafe { &mut *slot.buf.get() };
        let t0 = if trace::enabled() { trace::now_us() } else { 0 };
        w.decode_rows_into(r0, r1, buf);
        if trace::enabled() {
            // The pipeline's decode stage is its pack step: one panel
            // reconstructed from the compressed representation.
            trace::record_span(
                TraceKind::PackB,
                trace::current_trace(),
                t0,
                ((r1 - r0) * w.n_cols()) as u64,
            );
        }
        slot.ready.store(pi + 1, Ordering::Release);
        pi += stride;
    }
}

/// Consumer `ci`: applies every panel, in order, to output columns
/// `[j0, j1)`.
#[allow(clippy::too_many_arguments)]
fn consume_role(
    ring: &PanelRing,
    x: &[f32],
    c: SendPtr,
    m: usize,
    k: usize,
    n: usize,
    panel_k: usize,
    npanels: usize,
    ci: usize,
    j0: usize,
    j1: usize,
) {
    let _bail = Bail(&ring.dead);
    for pi in 0..npanels {
        let slot = &ring.slots[pi % ring.depth];
        let mut waited = 0u32;
        while slot.ready.load(Ordering::Acquire) != pi + 1 {
            if ring.dead.load(Ordering::Relaxed) {
                return;
            }
            stage_wait(&mut waited);
        }
        let r0 = pi * panel_k;
        let kb = (r0 + panel_k).min(k) - r0;
        // SAFETY: `ready == pi+1` orders this read after the decode write;
        // consumers share the buffer read-only, and this consumer
        // exclusively owns C columns [j0, j1).
        let buf = unsafe { &*slot.buf.get() };
        unsafe { panel_acc_stripe(x, &buf[..kb * n], c.0, m, k, n, r0, kb, j0, j1) };
        ring.prog[ci].store(pi + 1, Ordering::Release);
    }
}

/// Shared engine for both pipelined entry points: decode workers stream
/// K-panels into the ring while consumers apply (adapter stripe +) panel
/// stripes to their disjoint output columns. `u = X @ A_cat` is
/// precomputed; pass `rank_total = 0` to skip the adapter update.
///
/// Must be called from outside the pool (the roles coordinate, so they
/// need `decoders + consumers <= pool.threads()` contexts to eventually
/// run concurrently — guaranteed for top-level callers by the pool's FIFO
/// queue, but not for a caller that is itself a pool task).
#[allow(clippy::too_many_arguments)]
fn run_pipelined<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    u: &[f32],
    b_cat: &[f32],
    rank_total: usize,
    c: &mut [f32],
    m: usize,
    panel_k: usize,
    npanels: usize,
    ring_depth: usize,
    pool: &WorkerPool,
) {
    let (k, n) = (w.k_rows(), w.n_cols());
    let (decoders, consumers) = stage_split(pool.threads(), npanels, n);
    // Slot buffers come from the calling thread's arena and go back to it
    // once every stage has finished — steady-state prefill GEMMs reuse
    // the same slabs instead of reallocating `depth × panel` floats.
    let bufs: Vec<Vec<f32>> = (0..ring_depth.max(2))
        .map(|_| arena::take_vec(panel_k * n))
        .collect();
    let ring = PanelRing::new(bufs, consumers);
    let cptr = SendPtr(c.as_mut_ptr());
    // Stage workers run on pool threads with no trace context of their
    // own; carry the caller's id across so decode-stage `pack_b` spans
    // attribute to the request.
    let tid = trace::current_trace();
    pool.run(decoders + consumers, &|role| {
        if role < decoders {
            trace::with_trace(tid, || decode_role(&ring, w, panel_k, npanels, role, decoders));
        } else {
            let ci = role - decoders;
            let j0 = ci * n / consumers;
            let j1 = (ci + 1) * n / consumers;
            if rank_total > 0 {
                // The adapter GEMM overlaps the first panels' decode — the
                // paper's "the LoRA module participates in GEMM
                // computation" during the decode stage.
                // SAFETY: this consumer exclusively owns columns [j0, j1).
                unsafe { addmul_stripe(u, b_cat, cptr.0, m, rank_total, n, j0, j1) };
            }
            consume_role(&ring, x, cptr, m, k, n, panel_k, npanels, ci, j0, j1);
        }
    });
    for slot in ring.slots {
        arena::give_vec(slot.buf.into_inner());
    }
}

/// `C[m,n] = X[m,k] @ W[k,n]` with compressed `W` (any [`PackB`] source),
/// decode and GEMM overlapped across `cfg.num_threads` workers (0 = all
/// cores). Falls back to the panel-streamed sequential path when there is
/// no parallel resource.
///
/// Resolves a registry pool from the thread knob; callers that own a pool
/// (the engine, per-worker private pools) should use
/// [`gemm_pipelined_pool`] so every execution path shares one thread
/// budget.
pub fn gemm_pipelined<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
) {
    gemm_pipelined_pool(x, w, c, m, cfg, &WorkerPool::with_threads(cfg.num_threads));
}

/// [`gemm_pipelined`] on an explicit pool: the stage workers (and the
/// degenerate fallback) run on `pool`, ignoring `cfg.num_threads` — this
/// is what makes `--threads 1` ablations apples-to-apples when the engine
/// owns a private (un-registered) pool. Equivalent to the adapter-fused
/// entry with a rank-0 adapter, and shares its code so the two stay
/// bitwise aligned.
pub fn gemm_pipelined_pool<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
    pool: &WorkerPool,
) {
    let (k, n) = (w.k_rows(), w.n_cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    salr_gemm_pipelined_pool(x, w, &[], &[], 0, c, m, cfg, pool);
}

/// Fold the low-rank adapter update into the same call:
/// `C = X @ W_sparse + (X @ A_cat) @ B_cat`, with each consumer applying
/// its adapter stripe *while the first panels decode*. Resolves a registry
/// pool from `cfg.num_threads`; pool-owning callers use
/// [`salr_gemm_pipelined_pool`].
#[allow(clippy::too_many_arguments)]
pub fn salr_gemm_pipelined<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    a_cat: &[f32],
    b_cat: &[f32],
    rank_total: usize,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
) {
    salr_gemm_pipelined_pool(
        x,
        w,
        a_cat,
        b_cat,
        rank_total,
        c,
        m,
        cfg,
        &WorkerPool::with_threads(cfg.num_threads),
    );
}

/// [`salr_gemm_pipelined`] on an explicit pool (stage workers + the
/// adapter pre-GEMM + the degenerate fallback all run on `pool`;
/// `cfg.num_threads` is ignored). The engine's prefill path calls this
/// with its own pool, so private per-engine-worker pools are honored end
/// to end.
#[allow(clippy::too_many_arguments)]
pub fn salr_gemm_pipelined_pool<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    a_cat: &[f32],
    b_cat: &[f32],
    rank_total: usize,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
    pool: &WorkerPool,
) {
    // One `gemm_call` span per pipelined entry (both public wrappers
    // funnel here, so no duplicates); disabled cost is one relaxed load.
    if !trace::enabled() {
        return salr_pipelined_inner(x, w, a_cat, b_cat, rank_total, c, m, cfg, pool);
    }
    let t0 = trace::now_us();
    let macs = (m * w.k_rows() * w.n_cols()) as u64;
    salr_pipelined_inner(x, w, a_cat, b_cat, rank_total, c, m, cfg, pool);
    trace::record_span(TraceKind::GemmCall, trace::current_trace(), t0, macs);
}

#[allow(clippy::too_many_arguments)]
fn salr_pipelined_inner<S: PackB + ?Sized>(
    x: &[f32],
    w: &S,
    a_cat: &[f32],
    b_cat: &[f32],
    rank_total: usize,
    c: &mut [f32],
    m: usize,
    cfg: PipelineConfig,
    pool: &WorkerPool,
) {
    let (k, n) = (w.k_rows(), w.n_cols());
    c[..m * n].fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    // `u = X @ A_cat` is tiny (m × total_rank); computing it up front keeps
    // the consumers' adapter stripes independent of each other. Arena
    // scratch: the GEMM zero-fills it before accumulating.
    let mut u = arena::scratch_undef(m * rank_total);
    if rank_total > 0 && k > 0 {
        crate::gemm::dense::gemm_f32_pool(x, a_cat, &mut u, m, k, rank_total, pool);
    }
    if k == 0 {
        // X has no columns: every product term is zero.
        return;
    }
    let panel_k = cfg.panel_k.max(1).min(k);
    let npanels = k.div_ceil(panel_k);
    if npanels == 1 || cfg.ring_depth < 2 || pool.threads() < 2 {
        // Single context: adapters first, then stream panels straight into
        // C — same per-element order as the pipelined path, no m*n temp.
        if rank_total > 0 {
            // SAFETY: we hold the only reference to `c`.
            unsafe { addmul_stripe(&u, b_cat, c.as_mut_ptr(), m, rank_total, n, 0, n) };
        }
        let mut scratch = arena::scratch_undef(panel_k * n);
        let mut r0 = 0;
        while r0 < k {
            let r1 = (r0 + panel_k).min(k);
            let kb = r1 - r0;
            let t0 = if trace::enabled() { trace::now_us() } else { 0 };
            w.decode_rows_into(r0, r1, &mut scratch);
            if trace::enabled() {
                trace::record_span(
                    TraceKind::PackB,
                    trace::current_trace(),
                    t0,
                    (kb * n) as u64,
                );
            }
            panel_acc(x, &scratch[..kb * n], c, m, k, n, r0, kb);
            r0 = r1;
        }
        return;
    }
    run_pipelined(x, w, &u, b_cat, rank_total, c, m, panel_k, npanels, cfg.ring_depth, pool);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::quant::SparseNf4Matrix;
    use crate::sparse::BitmapMatrix;
    use crate::tensor::{add, matmul, matmul_naive, max_abs_diff, Tensor};
    use crate::util::rng::Rng;

    #[test]
    fn pipelined_matches_dense() {
        let mut rng = Rng::new(120);
        for &(m, k, n, pk, depth) in &[
            (4usize, 64usize, 32usize, 16usize, 2usize),
            (8, 200, 48, 33, 3),
            (1, 512, 64, 64, 4),
            (5, 10, 10, 4, 2),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            prune_global(&mut [&mut w], 0.5);
            let bm = BitmapMatrix::encode(&w);
            let want = matmul_naive(&x, &w);
            let mut c = vec![0.0f32; m * n];
            gemm_pipelined(
                x.data(),
                &bm,
                &mut c,
                m,
                PipelineConfig {
                    panel_k: pk,
                    ring_depth: depth,
                    num_threads: 0,
                },
            );
            let c = Tensor::from_vec(&[m, n], c);
            assert!(
                max_abs_diff(&c, &want) < 1e-3,
                "({m},{k},{n},{pk},{depth})"
            );
        }
    }

    #[test]
    fn salr_pipelined_includes_adapters() {
        let mut rng = Rng::new(121);
        let (m, k, n, r) = (6usize, 96usize, 40usize, 8usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let a = Tensor::randn(&[k, r], 0.1, &mut rng);
        let b = Tensor::randn(&[r, n], 0.1, &mut rng);
        let bm = BitmapMatrix::encode(&w);
        let want = add(&matmul_naive(&x, &w), &matmul(&matmul(&x, &a), &b));
        let mut c = vec![0.0f32; m * n];
        salr_gemm_pipelined(
            x.data(),
            &bm,
            a.data(),
            b.data(),
            r,
            &mut c,
            m,
            PipelineConfig::default(),
        );
        let c = Tensor::from_vec(&[m, n], c);
        assert!(max_abs_diff(&c, &want) < 1e-2, "diff={}", max_abs_diff(&c, &want));
    }

    #[test]
    fn ring_depth_one_falls_back() {
        let mut rng = Rng::new(122);
        let x = Tensor::randn(&[3, 32], 1.0, &mut rng);
        let mut w = Tensor::randn(&[32, 16], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        let want = matmul_naive(&x, &w);
        let mut c = vec![0.0f32; 3 * 16];
        gemm_pipelined(
            x.data(),
            &bm,
            &mut c,
            3,
            PipelineConfig {
                panel_k: 8,
                ring_depth: 1,
                num_threads: 0,
            },
        );
        let c = Tensor::from_vec(&[3, 16], c);
        assert!(max_abs_diff(&c, &want) < 1e-3);
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        let mut rng = Rng::new(123);
        let x = Tensor::randn(&[4, 128], 1.0, &mut rng);
        let mut w = Tensor::randn(&[128, 32], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        let mut first = vec![0.0f32; 4 * 32];
        gemm_pipelined(x.data(), &bm, &mut first, 4, PipelineConfig::default());
        for _ in 0..10 {
            let mut c = vec![0.0f32; 4 * 32];
            gemm_pipelined(x.data(), &bm, &mut c, 4, PipelineConfig::default());
            assert_eq!(c, first, "pipeline must be deterministic");
        }
    }

    #[test]
    fn explicit_pool_matches_registry_pool() {
        // The `_pool` entry points must produce the same bits whether the
        // pool is a private instance (any width, including 1 = sequential
        // fallback) or the registry pool the knob-based API resolves.
        let mut rng = Rng::new(125);
        let (m, k, n, r) = (6usize, 160usize, 48usize, 8usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let a = Tensor::randn(&[k, r], 0.1, &mut rng);
        let b = Tensor::randn(&[r, n], 0.1, &mut rng);
        let bm = BitmapMatrix::encode(&w);
        let cfg = PipelineConfig {
            panel_k: 32,
            ring_depth: 3,
            num_threads: 3,
        };
        let mut via_knob = vec![0.0f32; m * n];
        salr_gemm_pipelined(x.data(), &bm, a.data(), b.data(), r, &mut via_knob, m, cfg);
        for threads in [1usize, 2, 4] {
            let private = WorkerPool::new(threads);
            let mut c = vec![0.0f32; m * n];
            salr_gemm_pipelined_pool(
                x.data(),
                &bm,
                a.data(),
                b.data(),
                r,
                &mut c,
                m,
                cfg,
                &private,
            );
            assert_eq!(c, via_knob, "private pool width {threads} changed bits");
            let mut cb = vec![0.0f32; m * n];
            gemm_pipelined_pool(x.data(), &bm, &mut cb, m, cfg, &private);
            let mut want = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &bm, &mut want, m, cfg);
            assert_eq!(cb, want, "bitmap private pool width {threads} changed bits");
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let mut rng = Rng::new(124);
        let (m, k, n, r) = (8usize, 256usize, 96usize, 12usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let a = Tensor::randn(&[k, r], 0.1, &mut rng);
        let b = Tensor::randn(&[r, n], 0.1, &mut rng);
        let bm = BitmapMatrix::encode(&w);
        let mut base: Option<Vec<f32>> = None;
        let mut salr_base: Option<Vec<f32>> = None;
        for &t in &[1usize, 2, 3, 4] {
            let cfg = PipelineConfig {
                panel_k: 32,
                ring_depth: 3,
                num_threads: t,
            };
            let mut c = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &bm, &mut c, m, cfg);
            match &base {
                None => base = Some(c),
                Some(bref) => assert_eq!(&c, bref, "bitmap t={t} changed bits"),
            }
            let mut cs = vec![0.0f32; m * n];
            salr_gemm_pipelined(x.data(), &bm, a.data(), b.data(), r, &mut cs, m, cfg);
            match &salr_base {
                None => salr_base = Some(cs),
                Some(sref) => assert_eq!(&cs, sref, "salr t={t} changed bits"),
            }
        }
    }

    #[test]
    fn pipelined_sources_are_bitwise_identical_when_values_agree() {
        // Every PackB source streams panels through the same ring and the
        // same consumer kernel, so two sources that decode to the same
        // f32 values must produce the same bits: a WeightStore wrapping a
        // bitmap matches the bare bitmap, and an NF4 store matches a
        // bitmap re-encoding of its dequantized values.
        let mut rng = Rng::new(126);
        let (m, k, n) = (5usize, 160usize, 40usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        let store = crate::model::WeightStore::from_bitmap(bm.clone());
        let snf = SparseNf4Matrix::from_bitmap(&bm, 64);
        let bm_of_dq = BitmapMatrix::encode(&snf.decode());
        for &t in &[1usize, 3] {
            let cfg = PipelineConfig {
                panel_k: 48,
                ring_depth: 3,
                num_threads: t,
            };
            let mut via_bm = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &bm, &mut via_bm, m, cfg);
            let mut via_store = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &store, &mut via_store, m, cfg);
            assert_eq!(via_store, via_bm, "store t={t} changed bits");
            let mut via_nf4 = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &snf, &mut via_nf4, m, cfg);
            let mut via_dq = vec![0.0f32; m * n];
            gemm_pipelined(x.data(), &bm_of_dq, &mut via_dq, m, cfg);
            assert_eq!(via_nf4, via_dq, "nf4 t={t} changed bits");
        }
    }
}
