//! Runtime-dispatched SIMD micro-kernels for the packed 4×16 GEMM tile.
//!
//! The blocked GEMM in [`super::dense`] spends essentially all of its time
//! in one place: `acc[r][j] += pa[p*MR+r] * pb[p*NR+j]` over a K panel,
//! with both operands pre-packed into contiguous panels. This module owns
//! that inner loop and picks the widest implementation the host supports
//! **at runtime**:
//!
//! | arch      | kernel   | selected when |
//! |-----------|----------|---------------|
//! | `x86_64`  | `avx2`   | `is_x86_feature_detected!("avx2")` |
//! | `aarch64` | `neon`   | always (NEON is baseline on aarch64) |
//! | any       | `scalar` | no SIMD available, or `SALR_FORCE_SCALAR=1` |
//!
//! **Bitwise determinism.** The SIMD kernels vectorize *across the 16
//! packed-B lanes*: lane `j` of the accumulator only ever combines
//! `pa[p*MR+r] * pb[p*NR+j]` terms, added in ascending `p` order — exactly
//! the per-element accumulation order of the scalar kernel. Multiplies and
//! adds are separate IEEE-754 operations (`mul_ps` + `add_ps`, never FMA,
//! which would contract them and change the rounding), so every lane of
//! every output is **bit-identical** to the scalar kernel on every input.
//! The test suite asserts this over a ragged shape sweep, and CI runs the
//! whole test suite a second time under `SALR_FORCE_SCALAR=1` so the
//! fallback cannot rot.
//!
//! `SALR_FORCE_SCALAR=1` (read once, via `once_cell`) pins dispatch to the
//! scalar kernel — the ablation/CI knob for exercising both code paths on
//! the same host.

use once_cell::sync::Lazy;

/// Rows of the register micro-tile (A panel width).
pub const MR: usize = 4;
/// Columns of the register micro-tile (B panel width).
pub const NR: usize = 16;

/// The packed micro-kernel contract: accumulate
/// `acc[r][j] += Σ_p pa[p*MR + r] * pb[p*NR + j]` for `p in 0..kb`,
/// with `pa`/`pb` contiguous MR-/NR-wide panels (zero-padded at edges).
/// Terms must be added in ascending `p` order per element — that is what
/// keeps every implementation bitwise interchangeable.
pub type MicroKernelFn = fn(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize);

/// The dispatched axpy contract: `dst[j] += s * src[j]` element-wise.
/// Each output element receives exactly one multiply and one add, so
/// vectorizing *across* elements cannot reorder any element's
/// accumulation — every implementation is bitwise interchangeable (same
/// mul-then-add, never FMA, rule as the micro-kernel). This is the inner
/// loop of the pipeline consumers (`panel_acc_stripe` / `addmul_stripe`),
/// whose zero-skip outer loops stay scalar.
pub type AxpyFn = fn(s: f32, src: &[f32], dst: &mut [f32]);

/// A selected micro-kernel implementation (copyable function handles).
#[derive(Clone, Copy)]
pub struct Kernel {
    micro: MicroKernelFn,
    axpy: AxpyFn,
    name: &'static str,
}

impl Kernel {
    /// The portable scalar kernel (always available; the dispatch
    /// baseline every SIMD path must match bit-for-bit).
    pub fn scalar() -> Kernel {
        Kernel {
            micro: micro_scalar,
            axpy: axpy_scalar,
            name: "scalar",
        }
    }

    /// The kernel the runtime dispatcher selected for this host
    /// (cached after the first call; honors `SALR_FORCE_SCALAR=1`).
    pub fn active() -> Kernel {
        *ACTIVE
    }

    /// Implementation name: `"avx2"`, `"neon"` or `"scalar"` — logged by
    /// the benches so JSON rows record which kernel produced them.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Run the micro-kernel over one packed tile pair.
    #[inline]
    pub fn run(&self, pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
        (self.micro)(pa, pb, acc, kb)
    }

    /// `dst[j] += s * src[j]` over `min(src.len(), dst.len())` elements,
    /// with this kernel's axpy implementation.
    #[inline]
    pub fn axpy(&self, s: f32, src: &[f32], dst: &mut [f32]) {
        (self.axpy)(s, src, dst)
    }
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernel").field("name", &self.name).finish()
    }
}

/// `true` when `SALR_FORCE_SCALAR=1` (or `=true`) pins dispatch to the
/// scalar kernel. Read once per process.
pub fn force_scalar() -> bool {
    static FORCE: Lazy<bool> = Lazy::new(|| {
        matches!(
            std::env::var("SALR_FORCE_SCALAR").as_deref(),
            Ok("1") | Ok("true")
        )
    });
    *FORCE
}

static ACTIVE: Lazy<Kernel> = Lazy::new(detect);

fn detect() -> Kernel {
    if force_scalar() {
        return Kernel::scalar();
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return Kernel {
            micro: x86::micro_avx2,
            axpy: x86::axpy_avx2,
            name: "avx2",
        };
    }
    #[cfg(target_arch = "aarch64")]
    {
        Kernel {
            micro: neon::micro_neon,
            axpy: neon::axpy_neon,
            name: "neon",
        }
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        Kernel::scalar()
    }
}

/// Portable reference micro-kernel. The NR-wide inner loop is written so
/// the autovectorizer can lift it, but its *semantics* are the contract:
/// one mul and one add per (element, p), ascending p.
fn micro_scalar(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
    for p in 0..kb {
        let arow = &pa[p * MR..p * MR + MR];
        let brow = &pb[p * NR..p * NR + NR];
        let (a0, a1, a2, a3) = (arow[0], arow[1], arow[2], arow[3]);
        for jj in 0..NR {
            let bv = brow[jj];
            acc[0][jj] += a0 * bv;
            acc[1][jj] += a1 * bv;
            acc[2][jj] += a2 * bv;
            acc[3][jj] += a3 * bv;
        }
    }
}

/// Portable reference axpy: one mul and one add per element, in index
/// order. The SIMD variants compute exactly these per-element operations,
/// just more of them per instruction.
fn axpy_scalar(s: f32, src: &[f32], dst: &mut [f32]) {
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += s * x;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// AVX2 axpy: 8 lanes per step, scalar tail. Safe wrapper — only
    /// ever selected after `is_x86_feature_detected!("avx2")`.
    pub(super) fn axpy_avx2(s: f32, src: &[f32], dst: &mut [f32]) {
        // SAFETY: the dispatcher guarantees AVX2 is present on this host.
        unsafe { axpy_avx2_impl(s, src, dst) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_avx2_impl(s: f32, src: &[f32], dst: &mut [f32]) {
        let n = src.len().min(dst.len());
        let sv = _mm256_set1_ps(s);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(src.as_ptr().add(j));
            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
            // mul then add, NOT fma: bitwise parity with the scalar axpy.
            _mm256_storeu_ps(
                dst.as_mut_ptr().add(j),
                _mm256_add_ps(d, _mm256_mul_ps(sv, x)),
            );
            j += 8;
        }
        for jj in j..n {
            dst[jj] += s * src[jj];
        }
    }

    /// AVX2 micro-kernel: 4 rows × 2 × 256-bit lanes. Safe wrapper — only
    /// ever selected after `is_x86_feature_detected!("avx2")`.
    pub(super) fn micro_avx2(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
        // SAFETY: the dispatcher guarantees AVX2 is present on this host.
        unsafe { micro_avx2_impl(pa, pb, acc, kb) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn micro_avx2_impl(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
        debug_assert!(pa.len() >= kb * MR && pb.len() >= kb * NR);
        // Load the 4×16 accumulator tile into 8 ymm registers.
        let mut c00 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c01 = _mm256_loadu_ps(acc[0].as_ptr().add(8));
        let mut c10 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c11 = _mm256_loadu_ps(acc[1].as_ptr().add(8));
        let mut c20 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c21 = _mm256_loadu_ps(acc[2].as_ptr().add(8));
        let mut c30 = _mm256_loadu_ps(acc[3].as_ptr());
        let mut c31 = _mm256_loadu_ps(acc[3].as_ptr().add(8));
        let mut ap = pa.as_ptr();
        let mut bp = pb.as_ptr();
        for _ in 0..kb {
            let b0 = _mm256_loadu_ps(bp);
            let b1 = _mm256_loadu_ps(bp.add(8));
            // mul then add, NOT fma: keeps each lane's arithmetic
            // identical to the scalar kernel's `acc += a * b`.
            let a0 = _mm256_broadcast_ss(&*ap);
            c00 = _mm256_add_ps(c00, _mm256_mul_ps(a0, b0));
            c01 = _mm256_add_ps(c01, _mm256_mul_ps(a0, b1));
            let a1 = _mm256_broadcast_ss(&*ap.add(1));
            c10 = _mm256_add_ps(c10, _mm256_mul_ps(a1, b0));
            c11 = _mm256_add_ps(c11, _mm256_mul_ps(a1, b1));
            let a2 = _mm256_broadcast_ss(&*ap.add(2));
            c20 = _mm256_add_ps(c20, _mm256_mul_ps(a2, b0));
            c21 = _mm256_add_ps(c21, _mm256_mul_ps(a2, b1));
            let a3 = _mm256_broadcast_ss(&*ap.add(3));
            c30 = _mm256_add_ps(c30, _mm256_mul_ps(a3, b0));
            c31 = _mm256_add_ps(c31, _mm256_mul_ps(a3, b1));
            ap = ap.add(MR);
            bp = bp.add(NR);
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c00);
        _mm256_storeu_ps(acc[0].as_mut_ptr().add(8), c01);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c10);
        _mm256_storeu_ps(acc[1].as_mut_ptr().add(8), c11);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c20);
        _mm256_storeu_ps(acc[2].as_mut_ptr().add(8), c21);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c30);
        _mm256_storeu_ps(acc[3].as_mut_ptr().add(8), c31);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// NEON axpy: 4 lanes per step, scalar tail. NEON is part of the
    /// aarch64 baseline, so no runtime detection is needed.
    pub(super) fn axpy_neon(s: f32, src: &[f32], dst: &mut [f32]) {
        // SAFETY: NEON is mandatory on aarch64.
        unsafe { axpy_neon_impl(s, src, dst) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_neon_impl(s: f32, src: &[f32], dst: &mut [f32]) {
        let n = src.len().min(dst.len());
        let sv = vdupq_n_f32(s);
        let mut j = 0;
        while j + 4 <= n {
            let x = vld1q_f32(src.as_ptr().add(j));
            let d = vld1q_f32(dst.as_ptr().add(j));
            // mul then add, NOT vfmaq: bitwise parity with scalar.
            vst1q_f32(dst.as_mut_ptr().add(j), vaddq_f32(d, vmulq_f32(sv, x)));
            j += 4;
        }
        for jj in j..n {
            dst[jj] += s * src[jj];
        }
    }

    /// NEON micro-kernel: 4 rows × 4 × 128-bit lanes. NEON is part of the
    /// aarch64 baseline, so no runtime detection is needed.
    pub(super) fn micro_neon(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
        // SAFETY: NEON is mandatory on aarch64.
        unsafe { micro_neon_impl(pa, pb, acc, kb) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn micro_neon_impl(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], kb: usize) {
        debug_assert!(pa.len() >= kb * MR && pb.len() >= kb * NR);
        // 4 rows × 4 quads = 16 accumulator registers.
        let mut c: [[float32x4_t; 4]; MR] = [
            [
                vld1q_f32(acc[0].as_ptr()),
                vld1q_f32(acc[0].as_ptr().add(4)),
                vld1q_f32(acc[0].as_ptr().add(8)),
                vld1q_f32(acc[0].as_ptr().add(12)),
            ],
            [
                vld1q_f32(acc[1].as_ptr()),
                vld1q_f32(acc[1].as_ptr().add(4)),
                vld1q_f32(acc[1].as_ptr().add(8)),
                vld1q_f32(acc[1].as_ptr().add(12)),
            ],
            [
                vld1q_f32(acc[2].as_ptr()),
                vld1q_f32(acc[2].as_ptr().add(4)),
                vld1q_f32(acc[2].as_ptr().add(8)),
                vld1q_f32(acc[2].as_ptr().add(12)),
            ],
            [
                vld1q_f32(acc[3].as_ptr()),
                vld1q_f32(acc[3].as_ptr().add(4)),
                vld1q_f32(acc[3].as_ptr().add(8)),
                vld1q_f32(acc[3].as_ptr().add(12)),
            ],
        ];
        let mut ap = pa.as_ptr();
        let mut bp = pb.as_ptr();
        for _ in 0..kb {
            let b = [
                vld1q_f32(bp),
                vld1q_f32(bp.add(4)),
                vld1q_f32(bp.add(8)),
                vld1q_f32(bp.add(12)),
            ];
            for (r, crow) in c.iter_mut().enumerate() {
                // mul then add, NOT vfmaq: bitwise parity with scalar.
                let av = vdupq_n_f32(*ap.add(r));
                crow[0] = vaddq_f32(crow[0], vmulq_f32(av, b[0]));
                crow[1] = vaddq_f32(crow[1], vmulq_f32(av, b[1]));
                crow[2] = vaddq_f32(crow[2], vmulq_f32(av, b[2]));
                crow[3] = vaddq_f32(crow[3], vmulq_f32(av, b[3]));
            }
            ap = ap.add(MR);
            bp = bp.add(NR);
        }
        for (r, crow) in c.iter().enumerate() {
            vst1q_f32(acc[r].as_mut_ptr(), crow[0]);
            vst1q_f32(acc[r].as_mut_ptr().add(4), crow[1]);
            vst1q_f32(acc[r].as_mut_ptr().add(8), crow[2]);
            vst1q_f32(acc[r].as_mut_ptr().add(12), crow[3]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn run_kernel(kern: Kernel, kb: usize, seed: u64) -> [[f32; NR]; MR] {
        let mut rng = Rng::new(seed);
        let pa: Vec<f32> = (0..kb * MR).map(|_| rng.normal_f32()).collect();
        let pb: Vec<f32> = (0..kb * NR).map(|_| rng.normal_f32()).collect();
        let mut acc = [[0.0f32; NR]; MR];
        kern.run(&pa, &pb, &mut acc, kb);
        acc
    }

    #[test]
    fn active_matches_scalar_bitwise_on_tiles() {
        // The dispatch contract at the tile level, for awkward kb values
        // (1, primes, larger than one cache line of k).
        for &kb in &[1usize, 2, 3, 7, 16, 33, 256] {
            let scalar = run_kernel(Kernel::scalar(), kb, 42 + kb as u64);
            let active = run_kernel(Kernel::active(), kb, 42 + kb as u64);
            for r in 0..MR {
                assert_eq!(
                    scalar[r].map(f32::to_bits),
                    active[r].map(f32::to_bits),
                    "kernel {} diverged from scalar at kb={kb} row={r}",
                    Kernel::active().name()
                );
            }
        }
    }

    #[test]
    fn accumulates_on_top_of_existing_tile() {
        let mut rng = Rng::new(7);
        let kb = 5;
        let pa: Vec<f32> = (0..kb * MR).map(|_| rng.normal_f32()).collect();
        let pb: Vec<f32> = (0..kb * NR).map(|_| rng.normal_f32()).collect();
        // Both implementations must *load* the incoming tile (not assume
        // zeros): start from the same non-zero acc and compare bitwise.
        let mut via_scalar = [[1.0f32; NR]; MR];
        Kernel::scalar().run(&pa, &pb, &mut via_scalar, kb);
        let mut via_active = [[1.0f32; NR]; MR];
        Kernel::active().run(&pa, &pb, &mut via_active, kb);
        for r in 0..MR {
            assert_eq!(
                via_scalar[r].map(f32::to_bits),
                via_active[r].map(f32::to_bits),
                "row {r}"
            );
            // And the base actually contributed (approximately +1.0).
            let mut from_zero = [[0.0f32; NR]; MR];
            Kernel::scalar().run(&pa, &pb, &mut from_zero, kb);
            for j in 0..NR {
                assert!((via_scalar[r][j] - 1.0 - from_zero[r][j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn axpy_active_matches_scalar_bitwise() {
        // Ragged lengths straddling the 4- and 8-lane SIMD widths, plus
        // zero-length and a scale of exactly 0.0 (must still execute the
        // mul+add per element: -0.0 inputs make 0.0*x sign-sensitive).
        let mut rng = Rng::new(71);
        for &len in &[0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 64, 130] {
            for &s in &[0.0f32, 1.0, -0.75, 3.5e-3] {
                let src: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let base: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let mut via_scalar = base.clone();
                Kernel::scalar().axpy(s, &src, &mut via_scalar);
                let mut via_active = base.clone();
                Kernel::active().axpy(s, &src, &mut via_active);
                assert_eq!(
                    via_scalar.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    via_active.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "axpy kernel {} diverged at len={len} s={s}",
                    Kernel::active().name()
                );
            }
        }
    }

    #[test]
    fn force_scalar_pins_dispatch() {
        // Meaningful in the CI leg that exports SALR_FORCE_SCALAR=1; a
        // no-op assertion otherwise (dispatch may legitimately be SIMD).
        if matches!(
            std::env::var("SALR_FORCE_SCALAR").as_deref(),
            Ok("1") | Ok("true")
        ) {
            assert!(force_scalar());
            assert_eq!(Kernel::active().name(), "scalar");
        }
        assert_eq!(Kernel::scalar().name(), "scalar");
    }
}
