//! Sequential bitmap-decode-then-GEMM: the naive deployment of bitmap
//! weights (decode everything, then multiply). The two-stage pipeline in
//! [`super::pipeline`] overlaps the same two phases.
//!
//! All scratch (decode targets, transposed X/C working sets) is borrowed
//! from the executing thread's arena ([`crate::util::arena`]) — callers
//! pass no buffers, and steady-state calls perform no heap allocation.

use crate::gemm::dense;
use crate::sparse::BitmapMatrix;
use crate::util::arena::{scratch_f32, scratch_undef};
use crate::util::pool::{SendPtr, WorkerPool};

/// `C[m,n] = X[m,k] @ W[k,n]` where `W` is bitmap-encoded.
/// Fully decodes `W` into arena scratch first (sequential baseline);
/// the dense multiply runs on the process-global pool.
pub fn bitmap_gemm_sequential(x: &[f32], w: &BitmapMatrix, c: &mut [f32], m: usize) {
    bitmap_gemm_sequential_pool(x, w, c, m, &WorkerPool::global());
}

/// [`bitmap_gemm_sequential`] with an explicit pool for the dense multiply
/// — pass a 1-thread pool for a genuinely sequential ablation baseline.
pub fn bitmap_gemm_sequential_pool(
    x: &[f32],
    w: &BitmapMatrix,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
) {
    let (k, n) = (w.rows(), w.cols());
    // Decode overwrites every element (zeros included), so the scratch
    // needs no pre-clearing.
    let mut scratch = scratch_undef(k * n);
    w.decode_rows_into(0, k, &mut scratch);
    dense::gemm_f32_pool(x, &scratch, c, m, k, n, pool);
}

/// Panel-streamed variant: decode a K-panel of `W`, multiply, move on —
/// same total work but bounded scratch (`panel_k × n`), no overlap.
pub fn bitmap_gemm_panelled(x: &[f32], w: &BitmapMatrix, c: &mut [f32], m: usize, panel_k: usize) {
    let (k, n) = (w.rows(), w.cols());
    c[..m * n].fill(0.0);
    let mut scratch = scratch_undef(panel_k * n);
    let mut p0 = 0;
    while p0 < k {
        let p1 = (p0 + panel_k).min(k);
        let kb = p1 - p0;
        w.decode_rows_into(p0, p1, &mut scratch);
        // C += X[:, p0..p1] @ panel — strided A access via a gathered copy.
        panel_acc(x, &scratch[..kb * n], c, m, k, n, p0, kb);
        p0 = p1;
    }
}

/// Direct sparse GEMM: `C[m,n] = X[m,k] @ W` touching only the nonzero
/// weights (≈ nnz·m MACs instead of k·n·m) — never materializes a dense
/// panel. This is the decode-batch hot path of the native engine: at the
/// small m of autoregressive decode it beats the dense GEMM because it
/// does `(1−p)` of the multiply-adds *and* `(1−p)` of the weight traffic.
///
/// Internally works on transposed X/C arena scratch so the m-loop is
/// contiguous and vectorizes.
pub fn bitmap_gemm_direct(x: &[f32], w: &BitmapMatrix, c: &mut [f32], m: usize) {
    let (k, n) = (w.rows(), w.cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    if m == 0 {
        return;
    }
    // xT is fully overwritten by the transpose; cT accumulates, so it
    // must start zeroed.
    let mut xt = scratch_undef(k * m);
    let mut ct = scratch_f32(n * m);
    for i in 0..m {
        for p in 0..k {
            xt[p * m + i] = x[i * k + p];
        }
    }
    let masks = w.masks();
    let values = w.values();
    let bpr = w.bytes_per_row();
    let mut voff = 0usize;
    for p in 0..k {
        let xcol = &xt[p * m..(p + 1) * m];
        let row_masks = &masks[p * bpr..(p + 1) * bpr];
        for (b, &mask) in row_masks.iter().enumerate() {
            let mut mbits = mask;
            while mbits != 0 {
                let t = mbits.trailing_zeros() as usize;
                let j = b * 8 + t;
                let v = values[voff];
                voff += 1;
                let crow = &mut ct[j * m..(j + 1) * m];
                for i in 0..m {
                    crow[i] += xcol[i] * v;
                }
                mbits &= mbits - 1;
            }
        }
    }
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = ct[j * m + i];
        }
    }
}

/// [`bitmap_gemm_direct`] parallelized over **column stripes** on the
/// caller's pool — the decode-batch hot path of the serving engine.
///
/// Each stripe task owns a disjoint byte-block range of W's columns (and
/// therefore disjoint columns of the transposed C scratch): it walks every
/// weight row, skips the value prefix belonging to earlier stripes via
/// mask popcounts, and accumulates only its own columns. Because a given
/// output column receives its terms in ascending weight-row order no
/// matter how many stripes run, the result is **bitwise identical** to
/// the single-threaded kernel at every pool width. The transposed
/// working set lives in the calling thread's arena; stripe tasks borrow
/// it and allocate nothing.
pub fn bitmap_gemm_direct_pool(
    x: &[f32],
    w: &BitmapMatrix,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
) {
    let (k, n) = (w.rows(), w.cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    if m == 0 || n == 0 {
        return;
    }
    let bpr = w.bytes_per_row();
    let stripes = pool.threads().min(bpr);
    if stripes <= 1 || k == 0 {
        return bitmap_gemm_direct(x, w, c, m);
    }
    // Transposed so the m-loop is contiguous — same layout as the serial
    // kernel. xT fully overwritten; cT accumulates from zero.
    let mut xt = scratch_undef(k * m);
    let mut ct = scratch_f32(n * m);
    for i in 0..m {
        for p in 0..k {
            xt[p * m + i] = x[i * k + p];
        }
    }
    {
        let xt = &*xt;
        let masks = w.masks();
        let values = w.values();
        let offs = w.row_offsets();
        let cptr = SendPtr(ct.as_mut_ptr());
        pool.run(stripes, &|s| {
            // Stripe `s` owns byte blocks [b0, b1) → columns [b0*8, b1*8).
            let b0 = s * bpr / stripes;
            let b1 = (s + 1) * bpr / stripes;
            for p in 0..k {
                let xcol = &xt[p * m..(p + 1) * m];
                let row_masks = &masks[p * bpr..(p + 1) * bpr];
                // Skip this row's values that belong to earlier stripes.
                let mut voff = offs[p] as usize;
                for &mask in &row_masks[..b0] {
                    voff += mask.count_ones() as usize;
                }
                for (b, &mask) in row_masks.iter().enumerate().take(b1).skip(b0) {
                    let mut mbits = mask;
                    while mbits != 0 {
                        let t = mbits.trailing_zeros() as usize;
                        let j = b * 8 + t;
                        let v = values[voff];
                        voff += 1;
                        // SAFETY: stripe `s` exclusively owns cT columns
                        // [b0*8, b1*8), and j lies in that range.
                        let crow =
                            unsafe { std::slice::from_raw_parts_mut(cptr.0.add(j * m), m) };
                        for i in 0..m {
                            crow[i] += xcol[i] * v;
                        }
                        mbits &= mbits - 1;
                    }
                }
            }
        });
    }
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = ct[j * m + i];
        }
    }
}

/// `C += X[:, p0..p0+kb] @ P[kb, n]` with X row-major `m × k`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn panel_acc(
    x: &[f32],
    panel: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    kb: usize,
) {
    assert!(c.len() >= m * n);
    // SAFETY: `c` covers m*n elements and we hold the only reference.
    unsafe { panel_acc_stripe(x, panel, c.as_mut_ptr(), m, k, n, p0, kb, 0, n) }
}

/// Column-stripe form of [`panel_acc`]: `C[:, j0..j1] += X[:, p0..p0+kb] @
/// P[kb, n][:, j0..j1]`, writing through a raw base pointer. The pipeline's
/// parallel consumers each own a disjoint stripe of C columns, so their
/// writes never race; the per-element accumulation order is identical to
/// the full-width version, which keeps results bitwise independent of the
/// stripe count.
///
/// # Safety
/// `c` must point to an `m*n` f32 buffer, and no other thread may access
/// columns `[j0, j1)` of it concurrently.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn panel_acc_stripe(
    x: &[f32],
    panel: &[f32],
    c: *mut f32,
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    kb: usize,
    j0: usize,
    j1: usize,
) {
    for i in 0..m {
        let xrow = &x[i * k + p0..i * k + p0 + kb];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let prow = &panel[p * n + j0..p * n + j1];
            let crow = c.add(i * n + j0);
            for (jj, &pv) in prow.iter().enumerate() {
                *crow.add(jj) += xv * pv;
            }
        }
    }
}

/// `C[:, j0..j1] += U[m, r] @ B[r, n][:, j0..j1]` through a raw base
/// pointer — the adapter-update stripe applied by each pipeline consumer
/// before it starts consuming panels.
///
/// # Safety
/// Same contract as [`panel_acc_stripe`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn addmul_stripe(
    u: &[f32],
    bmat: &[f32],
    c: *mut f32,
    m: usize,
    r: usize,
    n: usize,
    j0: usize,
    j1: usize,
) {
    for i in 0..m {
        let urow = &u[i * r..(i + 1) * r];
        for (p, &uv) in urow.iter().enumerate() {
            if uv == 0.0 {
                continue;
            }
            let brow = &bmat[p * n + j0..p * n + j1];
            let crow = c.add(i * n + j0);
            for (jj, &bv) in brow.iter().enumerate() {
                *crow.add(jj) += uv * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::tensor::{matmul_naive, max_abs_diff, Tensor};
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng, m: usize, k: usize, n: usize) -> (Tensor, Tensor, BitmapMatrix) {
        let x = Tensor::randn(&[m, k], 1.0, rng);
        let mut w = Tensor::randn(&[k, n], 1.0, rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        (x, w, bm)
    }

    #[test]
    fn sequential_matches_dense() {
        let mut rng = Rng::new(110);
        let (x, w, bm) = setup(&mut rng, 9, 64, 33);
        let want = matmul_naive(&x, &w);
        let mut c = vec![0.0f32; 9 * 33];
        bitmap_gemm_sequential(x.data(), &bm, &mut c, 9);
        let c = Tensor::from_vec(&[9, 33], c);
        assert!(max_abs_diff(&c, &want) < 1e-3);
    }

    #[test]
    fn direct_matches_dense() {
        let mut rng = Rng::new(112);
        for &(m, k, n, p) in &[
            (1usize, 64usize, 48usize, 0.5f64),
            (8, 128, 96, 0.5),
            (16, 100, 33, 0.9),
            (3, 17, 8, 0.0),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            crate::prune::prune_global(&mut [&mut w], p);
            let bm = BitmapMatrix::encode(&w);
            let want = matmul_naive(&x, &w);
            let mut c = vec![0.0f32; m * n];
            bitmap_gemm_direct(x.data(), &bm, &mut c, m);
            let c = Tensor::from_vec(&[m, n], c);
            assert!(max_abs_diff(&c, &want) < 1e-3, "({m},{k},{n},{p})");
        }
    }

    #[test]
    fn direct_pool_is_bitwise_identical_to_serial() {
        // Column-striped parallel direct GEMM: same bits as the serial
        // kernel at every pool width (each column accumulates in ascending
        // weight-row order regardless of the stripe count), including
        // ragged column counts that don't align to byte blocks.
        let mut rng = Rng::new(113);
        for &(m, k, n, p) in &[
            (1usize, 64usize, 48usize, 0.5f64),
            (4, 96, 33, 0.5),
            (8, 50, 7, 0.9),
            (2, 40, 100, 0.0),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            crate::prune::prune_global(&mut [&mut w], p);
            let bm = BitmapMatrix::encode(&w);
            let mut serial = vec![0.0f32; m * n];
            bitmap_gemm_direct(x.data(), &bm, &mut serial, m);
            for threads in [1usize, 2, 3, 8] {
                let pool = WorkerPool::new(threads);
                let mut c = vec![0.0f32; m * n];
                bitmap_gemm_direct_pool(x.data(), &bm, &mut c, m, &pool);
                assert_eq!(c, serial, "({m},{k},{n},{p}) threads={threads}");
            }
            let want = matmul_naive(&x, &w);
            let c = Tensor::from_vec(&[m, n], serial);
            assert!(max_abs_diff(&c, &want) < 1e-3, "({m},{k},{n},{p})");
        }
    }

    #[test]
    fn direct_steady_state_does_not_allocate() {
        // The decode hot path's acceptance bar: after one warmup call the
        // transposed working set is arena-resident and repeated calls do
        // not move the thread's allocation counter.
        let mut rng = Rng::new(114);
        let (x, _w, bm) = setup(&mut rng, 4, 96, 64);
        let mut c = vec![0.0f32; 4 * 64];
        bitmap_gemm_direct(x.data(), &bm, &mut c, 4);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            bitmap_gemm_direct(x.data(), &bm, &mut c, 4);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "bitmap_gemm_direct allocated in steady state"
        );
    }

    #[test]
    fn panelled_matches_dense_various_panels() {
        let mut rng = Rng::new(111);
        let (x, w, bm) = setup(&mut rng, 7, 100, 25);
        let want = matmul_naive(&x, &w);
        for &panel in &[1usize, 8, 33, 100, 200] {
            let mut c = vec![0.0f32; 7 * 25];
            bitmap_gemm_panelled(x.data(), &bm, &mut c, 7, panel);
            let c = Tensor::from_vec(&[7, 25], c);
            assert!(max_abs_diff(&c, &want) < 1e-3, "panel={panel}");
        }
    }
}
