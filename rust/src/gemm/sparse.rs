//! Direct sparse GEMM kernels over compressed weight operands.
//!
//! The decode-to-dense-scratch layer that used to live here
//! (`bitmap_gemm_sequential` / `bitmap_gemm_panelled`) is gone: batch
//! GEMMs over compressed weights now decode inside the blocked GEMM's
//! panel pack step ([`super::dense::PackB`]), so the only kernels left in
//! this module are the ones that never materialize dense weights at all:
//!
//! * [`sparse_gemm_direct`] / [`sparse_gemm_direct_pool`] — the small-m
//!   decode-batch hot path, walking the bitmap directly (≈ nnz·m MACs);
//!   generic over a [`SparseSource`], so the bitmap+NF4 store runs the
//!   same kernel with per-element LUT dequantization.
//! * [`panel_acc`] / `panel_acc_stripe` / `addmul_stripe` — the pipeline
//!   consumers' column-stripe accumulators, with a zero-skip outer loop
//!   and a dispatched SIMD axpy ([`crate::gemm::kernel::Kernel::axpy`])
//!   inner loop.
//!
//! All scratch (transposed X/C working sets) is borrowed from the
//! executing thread's arena ([`crate::util::arena`]) — callers pass no
//! buffers, and steady-state calls perform no heap allocation.

use crate::gemm::kernel::Kernel;
use crate::quant::SparseNf4Matrix;
use crate::sparse::BitmapMatrix;
use crate::util::arena::{scratch_f32, scratch_undef};
use crate::util::pool::{SendPtr, WorkerPool};

/// A bitmap-masked sparse operand the direct kernels can walk without
/// decoding: the mask layout of [`BitmapMatrix`] plus random access into
/// the row-major nonzero stream. `value(voff)` is the only place the two
/// compressed formats differ — a stored f32 for the bitmap format, a
/// LUT-dequantized NF4 code for the quantized one — so every walk order
/// (and therefore every accumulation order) is shared, which keeps the
/// parallel kernels bitwise identical across formats' code paths.
pub trait SparseSource: Sync {
    /// Weight rows (the GEMM's `k`).
    fn rows(&self) -> usize;
    /// Weight columns (the GEMM's `n`).
    fn cols(&self) -> usize;
    /// Byte-blocked bitmap, `bytes_per_row` per row.
    fn masks(&self) -> &[u8];
    /// Per-row offsets into the nonzero stream (len = rows + 1).
    fn row_offsets(&self) -> &[u32];
    /// `ceil(cols / 8)`.
    fn bytes_per_row(&self) -> usize;
    /// The `voff`-th nonzero of the row-major stream.
    fn value(&self, voff: usize) -> f32;
}

impl SparseSource for BitmapMatrix {
    fn rows(&self) -> usize {
        BitmapMatrix::rows(self)
    }

    fn cols(&self) -> usize {
        BitmapMatrix::cols(self)
    }

    fn masks(&self) -> &[u8] {
        BitmapMatrix::masks(self)
    }

    fn row_offsets(&self) -> &[u32] {
        BitmapMatrix::row_offsets(self)
    }

    fn bytes_per_row(&self) -> usize {
        BitmapMatrix::bytes_per_row(self)
    }

    #[inline]
    fn value(&self, voff: usize) -> f32 {
        self.values()[voff]
    }
}

impl SparseSource for SparseNf4Matrix {
    fn rows(&self) -> usize {
        SparseNf4Matrix::rows(self)
    }

    fn cols(&self) -> usize {
        SparseNf4Matrix::cols(self)
    }

    fn masks(&self) -> &[u8] {
        SparseNf4Matrix::masks(self)
    }

    fn row_offsets(&self) -> &[u32] {
        SparseNf4Matrix::row_offsets(self)
    }

    fn bytes_per_row(&self) -> usize {
        SparseNf4Matrix::bytes_per_row(self)
    }

    #[inline]
    fn value(&self, voff: usize) -> f32 {
        SparseNf4Matrix::value(self, voff)
    }
}

/// Direct sparse GEMM: `C[m,n] = X[m,k] @ W` touching only the nonzero
/// weights (≈ nnz·m MACs instead of k·n·m) — never materializes a dense
/// panel. This is the decode-batch hot path of the native engine: at the
/// small m of autoregressive decode it beats the dense GEMM because it
/// does `(1−p)` of the multiply-adds *and* `(1−p)` of the weight traffic.
///
/// Internally works on transposed X/C arena scratch so the m-loop is
/// contiguous and vectorizes.
pub fn sparse_gemm_direct<S: SparseSource + ?Sized>(x: &[f32], w: &S, c: &mut [f32], m: usize) {
    let (k, n) = (w.rows(), w.cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    if m == 0 {
        return;
    }
    // xT is fully overwritten by the transpose; cT accumulates, so it
    // must start zeroed.
    let mut xt = scratch_undef(k * m);
    let mut ct = scratch_f32(n * m);
    for i in 0..m {
        for p in 0..k {
            xt[p * m + i] = x[i * k + p];
        }
    }
    let masks = w.masks();
    let bpr = w.bytes_per_row();
    let mut voff = 0usize;
    for p in 0..k {
        let xcol = &xt[p * m..(p + 1) * m];
        let row_masks = &masks[p * bpr..(p + 1) * bpr];
        for (b, &mask) in row_masks.iter().enumerate() {
            let mut mbits = mask;
            while mbits != 0 {
                let t = mbits.trailing_zeros() as usize;
                let j = b * 8 + t;
                let v = w.value(voff);
                voff += 1;
                let crow = &mut ct[j * m..(j + 1) * m];
                for i in 0..m {
                    crow[i] += xcol[i] * v;
                }
                mbits &= mbits - 1;
            }
        }
    }
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = ct[j * m + i];
        }
    }
}

/// [`sparse_gemm_direct`] parallelized over **column stripes** on the
/// caller's pool — the decode-batch hot path of the serving engine.
///
/// Each stripe task owns a disjoint byte-block range of W's columns (and
/// therefore disjoint columns of the transposed C scratch): it walks every
/// weight row, skips the value prefix belonging to earlier stripes via
/// mask popcounts, and accumulates only its own columns. Because a given
/// output column receives its terms in ascending weight-row order no
/// matter how many stripes run, the result is **bitwise identical** to
/// the single-threaded kernel at every pool width. The transposed
/// working set lives in the calling thread's arena; stripe tasks borrow
/// it and allocate nothing.
pub fn sparse_gemm_direct_pool<S: SparseSource + ?Sized>(
    x: &[f32],
    w: &S,
    c: &mut [f32],
    m: usize,
    pool: &WorkerPool,
) {
    let (k, n) = (w.rows(), w.cols());
    assert!(x.len() >= m * k && c.len() >= m * n);
    if m == 0 || n == 0 {
        return;
    }
    let bpr = w.bytes_per_row();
    let stripes = pool.threads().min(bpr);
    if stripes <= 1 || k == 0 {
        return sparse_gemm_direct(x, w, c, m);
    }
    // Transposed so the m-loop is contiguous — same layout as the serial
    // kernel. xT fully overwritten; cT accumulates from zero.
    let mut xt = scratch_undef(k * m);
    let mut ct = scratch_f32(n * m);
    for i in 0..m {
        for p in 0..k {
            xt[p * m + i] = x[i * k + p];
        }
    }
    {
        let xt = &*xt;
        let masks = w.masks();
        let offs = w.row_offsets();
        let cptr = SendPtr(ct.as_mut_ptr());
        pool.run(stripes, &|s| {
            // Stripe `s` owns byte blocks [b0, b1) → columns [b0*8, b1*8).
            let b0 = s * bpr / stripes;
            let b1 = (s + 1) * bpr / stripes;
            for p in 0..k {
                let xcol = &xt[p * m..(p + 1) * m];
                let row_masks = &masks[p * bpr..(p + 1) * bpr];
                // Skip this row's values that belong to earlier stripes.
                let mut voff = offs[p] as usize;
                for &mask in &row_masks[..b0] {
                    voff += mask.count_ones() as usize;
                }
                for (b, &mask) in row_masks.iter().enumerate().take(b1).skip(b0) {
                    let mut mbits = mask;
                    while mbits != 0 {
                        let t = mbits.trailing_zeros() as usize;
                        let j = b * 8 + t;
                        let v = w.value(voff);
                        voff += 1;
                        // SAFETY: stripe `s` exclusively owns cT columns
                        // [b0*8, b1*8), and j lies in that range.
                        let crow =
                            unsafe { std::slice::from_raw_parts_mut(cptr.0.add(j * m), m) };
                        for i in 0..m {
                            crow[i] += xcol[i] * v;
                        }
                        mbits &= mbits - 1;
                    }
                }
            }
        });
    }
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = ct[j * m + i];
        }
    }
}

/// `C += X[:, p0..p0+kb] @ P[kb, n]` with X row-major `m × k`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn panel_acc(
    x: &[f32],
    panel: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    kb: usize,
) {
    assert!(c.len() >= m * n);
    // SAFETY: `c` covers m*n elements and we hold the only reference.
    unsafe { panel_acc_stripe(x, panel, c.as_mut_ptr(), m, k, n, p0, kb, 0, n) }
}

/// Column-stripe form of [`panel_acc`]: `C[:, j0..j1] += X[:, p0..p0+kb] @
/// P[kb, n][:, j0..j1]`, writing through a raw base pointer. The pipeline's
/// parallel consumers each own a disjoint stripe of C columns, so their
/// writes never race; the per-element accumulation order is identical to
/// the full-width version, which keeps results bitwise independent of the
/// stripe count.
///
/// The outer loops keep the zero-skip (an activation of exactly 0.0
/// contributes no term — `0.0 + c == c` for every finite c the panels
/// produce); the contiguous inner loop runs the dispatched SIMD axpy,
/// which performs the identical per-element mul-then-add in the identical
/// order, so SIMD dispatch never changes a bit.
///
/// # Safety
/// `c` must point to an `m*n` f32 buffer, and no other thread may access
/// columns `[j0, j1)` of it concurrently.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn panel_acc_stripe(
    x: &[f32],
    panel: &[f32],
    c: *mut f32,
    m: usize,
    k: usize,
    n: usize,
    p0: usize,
    kb: usize,
    j0: usize,
    j1: usize,
) {
    let kern = Kernel::active();
    for i in 0..m {
        let xrow = &x[i * k + p0..i * k + p0 + kb];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let prow = &panel[p * n + j0..p * n + j1];
            let crow = std::slice::from_raw_parts_mut(c.add(i * n + j0), j1 - j0);
            kern.axpy(xv, prow, crow);
        }
    }
}

/// `C[:, j0..j1] += U[m, r] @ B[r, n][:, j0..j1]` through a raw base
/// pointer — the adapter-update stripe applied by each pipeline consumer
/// before it starts consuming panels. Zero-skip outer loops, dispatched
/// SIMD axpy inner loop (same bitwise-identity argument as
/// [`panel_acc_stripe`]).
///
/// # Safety
/// Same contract as [`panel_acc_stripe`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn addmul_stripe(
    u: &[f32],
    bmat: &[f32],
    c: *mut f32,
    m: usize,
    r: usize,
    n: usize,
    j0: usize,
    j1: usize,
) {
    let kern = Kernel::active();
    for i in 0..m {
        let urow = &u[i * r..(i + 1) * r];
        for (p, &uv) in urow.iter().enumerate() {
            if uv == 0.0 {
                continue;
            }
            let brow = &bmat[p * n + j0..p * n + j1];
            let crow = std::slice::from_raw_parts_mut(c.add(i * n + j0), j1 - j0);
            kern.axpy(uv, brow, crow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::prune_global;
    use crate::tensor::{matmul_naive, max_abs_diff, Tensor};
    use crate::util::rng::Rng;

    fn setup(rng: &mut Rng, m: usize, k: usize, n: usize) -> (Tensor, Tensor, BitmapMatrix) {
        let x = Tensor::randn(&[m, k], 1.0, rng);
        let mut w = Tensor::randn(&[k, n], 1.0, rng);
        prune_global(&mut [&mut w], 0.5);
        let bm = BitmapMatrix::encode(&w);
        (x, w, bm)
    }

    #[test]
    fn direct_matches_dense() {
        let mut rng = Rng::new(112);
        for &(m, k, n, p) in &[
            (1usize, 64usize, 48usize, 0.5f64),
            (8, 128, 96, 0.5),
            (16, 100, 33, 0.9),
            (3, 17, 8, 0.0),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            crate::prune::prune_global(&mut [&mut w], p);
            let bm = BitmapMatrix::encode(&w);
            let want = matmul_naive(&x, &w);
            let mut c = vec![0.0f32; m * n];
            sparse_gemm_direct(x.data(), &bm, &mut c, m);
            let c = Tensor::from_vec(&[m, n], c);
            assert!(max_abs_diff(&c, &want) < 1e-3, "({m},{k},{n},{p})");
        }
    }

    #[test]
    fn direct_pool_is_bitwise_identical_to_serial() {
        // Column-striped parallel direct GEMM: same bits as the serial
        // kernel at every pool width (each column accumulates in ascending
        // weight-row order regardless of the stripe count), including
        // ragged column counts that don't align to byte blocks — for both
        // compressed formats.
        let mut rng = Rng::new(113);
        for &(m, k, n, p) in &[
            (1usize, 64usize, 48usize, 0.5f64),
            (4, 96, 33, 0.5),
            (8, 50, 7, 0.9),
            (2, 40, 100, 0.0),
        ] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
            crate::prune::prune_global(&mut [&mut w], p);
            let bm = BitmapMatrix::encode(&w);
            let snf = SparseNf4Matrix::from_bitmap(&bm, 64);
            let mut serial = vec![0.0f32; m * n];
            sparse_gemm_direct(x.data(), &bm, &mut serial, m);
            let mut serial_nf = vec![0.0f32; m * n];
            sparse_gemm_direct(x.data(), &snf, &mut serial_nf, m);
            for threads in [1usize, 2, 3, 8] {
                let pool = WorkerPool::new(threads);
                let mut c = vec![0.0f32; m * n];
                sparse_gemm_direct_pool(x.data(), &bm, &mut c, m, &pool);
                assert_eq!(c, serial, "({m},{k},{n},{p}) threads={threads}");
                let mut cn = vec![0.0f32; m * n];
                sparse_gemm_direct_pool(x.data(), &snf, &mut cn, m, &pool);
                assert_eq!(cn, serial_nf, "nf4 ({m},{k},{n},{p}) threads={threads}");
            }
            let want = matmul_naive(&x, &w);
            let c = Tensor::from_vec(&[m, n], serial);
            assert!(max_abs_diff(&c, &want) < 1e-3, "({m},{k},{n},{p})");
        }
    }

    #[test]
    fn direct_nf4_matches_dequantize_then_dense_oracle() {
        // The NF4 direct walk dequantizes per element inside the kernel;
        // run the same kernel on a bitmap re-encoding of the dequantized
        // matrix (the decode-then-GEMM form) and the bits must match,
        // since both see the identical f32 stream in identical order.
        let mut rng = Rng::new(115);
        let (m, k, n) = (5usize, 80usize, 37usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut w = Tensor::randn(&[k, n], 1.0, &mut rng);
        prune_global(&mut [&mut w], 0.5);
        let snf = SparseNf4Matrix::encode(&w, 64);
        let dq = snf.decode();
        let bm_of_dq = BitmapMatrix::encode(&dq);
        let mut via_nf4 = vec![0.0f32; m * n];
        sparse_gemm_direct(x.data(), &snf, &mut via_nf4, m);
        let mut via_bitmap = vec![0.0f32; m * n];
        sparse_gemm_direct(x.data(), &bm_of_dq, &mut via_bitmap, m);
        assert!(via_nf4
            .iter()
            .zip(&via_bitmap)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // And it is close to the true (unquantized) product.
        let want = matmul_naive(&x, &w);
        let c = Tensor::from_vec(&[m, n], via_nf4);
        assert!(max_abs_diff(&c, &want) < 0.5);
    }

    #[test]
    fn direct_steady_state_does_not_allocate() {
        // The decode hot path's acceptance bar: after one warmup call the
        // transposed working set is arena-resident and repeated calls do
        // not move the thread's allocation counter.
        let mut rng = Rng::new(114);
        let (x, _w, bm) = setup(&mut rng, 4, 96, 64);
        let mut c = vec![0.0f32; 4 * 64];
        sparse_gemm_direct(x.data(), &bm, &mut c, 4);
        let before = crate::util::arena::thread_allocated_bytes();
        for _ in 0..10 {
            sparse_gemm_direct(x.data(), &bm, &mut c, 4);
        }
        assert_eq!(
            crate::util::arena::thread_allocated_bytes(),
            before,
            "sparse_gemm_direct allocated in steady state"
        );
    }

    #[test]
    fn panel_acc_stripes_compose_to_full_width() {
        // Striped panel application (the pipeline consumer kernel) must
        // equal the full-width call bit-for-bit however the columns are
        // split, and the SIMD axpy must not change bits vs its own
        // zero-skip semantics (xv == 0.0 rows contribute nothing).
        let mut rng = Rng::new(116);
        let (m, k, n) = (6usize, 40usize, 53usize);
        let (p0, kb) = (8usize, 16usize);
        let mut x = Tensor::randn(&[m, k], 1.0, &mut rng);
        // Plant exact zeros in the panel's x columns to exercise the skip.
        for i in 0..m {
            x.set(i, p0 + 1, 0.0);
            x.set(i, p0 + 7, 0.0);
        }
        let panel = Tensor::randn(&[kb, n], 1.0, &mut rng);
        let mut full = vec![0.5f32; m * n];
        panel_acc(x.data(), panel.data(), &mut full, m, k, n, p0, kb);
        for splits in [2usize, 3, 5] {
            let mut striped = vec![0.5f32; m * n];
            let cptr = striped.as_mut_ptr();
            for s in 0..splits {
                let j0 = s * n / splits;
                let j1 = (s + 1) * n / splits;
                // SAFETY: single-threaded here; stripes are disjoint.
                unsafe {
                    panel_acc_stripe(x.data(), panel.data(), cptr, m, k, n, p0, kb, j0, j1);
                }
            }
            assert!(
                striped.iter().zip(&full).all(|(a, b)| a.to_bits() == b.to_bits()),
                "splits={splits}"
            );
        }
    }

    #[test]
    fn addmul_stripes_compose_to_full_width() {
        let mut rng = Rng::new(117);
        let (m, r, n) = (4usize, 6usize, 29usize);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let bmat = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut full = vec![0.0f32; m * n];
        // SAFETY: single-threaded; full width.
        unsafe {
            addmul_stripe(u.data(), bmat.data(), full.as_mut_ptr(), m, r, n, 0, n);
        }
        let want = matmul_naive(&u, &bmat);
        let ft = Tensor::from_vec(&[m, n], full.clone());
        assert!(max_abs_diff(&ft, &want) < 1e-3);
        let mut striped = vec![0.0f32; m * n];
        let cptr = striped.as_mut_ptr();
        for (j0, j1) in [(0usize, 13usize), (13, 14), (14, 29)] {
            // SAFETY: single-threaded; stripes are disjoint.
            unsafe {
                addmul_stripe(u.data(), bmat.data(), cptr, m, r, n, j0, j1);
            }
        }
        assert!(striped.iter().zip(&full).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
