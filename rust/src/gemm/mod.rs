//! GEMM engines for the native serving path.
//!
//! The paper's deployment contribution is that bitmap-encoded sparse weights
//! can be *decoded and multiplied* at dense-GEMM throughput by overlapping
//! the two stages. This module provides:
//!
//! * [`dense`] — a blocked, register-tiled, packed-B f32 GEMM,
//!   parallelized over M row bands on the persistent worker pool (the
//!   baseline and the compute stage of the pipeline);
//! * [`sparse`] — bitmap-decode-then-GEMM, sequential (the naive
//!   deployment), plus the column-stripe kernels the parallel consumers
//!   share with the fallback paths;
//! * [`pipeline`] — the paper's two-stage design generalized to P decode
//!   workers filling a lock-free ring of dense K-panels while C consumer
//!   workers apply disjoint output stripes;
//! * [`fused`] — the concatenated multi-adapter GEMM (`A_cat`/`B_cat`)
//!   versus n sequential small GEMMs.
//!
//! All parallel paths are bitwise deterministic across thread counts: work
//! partitions are fixed (MC row bands, column stripes) and per-element
//! accumulation order never depends on the worker count.

pub mod dense;
pub mod fused;
pub mod pipeline;
pub mod sparse;
