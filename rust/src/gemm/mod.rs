//! GEMM engines for the native serving path.
//!
//! The paper's deployment contribution is that bitmap-encoded sparse weights
//! can be *decoded and multiplied* at dense-GEMM throughput by overlapping
//! the two stages. This module provides:
//!
//! * [`kernel`] — the runtime-dispatched 4×16 micro-kernel (AVX2 / NEON /
//!   scalar, all bitwise interchangeable; `SALR_FORCE_SCALAR=1` pins the
//!   fallback);
//! * [`dense`] — a blocked, register-tiled f32 GEMM with both operands
//!   packed into contiguous panels, parallelized over M row bands on the
//!   persistent worker pool; its B-operand pack step is generic over
//!   [`dense::PackB`], so compressed weights (bitmap, bitmap+NF4, or a
//!   [`crate::model::WeightStore`]) decode per tile *inside* the pack —
//!   straight from compressed bytes into the micro-kernel, with no dense
//!   scratch copy of W;
//! * [`sparse`] — the direct sparse kernels that never densify at all
//!   (the small-m decode hot path, generic over [`sparse::SparseSource`]),
//!   plus the column-stripe kernels the parallel pipeline consumers share
//!   with the fallback paths;
//! * [`pipeline`] — the paper's two-stage design generalized to P decode
//!   workers filling a lock-free ring of dense K-panels while C consumer
//!   workers apply disjoint output stripes;
//! * [`fused`] — the concatenated multi-adapter GEMM (`A_cat`/`B_cat`)
//!   versus n sequential small GEMMs.
//!
//! All parallel paths are bitwise deterministic across thread counts *and*
//! across kernel dispatch: work partitions are fixed (MC row bands, column
//! stripes), per-element accumulation order never depends on the worker
//! count, and the SIMD micro-kernels vectorize across output lanes without
//! reordering or contracting any element's k-accumulation. Scratch comes
//! from the per-worker arena ([`crate::util::arena`]) — steady-state calls
//! perform no heap allocation.

pub mod dense;
pub mod fused;
pub mod kernel;
pub mod pipeline;
pub mod sparse;
