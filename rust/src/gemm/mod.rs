//! GEMM engines for the native serving path.
//!
//! The paper's deployment contribution is that bitmap-encoded sparse weights
//! can be *decoded and multiplied* at dense-GEMM throughput by overlapping
//! the two stages. This module provides:
//!
//! * [`dense`] — a blocked, register-tiled f32 GEMM (the baseline and the
//!   compute stage of the pipeline);
//! * [`sparse`] — bitmap-decode-then-GEMM, sequential (the naive deployment);
//! * [`pipeline`] — the paper's two-stage design: decode worker(s) fill a
//!   ring buffer of dense K-panels while the GEMM stage consumes them;
//! * [`fused`] — the concatenated multi-adapter GEMM (`A_cat`/`B_cat`)
//!   versus n sequential small GEMMs.

pub mod dense;
pub mod fused;
pub mod pipeline;
pub mod sparse;
